"""Multi-process swarm: orchestrator and store in SEPARATE processes.

The paper's hub-and-spoke deployment (§2, Fig 6) finally crosses a real
process boundary: a ``StoreServer`` child process owns the authoritative
``StateStore`` behind a length-prefixed TCP socket, and the epoch loop
runs unchanged over ``SocketTransport`` — every token batch, activation,
int8 gradient code, weight shard, reduced copy, anchor and score is a
``repro.api.serde`` frame on the wire, digested server-side.

For both ``sync_mode="dense"`` and ``"sharded"`` (the store-and-forward
butterfly reduce, whose shard traffic now genuinely transits the hub),
the run must reproduce the ``InProcessTransport`` loss trajectory at the
same seed — asserted below; exits non-zero on any mismatch.  smoke.sh
runs this as the socket-path gate.

    PYTHONPATH=src python examples/multiprocess_swarm.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.common import human_bytes

EPOCHS = int(os.environ.get("MP_SWARM_EPOCHS", "2"))


def main():
    from repro.api import (InProcessTransport, KeySchema, SocketTransport,
                           Swarm, SwarmConfig)
    from repro.configs import get, smoke_variant
    from repro.runtime.store_server import spawn_store_server

    mcfg = dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)
    base = SwarmConfig(seed=0, n_stages=2, miners_per_stage=2, inner_steps=2,
                       b_min=1, batch_size=2, seq_len=16, validators=1)

    proc, addr = spawn_store_server()
    print(f"store server: pid {proc.pid} listening on {addr[0]}:{addr[1]} "
          f"(orchestrator pid {os.getpid()})")
    try:
        for mode in ("dense", "sharded"):
            cfg = dataclasses.replace(base, sync_mode=mode)
            schema = KeySchema(version=2 if mode == "sharded" else 1)

            with SocketTransport(addr, schema=schema) as tp:
                tp.reset_store()           # one server, independent runs
                remote = Swarm.create(mcfg, cfg, transport=tp)
                remote_stats = remote.run(EPOCHS)
                report = tp.traffic_report()
                wire = tp.wire_report()

            local = Swarm.create(mcfg, cfg,
                                 transport=InProcessTransport(schema=schema))
            local_stats = local.run(EPOCHS)

            remote_loss = [s.mean_loss for s in remote_stats]
            local_loss = [s.mean_loss for s in local_stats]
            assert remote_loss == local_loss, \
                f"{mode}: socket trajectory diverged: " \
                f"{remote_loss} != {local_loss}"
            assert [s.merged_stages for s in remote_stats] == \
                [s.merged_stages for s in local_stats], mode

            busiest = max(report["by_actor_up"].items(), key=lambda kv: kv[1])
            print(f"{mode:>7}: loss={remote_loss[-1]:.4f} "
                  f"(== in-process at seed {cfg.seed}) | server bytes: "
                  f"up={human_bytes(sum(report['by_actor_up'].values()))} "
                  f"down={human_bytes(sum(report['by_actor_down'].values()))} "
                  f"busiest={busiest[0]}@{human_bytes(busiest[1])} | "
                  f"wire (incl. framing): "
                  f"up={human_bytes(wire['up_bytes'])} "
                  f"down={human_bytes(wire['down_bytes'])} "
                  f"in {wire['requests']} requests")
    finally:
        with SocketTransport(addr) as tp:
            try:
                tp.stop_server()
            except Exception:
                proc.terminate()
        proc.join(timeout=10.0)
    print(f"\nstore server exited (code {proc.exitcode}); "
          f"multiprocess swarm OK")


if __name__ == "__main__":
    main()
