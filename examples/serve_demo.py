"""Batched serving demo: prefill + decode with KV cache over the public API.

    PYTHONPATH=src python examples/serve_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "llama3.2-1b", "--smoke", "--requests", "4",
          "--prompt-len", "32", "--max-new", "24", "--temperature", "0.8"])
