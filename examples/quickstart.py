"""Quickstart: train the paper's bottleneck-Llama (reduced config) end to end.

Trains a ~100M-scale-pattern model (smoke width) for a few hundred steps on
the synthetic corpus with checkpointing, then samples a continuation —
the end-to-end driver deliverable.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.bottleneck import compression_report
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.serve import generate
from repro.models import build_model

STEPS = int(os.environ.get("QUICKSTART_STEPS", "300"))
BATCH, SEQ = 16, 128


def main():
    cfg = configs.smoke_variant(configs.get("iota-bottleneck-1.5b"))
    print("arch:", cfg.model.arch_id, "| params:",
          f"{cfg.model.param_count()/1e6:.1f}M (reduced config)")
    print("compression:", compression_report(cfg.model))

    model = build_model(cfg)
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.model.vocab_size,
                                        seq_len=SEQ, batch_size=BATCH, seed=0))
    ckpt = CheckpointManager("/tmp/iota_quickstart_ckpt", keep=2)
    state = model.init_train_state(jax.random.key(0))

    step_fn = jax.jit(lambda s, b: model.train_step(s, b))
    losses = []
    for t in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(t).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (t + 1) % 50 == 0:
            ckpt.save(t + 1, state)
            print(f"step {t+1:4d} | loss {losses[-1]:.4f} "
                  f"| grad_norm {float(metrics['grad_norm']):.3f}")
    ckpt.wait()
    print(f"\nloss: {losses[0]:.3f} -> {sum(losses[-10:])/10:.3f} "
          f"over {STEPS} steps")

    prompt = jnp.asarray(corpus.batch(10_000)["tokens"][:2, :32])
    out = generate(model, state.params, prompt, max_new=16)
    print("sample continuation ids:", out[0, -16:].tolist())


if __name__ == "__main__":
    main()
