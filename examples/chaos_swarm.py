"""Chaos quickstart: crash-resume and store-failover on a live fleet.

Runs scenarios from the ``repro.scenarios`` catalog (docs/CHAOS.md)
against a real spawned actor swarm — by default the two tentpole
recovery paths:

  * ``kill-n-miners``   — a miner is hard-killed mid-epoch (watermark
    trigger), the ``EventDriver`` re-plans its pending ticks onto the
    stage survivor, and the casualty is respawned from its
    ``DiskSnapshotCache`` snapshot to rejoin mid-run;
  * ``store-failover``  — the primary ``StoreServer`` dies between
    epochs and every client (parent + children) fails over to the
    mirrored warm standby and replays its pending requests.

Each run must *converge* (final loss no worse than 1.05x the first
epoch's); exits non-zero otherwise.  smoke.sh runs this as the chaos
shard.

    PYTHONPATH=src python examples/chaos_swarm.py
    CHAOS_SCENARIOS=slow-link python examples/chaos_swarm.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

NAMES = [s for s in os.environ.get(
    "CHAOS_SCENARIOS", "kill-n-miners,store-failover").split(",") if s]


def main():
    import dataclasses

    from repro.configs import get, smoke_variant
    from repro.scenarios import SCENARIOS, run_scenario

    mcfg = dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)
    failures = 0
    for name in NAMES:
        scenario = SCENARIOS[name]()
        t0 = time.monotonic()
        with tempfile.TemporaryDirectory(prefix=f"chaos-{name}-") as root:
            result = run_scenario(scenario, mcfg, snapshot_root=root)
        wall = time.monotonic() - t0
        ok = result.converged
        failures += 0 if ok else 1
        print(f"{scenario.name:>22}: "
              f"{'ok' if ok else 'FAILED (did not converge)'} | "
              f"loss {result.first_loss:.3f} -> {result.final_loss:.3f} "
              f"over {len(result.stats)} epochs | kills={result.kills} "
              f"replanned={result.replanned_ticks} "
              f"recovery={result.recovery_seconds:.2f}s | {wall:.1f}s")
        for note in result.notes:
            print(f"{'':>24}- {note}")
    if failures:
        raise SystemExit(f"{failures} chaos scenarios failed")
    print("\nchaos swarm OK")


if __name__ == "__main__":
    main()
