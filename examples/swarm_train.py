"""Decentralized swarm training demo — the paper's full Fig 1/Fig 2 loop.

An orchestrator drives miners (layer-slice workers) and validators through
training / compressed-sharing / butterfly full-sync / validation epochs,
with a straggler, a dropper and a free-riding adversary injected.  Watch:
loss falls, the validator catches the cheat, CLASP ranks it worst, and
emissions follow validated work.

    PYTHONPATH=src python examples/swarm_train.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import configs
from repro.runtime import FaultModel, MinerBehavior, Orchestrator, SwarmConfig


def main():
    mcfg = dataclasses.replace(
        configs.smoke_variant(configs.get("llama3.2-1b")).model, n_layers=6)
    swarm = SwarmConfig(n_stages=3, miners_per_stage=3, inner_steps=24,
                        b_min=3, batch_size=4, seq_len=64, compress=True,
                        bottleneck_dim=16, validators=4, seed=0)
    faults = FaultModel({
        2: MinerBehavior(free_ride=True),          # adversary (stage 0)
        4: MinerBehavior(straggle_factor=3.0),     # slow hardware (stage 1)
        7: MinerBehavior(drop_prob=0.4),           # flaky node (stage 2)
    }, seed=0)
    orch = Orchestrator(mcfg, swarm, faults=faults)

    print(f"swarm: {swarm.n_stages} stages x {swarm.miners_per_stage} miners, "
          f"wire={swarm.bottleneck_dim}-d bottleneck codes "
          f"(vs {mcfg.d_model}-d residuals)")
    for epoch in range(5):
        s = orch.run_epoch()
        flagged = (np.where(s.clasp.flagged)[0].tolist()
                   if s.clasp is not None else [])
        cheats = [r.miner_uid for r in s.validation if not r.honest]
        print(f"epoch {s.epoch}: loss {s.mean_loss:.3f} | B_eff {s.b_eff} "
              f"| merged {s.merged_stages}/{swarm.n_stages} stages "
              f"| validator-caught {sorted(set(cheats))} "
              f"| clasp-flagged {flagged}")
    last = orch.history[-1]
    print("\nfinal emissions (miner: share):")
    for uid, share in sorted(last.emissions.items()):
        tag = " <- free-rider" if uid == 2 else ""
        print(f"  miner {uid}: {share:.3f}{tag}")
    print("\nstore traffic:", orch.store.traffic_report()["uploaded"])


if __name__ == "__main__":
    main()
