"""Decentralized swarm training demo — the paper's full Fig 1/Fig 2 loop.

A ``Swarm`` (see repro.api / docs/API.md) drives miners (layer-slice
workers) and validators through training / compressed-sharing / butterfly
full-sync / validation epochs, with a straggler, a dropper and a
free-riding adversary injected.  Watch: loss falls, the validator catches
the cheat, CLASP ranks it worst, and emissions follow validated work.

    python examples/swarm_train.py             # in-process transport
    python examples/swarm_train.py network     # simulated consumer links

The ``network`` variant runs the *same* deterministic trajectory but
accumulates simulated wall-clock per store transfer, reporting what the
epoch loop would cost over realistic links (§5.3 transfer analysis).
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import configs
from repro.api import (NetworkModel, SimulatedNetworkTransport, Swarm,
                       SwarmConfig)
from repro.runtime import FaultModel, MinerBehavior


def main():
    mcfg = dataclasses.replace(
        configs.smoke_variant(configs.get("llama3.2-1b")).model, n_layers=6)
    swarm_cfg = SwarmConfig(n_stages=3, miners_per_stage=3, inner_steps=24,
                            b_min=3, batch_size=4, seq_len=64, compress=True,
                            bottleneck_dim=16, validators=4, seed=0)
    faults = FaultModel({
        2: MinerBehavior(free_ride=True),          # adversary (stage 0)
        4: MinerBehavior(straggle_factor=3.0),     # slow hardware (stage 1)
        7: MinerBehavior(drop_prob=0.4),           # flaky node (stage 2)
    }, seed=0)
    networked = "network" in sys.argv[1:]
    transport = (SimulatedNetworkTransport(NetworkModel.consumer())
                 if networked else None)
    swarm = Swarm.create(mcfg, swarm_cfg, faults=faults, transport=transport)

    print(f"swarm: {swarm_cfg.n_stages} stages x "
          f"{swarm_cfg.miners_per_stage} miners, "
          f"wire={swarm_cfg.bottleneck_dim}-d bottleneck codes "
          f"(vs {mcfg.d_model}-d residuals)"
          + (" | transport=simulated-consumer-links" if networked else ""))
    for epoch in range(5):
        s = swarm.run_epoch()
        flagged = (np.where(s.clasp.flagged)[0].tolist()
                   if s.clasp is not None else [])
        cheats = [r.miner_uid for r in s.validation if not r.honest]
        line = (f"epoch {s.epoch}: loss {s.mean_loss:.3f} | B_eff {s.b_eff} "
                f"| merged {s.merged_stages}/{swarm_cfg.n_stages} stages "
                f"| validator-caught {sorted(set(cheats))} "
                f"| clasp-flagged {flagged}")
        if networked:
            line += f" | sim-clock {swarm.transport.elapsed_seconds():.1f}s"
        print(line)
    last = swarm.history[-1]
    print("\nfinal emissions (miner: share):")
    for uid, share in sorted(last.emissions.items()):
        tag = " <- free-rider" if uid == 2 else ""
        print(f"  miner {uid}: {share:.3f}{tag}")
    print("\nstore traffic:", swarm.transport.traffic_report()["uploaded"])
    if networked:
        print("per-link bytes (top 4 by upload):")
        rep = swarm.transport.link_report()
        top = sorted(rep.items(), key=lambda kv: -kv[1]["up_bytes"])[:4]
        for actor, s in top:
            print(f"  {actor}: up {s['up_bytes']:,} B, "
                  f"down {s['down_bytes']:,} B, "
                  f"busy {s['busy_seconds']:.1f}s")


if __name__ == "__main__":
    main()
