"""Butterfly All-Reduce walkthrough (paper §5, Figs 6-7).

12 miners merge a 1M-parameter layer: two miners drop mid-merge, one
tampers with its reduced shards.  The demo shows O(1) per-miner traffic,
the agreement matrix exposing the tamperer, and the C(N,2)-C(k,2)
fault-recovery arithmetic.

    PYTHONPATH=src python examples/butterfly_merge.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.common import human_bytes
from repro.core import butterfly


def main():
    n, length = 12, 1 << 20
    plan = butterfly.make_plan(n, length, seed=7)
    print(f"{n} miners, {plan.n_shards} pair-shards "
          f"(= C({n},2)), vector = {human_bytes(length*4)}")

    vol = butterfly.transfer_volume(n, length * 4)
    print(f"per-miner traffic: {human_bytes(vol['per_miner_bytes'])} "
          f"(4W + 2W/N) vs central merger ingest "
          f"{human_bytes(vol['central_merger_bytes'])}")

    uploads = {m: np.random.RandomState(m).randn(length).astype(np.float32)
               for m in range(n)}
    expected = np.mean(list(uploads.values()), axis=0)

    # --- clean merge ---
    merged, valid, agree = butterfly.reduce_shards(plan, uploads)
    print(f"\nclean merge: max|err| vs true mean = "
          f"{np.max(np.abs(merged - expected)):.2e}, "
          f"shards valid {valid.sum()}/{plan.n_shards}")

    # --- two reducers die ---
    dead = [3, 8]
    ok = [m not in dead for m in range(n)]
    merged, valid, _ = butterfly.reduce_shards(plan, uploads, reducer_ok=ok)
    lost = (~valid).sum()
    print(f"miners {dead} die: lost shards = {lost} "
          f"(formula says C(2,2)=1), weights retained = "
          f"{valid.mean():.4f} (formula "
          f"{butterfly.valid_shard_fraction(n, len(dead)):.4f})")

    # --- a tamperer ---
    copies = butterfly.reduce_with_copies(plan, uploads, tamper={5: 0.25})
    mat = butterfly.agreement_matrix(plan, copies)
    per_miner = np.array([np.nanmean(mat[m][np.arange(n) != m])
                          for m in range(n)])
    print("\nagreement per miner (1.0 = consensus):")
    print("  " + " ".join(f"m{m}:{per_miner[m]:.2f}" for m in range(n)))
    print(f"=> miner {int(np.argmin(per_miner))} is out of consensus "
          f"(tamperer was miner 5)")


if __name__ == "__main__":
    main()
