"""CLASP audit demo (paper §6 / App. B / Fig 8).

Runs the toy pathway model with planted adversaries and prints the two
attribution rules (conditional mean, miner-as-feature regression) side by
side, then repeats on LIVE losses from a tiny swarm run.

    PYTHONPATH=src python examples/clasp_audit.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import configs
from repro.api import Swarm, SwarmConfig
from repro.core import clasp
from repro.runtime import FaultModel, MinerBehavior


def toy():
    malicious = [3, 12]
    cfg = clasp.ToyConfig(n_samples=5000)
    recs, layer_of = clasp.toy_simulation(cfg, malicious)
    n = cfg.n_layers * cfg.miners_per_layer
    mean_rep = clasp.attribute(recs, n, layer_of)
    reg_rep = clasp.attribute_regression(recs, n, layer_of)

    print(f"toy model: {cfg.n_layers} layers x {cfg.miners_per_layer} "
          f"miners, adversaries = {malicious}")
    print(f"{'miner':>5} {'layer':>5} {'mean_loss':>10} {'z':>7} "
          f"{'beta':>8} {'z_reg':>7}")
    order = np.argsort(-np.nan_to_num(mean_rep.mean_loss))
    for m in order[:8]:
        mark = " <- planted" if m in malicious else ""
        print(f"{m:>5} {layer_of[m]:>5} {mean_rep.mean_loss[m]:>10.4f} "
              f"{mean_rep.z_scores[m]:>7.1f} {reg_rep.mean_loss[m]:>8.4f} "
              f"{reg_rep.z_scores[m]:>7.1f}{mark}")
    print(f"flagged: mean={np.where(mean_rep.flagged)[0].tolist()} "
          f"regression={np.where(reg_rep.flagged)[0].tolist()}")


def live():
    print("\n--- live swarm (free-rider at miner 4) ---")
    mcfg = dataclasses.replace(
        configs.smoke_variant(configs.get("llama3.2-1b")).model, n_layers=6)
    sw = SwarmConfig(n_stages=3, miners_per_stage=3, inner_steps=30, b_min=2,
                     batch_size=2, seq_len=32, validators=0, seed=2)
    swarm = Swarm.create(
        mcfg, sw, faults=FaultModel({4: MinerBehavior(free_ride=True)},
                                    seed=2))
    stats = swarm.run(3)
    rep = stats[-1].clasp
    print("per-miner z-scores:", np.round(rep.z_scores, 1).tolist())
    print(f"worst miner = {int(np.argmax(rep.z_scores))} (planted: 4)")


if __name__ == "__main__":
    toy()
    live()
