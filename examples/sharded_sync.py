"""Sharded store-and-forward sync quickstart (§5.1-5.3, KeySchema v2).

A tiny swarm (2 stages x 4 miners) runs one epoch twice over
``SimulatedNetworkTransport``: once with the dense in-process butterfly
(the golden oracle) and once with ``sync_mode="sharded"``, where every
shard upload, reduce download and reduced-copy re-upload crosses the
transport under the acting miner's link.  Asserts merged-anchor parity
(<= 1e-6) and prints the per-miner byte accounting next to the paper's
4W + 2W/N closed form.  Exits non-zero on any mismatch — smoke.sh runs
this as the sharded-sync gate.

    PYTHONPATH=src python examples/sharded_sync.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.common import human_bytes


def main():
    import jax
    import jax.numpy as jnp
    from jax.flatten_util import ravel_pytree

    from repro.api import (KeySchema, NetworkModel, SimulatedNetworkTransport,
                           Swarm, SwarmConfig)
    from repro.configs import get, smoke_variant

    mcfg = dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)
    base = SwarmConfig(seed=0, n_stages=2, miners_per_stage=4,
                       inner_steps=2, b_min=0, validators=1)

    runs = {}
    for mode in ("dense", "sharded"):
        cfg = dataclasses.replace(base, sync_mode=mode)
        tp = SimulatedNetworkTransport(
            NetworkModel.consumer(),
            schema=KeySchema(version=2 if mode == "sharded" else 1))
        swarm = Swarm.create(mcfg, cfg, transport=tp)
        stats = swarm.run(1)
        runs[mode] = (swarm, tp, stats)
        print(f"{mode:>7}: loss={stats[-1].mean_loss:.4f} "
              f"merged_stages={stats[-1].merged_stages} "
              f"sim_clock={tp.elapsed_seconds():.2f}s")

    # --- merged-anchor parity: sharded must reproduce the dense oracle ---
    def anchor_vecs(swarm):
        return [np.asarray(ravel_pytree(jax.tree.map(
            lambda x: x.astype(jnp.float32), a))[0]) for a in swarm.anchors]

    deltas = [float(np.abs(d - s).max())
              for d, s in zip(anchor_vecs(runs["dense"][0]),
                              anchor_vecs(runs["sharded"][0]))]
    print(f"anchor max|delta| per stage: "
          f"{', '.join(f'{d:.2e}' for d in deltas)}")
    assert max(deltas) <= 1e-6, f"sharded anchors diverged: {deltas}"
    assert runs["sharded"][2][-1].mean_loss == runs["dense"][2][-1].mean_loss

    # --- store-side audit came back clean ---
    audits = runs["sharded"][2][-1].reduce_audits
    assert audits and all(a.clean for a in audits), audits
    print(f"reduce audits: {len(audits)} stages, all clean")

    # --- per-miner bytes vs the closed form (sync traffic dominates) ---
    swarm, tp, _ = runs["sharded"]
    n = base.miners_per_stage
    w = anchor_vecs(swarm)[0].shape[0] * 4
    print(f"\nper-miner bytes, stage-0 miners (W = {human_bytes(w)} fp32; "
          f"closed form 4W + 2W/N = {human_bytes(4 * w + 2 * w / n)}; the "
          f"int8 share codec shrinks the upload/reduce legs ~4x — "
          f"BENCH_butterfly.json measures the fp32 form exactly):")
    rep = tp.link_report()
    for m in swarm.stage_miners(0):
        s = rep[m.actor]
        print(f"  {m.actor}: up={human_bytes(s['up_bytes'])} "
              f"down={human_bytes(s['down_bytes'])}")
    print("\nsharded sync OK")


if __name__ == "__main__":
    main()
