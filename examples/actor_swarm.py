"""Concurrent actor swarm: every miner and validator is its OWN process.

The paper's SWARM peers (§2) are autonomous workers polling a globally
accessible store — nobody calls them.  ``Swarm.create(...,
runtime="actors")`` builds exactly that: N miner processes + validator
processes (``spawn`` context, one ``SocketTransport`` store connection
each, a TCP health endpoint each), pulling work off the store through a
``WorkQueue`` while the parent's ``EventDriver`` publishes the epoch
plan and advances on watermark keys (tick losses, scores, uploads).

Determinism is the whole point: all swarm RNG is drawn at plan time in
the lockstep order and actors interact only through bit-exact store
payloads, so for both ``sync_mode="dense"`` and ``"sharded"`` the
concurrent run must reproduce the in-process loss trajectory at the
same seed — asserted below; exits non-zero on any mismatch.  smoke.sh
runs this as the actor-runtime gate.

    PYTHONPATH=src python examples/actor_swarm.py
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

EPOCHS = int(os.environ.get("ACTOR_SWARM_EPOCHS", "2"))


def main():
    from repro.api import Swarm, SwarmConfig
    from repro.configs import get, smoke_variant

    mcfg = dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)
    base = SwarmConfig(seed=0, n_stages=2, miners_per_stage=2, inner_steps=2,
                       b_min=1, batch_size=2, seq_len=16, validators=1)

    for mode in ("dense", "sharded"):
        cfg = dataclasses.replace(base, sync_mode=mode)

        swarm = Swarm.create(mcfg, cfg, runtime="actors")
        try:
            t0 = time.monotonic()
            swarm.start()
            spawn_s = time.monotonic() - t0
            beats = [swarm.supervisor.ping(n) for n in swarm.supervisor.names]
            assert len(beats) == cfg.n_stages * cfg.miners_per_stage \
                + cfg.validators, beats
            t0 = time.monotonic()
            actor_stats = swarm.run(EPOCHS)
            train_s = time.monotonic() - t0
        finally:
            swarm.shutdown()

        local = Swarm.create(mcfg, cfg)
        local_stats = local.run(EPOCHS)

        actor_loss = [s.mean_loss for s in actor_stats]
        local_loss = [s.mean_loss for s in local_stats]
        assert actor_loss == local_loss, \
            f"{mode}: actor trajectory diverged: {actor_loss} != {local_loss}"
        assert [s.merged_stages for s in actor_stats] == \
            [s.merged_stages for s in local_stats], mode
        assert [[(r.miner_uid, r.score) for r in s.validation]
                for s in actor_stats] == \
            [[(r.miner_uid, r.score) for r in s.validation]
             for s in local_stats], mode

        pids = sorted({b.pid for b in beats})
        print(f"{mode:>7}: loss={actor_loss[-1]:.4f} (== in-process at "
              f"seed {cfg.seed}) | {len(beats)} actor processes "
              f"(pids {pids[0]}..{pids[-1]}), spawned in {spawn_s:.1f}s, "
              f"{EPOCHS} epochs in {train_s:.1f}s")

    print("\nactor swarm OK")


if __name__ == "__main__":
    main()
