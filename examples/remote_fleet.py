"""Remote fleets: train AND serve actor processes against one external store.

Every other example lets the runtime spin up its own store.  Here the
store is a separate OS process started first — the same topology as
pointing ``--store-address`` at an already-running

    python -m repro.runtime.store_server --port 8799

on another machine — and two successive actor fleets attach to it:

  1. a training swarm (``Swarm.create(..., runtime="actors",
     store_address=...)``), checked against the in-process oracle's
     loss trajectory at the same seed;
  2. a serve fleet (``serve_swarm(..., transport="actors",
     store_address=...)``), checked token-for-token against the
     sequential ``swarm_generate`` oracle.

Neither fleet owns the store's lifecycle: shutdown leaves it running,
which is exactly what lets fleets come and go against a long-lived
store.  Exits non-zero on any mismatch.

    PYTHONPATH=src python examples/remote_fleet.py
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def train_against(store_address, mcfg):
    from repro.api import Swarm, SwarmConfig

    cfg = SwarmConfig(seed=0, n_stages=2, miners_per_stage=1, inner_steps=2,
                      b_min=1, batch_size=2, seq_len=16, validators=1)
    swarm = Swarm.create(mcfg, cfg, runtime="actors",
                         store_address=store_address)
    try:
        swarm.start()
        stats = swarm.run(2)
    finally:
        swarm.shutdown()

    local = Swarm.create(mcfg, cfg)
    local_stats = local.run(2)
    remote_loss = [s.mean_loss for s in stats]
    local_loss = [s.mean_loss for s in local_stats]
    assert remote_loss == local_loss, \
        f"remote-store trajectory diverged: {remote_loss} != {local_loss}"
    return remote_loss[-1]


def serve_against(store_address, mcfg):
    import numpy as np

    from repro.api.phases import ServeRequest
    from repro.launch.serve import serve_swarm, swarm_generate
    from repro.runtime import stage_model as sm

    spec = sm.SwarmModelSpec(mcfg, 2)
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(req=i,
                         prompt=rng.integers(3, mcfg.vocab_size, 6,
                                             dtype=np.int32),
                         max_new=4) for i in range(3)]
    records = serve_swarm(spec, reqs, n_lanes=2, max_len=10,
                          transport="actors", store_address=store_address)
    oracle = swarm_generate(spec, 0, reqs)
    for r in reqs:
        assert records[r.req].tokens == oracle[r.req], \
            f"req {r.req}: {records[r.req].tokens} != {oracle[r.req]}"
    return sum(len(rec.tokens) for rec in records.values())


def main():
    from repro.configs import get, smoke_variant
    from repro.runtime.store_server import spawn_store_server

    mcfg = dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)

    proc, address = spawn_store_server()
    print(f"external store listening on {address[0]}:{address[1]} "
          f"(pid {proc.pid})")
    try:
        t0 = time.monotonic()
        loss = train_against(address, mcfg)
        t1 = time.monotonic()
        print(f"  train fleet: loss={loss:.4f} (== in-process oracle) "
              f"in {t1 - t0:.1f}s")
        n_tok = serve_against(address, mcfg)
        t2 = time.monotonic()
        print(f"  serve fleet: {n_tok} tokens (== sequential oracle) "
              f"in {t2 - t1:.1f}s")
        assert proc.is_alive(), "fleet shutdown must not stop the store"
    finally:
        proc.terminate()
        proc.join()

    print("\nremote fleet OK")


if __name__ == "__main__":
    main()
