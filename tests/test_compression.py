"""Wire codecs + CLASP top-k logits (paper §2 compressed sharing, §4, §6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import compression


@pytest.mark.parametrize("codec", compression.CODECS)
def test_roundtrip_shapes(codec):
    v = jnp.asarray(np.random.RandomState(0).randn(4096), jnp.float32)
    p = compression.encode(v, codec)
    r = compression.decode(p, 4096)
    assert r.shape == v.shape


def test_bf16_ratio_and_error():
    v = jnp.asarray(np.random.RandomState(1).randn(4096), jnp.float32)
    p = compression.encode(v, "bf16")
    assert compression.compression_ratio(p, 4096) == pytest.approx(2.0)
    assert float(jnp.max(jnp.abs(compression.decode(p, 4096) - v))) < 0.05


def test_int8_error_bounded_by_scale():
    v = jnp.asarray(np.random.RandomState(2).randn(4096) * 3, jnp.float32)
    p = compression.encode(v, "int8")
    r = compression.decode(p, 4096)
    # per-block error <= scale/2 = amax/254
    blocks = np.asarray(v).reshape(-1, compression.INT8_BLOCK)
    amax = np.abs(blocks).max(axis=1)
    err = np.abs(np.asarray(r - v)).reshape(-1, compression.INT8_BLOCK)
    assert (err.max(axis=1) <= amax / 127.0 * 0.51 + 1e-6).all()


def test_topk_keeps_largest():
    v = jnp.zeros(1024).at[17].set(100.0).at[500].set(-50.0)
    p = compression.encode(v, "topk", topk_frac=2 / 1024)
    r = compression.decode(p, 1024)
    assert float(r[17]) == pytest.approx(100.0, rel=1e-2)
    assert float(r[500]) == pytest.approx(-50.0, rel=1e-2)
    assert float(jnp.sum(jnp.abs(r))) == pytest.approx(150.0, rel=1e-2)


@given(frac=st.sampled_from([1 / 256, 1 / 64, 1 / 16]),
       seed=st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_topk_ratio_scales(frac, seed):
    v = jnp.asarray(np.random.RandomState(seed).randn(8192), jnp.float32)
    p = compression.encode(v, "topk", topk_frac=frac)
    ratio = compression.compression_ratio(p, 8192)
    # values bf16 + idx int32 = 6 bytes per kept element vs 4*n
    assert ratio == pytest.approx((4 / 6) / frac, rel=0.1)


def test_topk_logits_exact_when_label_in_topk():
    rng = np.random.RandomState(3)
    logits = jnp.asarray(rng.randn(4, 8, 512) * 3, jnp.float32)
    labels = jnp.argmax(logits, axis=-1)       # guaranteed in top-k
    payload = compression.topk_logits(logits, k=16)
    nll, exact = compression.loss_from_topk(payload, labels)
    ref = -(jax.nn.log_softmax(logits)[
        jnp.arange(4)[:, None], jnp.arange(8)[None], labels])
    assert bool(jnp.all(exact))
    # values ride the wire in bf16: |err| <= bf16 eps at the logit scale
    np.testing.assert_allclose(np.asarray(nll), np.asarray(ref),
                               rtol=5e-2, atol=0.15)


def test_topk_logits_bandwidth():
    logits = jnp.zeros((1, 1, 151936))
    payload = compression.topk_logits(logits, k=64)
    nbytes = compression.payload_bytes(payload)
    assert nbytes < 151936 * 4 / 100           # >100x smaller than raw fp32
