"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must

see 1 device (only launch/dryrun.py forces 512 host devices, and the
multi-device tests spawn subprocesses that set their own flags)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
