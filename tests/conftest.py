"""Shared fixtures + the multi-device subprocess harness.  NOTE: no

XLA_FLAGS here — smoke tests and benches must see 1 device (only
launch/dryrun.py forces 512 host devices, and the multi-device tests
spawn subprocesses that set their own flags)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, SRC)

# prepended to every multi-device subprocess: jax<=0.4.x has no
# jax.sharding.AxisType — fall back to the positional mesh (explicit axis
# types are an optimisation hint here, not semantics)
MESH_COMPAT = """
import jax


def make_mesh(shape, names):
    try:
        return jax.make_mesh(shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(names))
    except AttributeError:
        return jax.make_mesh(shape, names)
"""


def run_py(code: str, devices: int = 8) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` forced host
    devices (the count must be fixed before jax initialises)."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", MESH_COMPAT + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session", autouse=True)
def _checked_store():
    """``REPRO_CHECKED_STORE=1`` runs the whole session with every
    ``StateStore`` operation sanitized (key shape vs the KeySchema,
    write-after-publish, read-before-write) — see
    repro.analysis.checked_store.  smoke.sh runs the store/transport
    shards under the flag; any suite must stay green with it on."""
    if os.environ.get("REPRO_CHECKED_STORE") != "1":
        yield None
        return
    from repro.analysis.checked_store import StoreSanitizer
    with StoreSanitizer() as sanitizer:
        yield sanitizer
