"""Per-arch smoke tests (assignment requirement): reduced same-family config,

one forward/train step on CPU, asserting output shapes + no NaNs; plus a
decode step for decoder archs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import build_model
from repro.models.frontends import audio_frame_embeds

ALL_ARCHS = configs.all_arch_ids(include_paper_ref=True)


@pytest.fixture(scope="module")
def smoke_models():
    return {}


def _get(smoke_models, arch):
    if arch not in smoke_models:
        cfg = configs.smoke_variant(configs.get(arch))
        smoke_models[arch] = (cfg, build_model(cfg))
    return smoke_models[arch]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(smoke_models, arch):
    cfg, model = _get(smoke_models, arch)
    state = model.init_train_state(jax.random.key(0))
    batch = model.synth_batch(jax.random.key(1), 4, 32)
    new_state, metrics = jax.jit(lambda s, b: model.train_step(s, b))(
        state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert float(metrics["grad_norm"]) > 0, arch
    assert int(new_state.step) == 1
    # params actually changed
    import numpy as np
    p0 = jax.tree_util.tree_leaves(state.params)[0]
    p1 = jax.tree_util.tree_leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(smoke_models, arch):
    cfg, model = _get(smoke_models, arch)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = model.synth_batch(jax.random.key(1), B, S)
    lgts, _, aux = model.forward(params, batch, None)
    assert lgts.shape == (B, S, cfg.model.padded_vocab)
    assert lgts.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(lgts)))
    # padded vocab entries are masked to ~-inf
    if cfg.model.padded_vocab > cfg.model.vocab_size:
        assert float(jnp.max(lgts[..., cfg.model.vocab_size:])) < -1e6


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step_smoke(smoke_models, arch):
    cfg, model = _get(smoke_models, arch)
    params = model.init(jax.random.key(0))
    B, S_cache = 2, 16
    state = model.init_decode_state(B, S_cache)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.model.family == "audio":
        batch["memory"] = audio_frame_embeds(
            jax.random.key(2), B, 8, cfg.model.d_model)
    lgts, new_state = jax.jit(lambda p, st, b: model.decode_step(p, st, b))(
        params, state, batch)
    assert lgts.shape == (B, 1, cfg.model.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lgts)))
    assert new_state is not None


def test_decode_matches_forward_prefix():
    """Incremental decoding == full forward on the same prefix (llama)."""
    cfg = configs.smoke_variant(configs.get("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(3), (B, S), 3,
                              cfg.model.vocab_size, jnp.int32)
    full, _, _ = model.forward(params, {"tokens": toks}, None)

    state = model.init_decode_state(B, S)
    outs = []
    step = jax.jit(lambda p, st, b: model.decode_step(p, st, b))
    for t in range(S):
        lgts, state = step(params, state, {"tokens": toks[:, t:t + 1]})
        outs.append(lgts[:, 0])
    inc = jnp.stack(outs, axis=1)
    import numpy as np
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=0.15, atol=0.15)
    # ranking agreement on the final position (bf16 cache tolerance)
    assert (jnp.argmax(inc[:, -1], -1) == jnp.argmax(full[:, -1], -1)).all()


@pytest.mark.parametrize("arch", ["xlstm-125m", "jamba-v0.1-52b"])
def test_recurrent_state_is_o1(smoke_models, arch):
    """Sub-quadratic archs: decode-state bytes don't grow with max_len

    (beyond the attention layers' caches for the hybrid)."""
    cfg, model = _get(smoke_models, arch)
    from repro.common import tree_bytes
    s1 = model.init_decode_state(2, 64)
    s2 = model.init_decode_state(2, 128)
    if arch == "xlstm-125m":
        assert tree_bytes(s1) == tree_bytes(s2)
    else:
        growth = tree_bytes(s2) / tree_bytes(s1)
        assert growth < 2.0          # only the 1-in-8 attn layers grow


def test_param_count_analytic_close_to_actual():
    """ModelConfig.param_count (used for MODEL_FLOPS) tracks real init."""
    from repro.common import tree_size
    for arch in ["llama3.2-1b", "olmoe-1b-7b", "xlstm-125m"]:
        cfg = configs.smoke_variant(configs.get(arch))
        model = build_model(cfg)
        actual = tree_size(model.init(jax.random.key(0)))
        predicted = cfg.model.param_count()
        assert abs(actual - predicted) / actual < 0.15, (
            arch, actual, predicted)


def test_full_config_param_counts():
    """Sanity: the assigned archs' analytic sizes land near their names."""
    expect = {"llama3.2-1b": (1.0e9, 2.0e9),
              "qwen3-14b": (12e9, 17e9),
              "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
              "jamba-v0.1-52b": (40e9, 60e9)}
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).model.param_count()
        assert lo < n < hi, (arch, n)
    active = configs.get("kimi-k2-1t-a32b").model.active_param_count()
    assert 20e9 < active < 45e9          # "a32b"
