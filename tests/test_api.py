"""Peer-protocol API tests: messages, KeySchema, transports, driver.

The golden trajectory constants below were recorded from the *seed*
monolithic ``Orchestrator`` (commit b78e3ed) running
``Orchestrator(mcfg, SwarmConfig(seed=0)).run(3)`` — the refactored
runtime must reproduce them bit-exactly through ``InProcessTransport``.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    ActivationMsg,
    AnchorMsg,
    GradientMsg,
    InProcessTransport,
    KeySchema,
    NetworkModel,
    ScoreMsg,
    SimulatedNetworkTransport,
    Swarm,
    SwarmConfig,
    WeightUploadMsg,
    message_for_key,
)
from repro.api.transport import LinkSpec
from repro.configs import get, smoke_variant
from repro.runtime import Orchestrator, StateStore, StoreKeyError

# seed trajectory: per-epoch EpochStats.mean_loss / b_eff / merged_stages
SEED_MEAN_LOSS = [6.283693909645081, 6.273095548152924, 6.267263352870941]
SEED_B_EFF = [16, 9, 13]
SEED_MERGED = [1, 0, 0]


def _mcfg(n_layers=6):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


# ---------------------------------------------------------------------------
# messages + keys
# ---------------------------------------------------------------------------

ALL_MESSAGES = [
    ActivationMsg.tokens(3, 1),
    ActivationMsg(3, 1, stage=2, miner_uid=7),
    GradientMsg(3, 1, stage=2, miner_uid=7),
    WeightUploadMsg(4, stage=0, miner_uid=5),
    AnchorMsg(4, stage=0),
    ScoreMsg(2, validator_uid=1, miner_uid=9),
]


def test_keys_match_seed_layout():
    ks = KeySchema()
    assert ks.tokens(0, 2) == "activations/ep0/t2/tokens"
    assert ks.activation(0, 2, 1, 4) == "activations/ep0/t2/s1/m4"
    assert ks.gradient(0, 2, 1, 4) == "activations/ep0/t2/s1/m4/grad"
    assert ks.gradient_for("activations/ep0/t2/s1/m4") == \
        "activations/ep0/t2/s1/m4/grad"
    assert ks.weight_upload(1, 0, 3) == "weights/ep1/s0/m3"
    assert ks.anchor(1, 0) == "weights/ep1/s0/merged"
    assert ks.activations_prefix(5) == "activations/ep5"


def test_key_schema_version_gate():
    assert KeySchema(version=1).version == 1
    with pytest.raises(ValueError):
        KeySchema(version=99)


@pytest.mark.parametrize("msg", ALL_MESSAGES, ids=lambda m: type(m).__name__
                         + ("/tokens" if getattr(m, "is_tokens", False)
                            else ""))
def test_key_parse_inverts_mint(msg):
    ks = KeySchema()
    assert message_for_key(msg.key(ks), ks) == msg


def test_parse_rejects_foreign_keys():
    with pytest.raises(ValueError):
        KeySchema().parse("checkpoints/step100")


def test_weight_upload_roundtrip_ignores_codec():
    # codec is advisory and not in the key: the audit inverse must hold
    # for any share_codec the config picked
    ks = KeySchema()
    msg = WeightUploadMsg(4, stage=0, miner_uid=5, codec="bf16")
    assert message_for_key(msg.key(ks), ks) == msg


@pytest.mark.parametrize("transport_cls", [
    InProcessTransport,
    lambda: SimulatedNetworkTransport(NetworkModel.consumer()),
], ids=["in_process", "simulated_network"])
def test_message_roundtrip_through_transport(transport_cls):
    tp = transport_cls()
    rng = np.random.RandomState(0)
    for i, msg in enumerate(ALL_MESSAGES):
        payload = rng.randn(8 + i).astype(np.float32)
        digest = tp.publish(msg, payload, actor=f"actor{i}")
        assert isinstance(digest, str) and digest
        got = tp.fetch(msg, actor=f"actor{i}")
        np.testing.assert_array_equal(got, payload)
    # raw-key plane sees the same objects
    ks = tp.schema
    np.testing.assert_array_equal(
        tp.get(ALL_MESSAGES[0].key(ks)), tp.fetch(ALL_MESSAGES[0]))


# ---------------------------------------------------------------------------
# StoreKeyError (descriptive missing-key diagnostics)
# ---------------------------------------------------------------------------

def test_store_missing_key_is_descriptive():
    store = StateStore()
    store.put("activations/ep0/t0/tokens", np.zeros(4), actor="orchestrator")
    with pytest.raises(StoreKeyError) as ei:
        store.get("activations/ep0/t1/s0/m2", actor="miner2")
    err = ei.value
    assert isinstance(err, KeyError)            # drop-in for bare KeyError
    assert err.key == "activations/ep0/t1/s0/m2"
    assert err.actor == "miner2"
    assert err.nearest_prefix == "activations/ep0"
    msg = str(err)
    assert "miner2" in msg and "activations/ep0" in msg


def test_store_key_error_surfaces_through_transports():
    for tp in (InProcessTransport(), SimulatedNetworkTransport()):
        with pytest.raises(StoreKeyError):
            tp.get("weights/ep9/s0/merged", actor="miner0")
        with pytest.raises(StoreKeyError):
            tp.fetch(AnchorMsg(9, 0), actor="miner0")


# ---------------------------------------------------------------------------
# trajectory equivalence + byte accounting (full golden config)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden_runs():
    in_proc = Orchestrator(_mcfg(), SwarmConfig(seed=0))
    in_stats = in_proc.run(3)
    net_tp = SimulatedNetworkTransport(NetworkModel.consumer())
    net = Swarm.create(_mcfg(), SwarmConfig(seed=0), transport=net_tp)
    net_stats = net.run(3)
    return in_proc, in_stats, net_tp, net_stats


def test_in_process_matches_seed_trajectory_bit_exactly(golden_runs):
    _, stats, _, _ = golden_runs
    assert [s.mean_loss for s in stats] == SEED_MEAN_LOSS
    assert [s.b_eff for s in stats] == SEED_B_EFF
    assert [s.merged_stages for s in stats] == SEED_MERGED


def test_network_transport_same_trajectory(golden_runs):
    _, in_stats, _, net_stats = golden_runs
    assert [s.mean_loss for s in net_stats] == [s.mean_loss for s in in_stats]
    assert [s.b_eff for s in net_stats] == [s.b_eff for s in in_stats]


def test_network_clock_advances(golden_runs):
    _, _, tp, _ = golden_runs
    assert tp.elapsed_seconds() > 0.0
    assert all(s["busy_seconds"] > 0 for s in tp.link_report().values())


def test_network_bytes_match_store_accounting(golden_runs):
    _, _, tp, _ = golden_runs
    rep = tp.link_report()
    store_rep = tp.store.traffic_report()
    assert sum(s["up_bytes"] for s in rep.values()) == \
        sum(store_rep["uploaded"].values())
    assert sum(s["down_bytes"] for s in rep.values()) == \
        sum(store_rep["downloaded"].values())
    # per-actor totals agree too (link accounting == store actor accounting)
    for actor, s in rep.items():
        assert s["up_bytes"] == store_rep["by_actor_up"].get(actor, 0)
        assert s["down_bytes"] == store_rep["by_actor_down"].get(actor, 0)


def test_scores_published_to_store(golden_runs):
    in_proc, in_stats, _, _ = golden_runs
    score_keys = in_proc.store.keys("scores/")
    assert len(score_keys) == sum(len(s.validation) for s in in_stats)
    for k in score_keys:
        msg = message_for_key(k, in_proc.transport.schema)
        assert isinstance(msg, ScoreMsg)


# ---------------------------------------------------------------------------
# transports: timing model
# ---------------------------------------------------------------------------

def test_link_spec_transfer_time():
    link = LinkSpec(latency_s=0.01, bandwidth_mbps=8.0)   # 1 MB/s
    assert link.transfer_seconds(1_000_000) == pytest.approx(1.01)


def test_parallel_block_takes_max_not_sum():
    tp = SimulatedNetworkTransport(
        NetworkModel(default=LinkSpec(latency_s=1.0, bandwidth_mbps=1e9)))
    with tp.parallel():
        for i in range(5):
            tp.put(f"weights/ep0/s0/m{i}", np.zeros(4), actor=f"miner{i}")
    assert tp.elapsed_seconds() == pytest.approx(1.0)     # overlapped
    tp.put("weights/ep0/s0/merged", np.zeros(4), actor="orchestrator")
    assert tp.elapsed_seconds() == pytest.approx(2.0)     # sequential


def test_parallel_block_serializes_same_link():
    # overlap is across links only: one actor's transfers still queue
    tp = SimulatedNetworkTransport(
        NetworkModel(default=LinkSpec(latency_s=1.0, bandwidth_mbps=1e9)))
    with tp.parallel():
        tp.put("weights/ep0/s0/m0", np.zeros(4), actor="miner0")
        tp.put("weights/ep0/s1/m0", np.zeros(4), actor="miner0")
        tp.put("weights/ep0/s0/m1", np.zeros(4), actor="miner1")
    assert tp.elapsed_seconds() == pytest.approx(2.0)     # miner0's sum


def test_in_process_transport_is_free():
    tp = InProcessTransport()
    tp.put("weights/ep0/s0/m0", np.zeros(1024), actor="miner0")
    tp.get("weights/ep0/s0/m0", actor="miner1")
    assert tp.elapsed_seconds() == 0.0
    assert tp.link_report() == {}


# ---------------------------------------------------------------------------
# facade + driver
# ---------------------------------------------------------------------------

def test_swarm_facade_run(golden_runs):
    in_proc, _, _, _ = golden_runs
    # the facade exposes the seed-era surface the tests/examples rely on
    assert in_proc.swarm.b_min == in_proc.config.b_min
    assert in_proc.store is in_proc.transport.store
    assert len(in_proc.history) == 3


def test_custom_phase_timeline():
    from repro.api import TrainingPhase, SharingPhase, SyncPhase

    class CountingPhase:
        name = "counting"

        def __init__(self):
            self.calls = 0

        def run(self, swarm, state):
            self.calls += 1

    probe = CountingPhase()
    sw = Swarm.create(
        _mcfg(), SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=2,
                             b_min=1, batch_size=2, seq_len=16, validators=0,
                             seed=0),
        phases=[TrainingPhase(), probe, SharingPhase(), SyncPhase()])
    stats = sw.run(2)
    assert probe.calls == 2
    assert len(stats) == 2 and np.isfinite(stats[-1].mean_loss)


def test_timeline_without_sharing_still_reports_batches():
    from repro.api import TrainingPhase

    sw = Swarm.create(
        _mcfg(), SwarmConfig(n_stages=3, miners_per_stage=1, inner_steps=3,
                             b_min=2, batch_size=2, seq_len=16, validators=0,
                             seed=0),
        phases=[TrainingPhase()])
    stats = sw.run(1)[0]
    assert sum(stats.batches.values()) > 0
    assert stats.b_eff == sum(b for b in stats.batches.values() if b >= 2)
