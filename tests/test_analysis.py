"""swarmlint suite: every rule (positive + suppressed-negative fixtures),
the CLI contract, the repo-wide zero-findings gate, and both runtime
sanitizers (TraceWatch retrace counting, CheckedStore store invariants).

The fixtures are in-memory source strings run through the same
``ModuleSource``/``run_rules`` path as the CLI, so a rule behaviour change
shows up here before it shows up as a confusing smoke.sh failure.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import SRC, run_py
from repro.analysis import (
    ALL_RULES, ActorRuntimeRule, KeyLiteralRule, ModuleSource,
    NoPickleEvalRule, ProtocolConformanceRule, ScenarioConformanceRule,
    ScheduleRegistryRule, SerdeCoverageRule, SpawnSafetyRule, run_rules,
)
from repro.analysis.__main__ import main as lint_main

REPO = os.path.dirname(SRC)


def lint(sources: dict, rules) -> list:
    """Run rules over {relpath: source} fixtures; returns findings."""
    modules = [ModuleSource(rel, rel, textwrap.dedent(text))
               for rel, text in sources.items()]
    return run_rules(modules, [r() for r in rules])


# ---------------------------------------------------------------------------
# key-literal
# ---------------------------------------------------------------------------


def test_key_literal_flags_plain_and_fstring():
    found = lint({"src/repro/runtime/miner.py": '''
        def up(e):
            a = "weights/ep0/s0/m1"
            b = f"activations/ep{e}/t0/tokens"
            return a, b
    '''}, [KeyLiteralRule])
    assert [f.line for f in found] == [3, 4]
    assert all(f.rule == "key-literal" for f in found)


def test_key_literal_sees_shard_fragment_in_fstring():
    # f"...shard{k}" renders as "shard{" in static text — the form a plain
    # grep for the quoted prefix misses
    found = lint({"src/repro/core/butterfly.py": '''
        def k(base, i):
            return f"{base}/shard{i}"
    '''}, [KeyLiteralRule])
    assert len(found) == 1


def test_key_literal_exempts_mint_module_and_docstrings():
    found = lint({
        "src/repro/api/keys.py": '''
            NS = "weights/"
        ''',
        "src/repro/api/phases.py": '''
            def run():
                """Reads ``scores/ep{E}`` rows (documentation only)."""
                return 1
        ''',
    }, [KeyLiteralRule])
    assert found == []


def test_key_literal_suppression_line_and_file():
    line = lint({"src/m.py": '''
        K = "weights/ep0/s0/m1"  # swarmlint: disable=key-literal
    '''}, [KeyLiteralRule])
    assert line == []
    file_ = lint({"src/m.py": '''
        # swarmlint: disable-file=key-literal
        A = "weights/ep0/s0/m1"
        B = "scores/ep0/v0/m0"
    '''}, [KeyLiteralRule])
    assert file_ == []
    wrong_rule = lint({"src/m.py": '''
        K = "weights/ep0/s0/m1"  # swarmlint: disable=no-pickle-eval
    '''}, [KeyLiteralRule])
    assert len(wrong_rule) == 1


# ---------------------------------------------------------------------------
# serde-coverage
# ---------------------------------------------------------------------------

_MESSAGES = '''
    class PingMsg:
        pass

    class PongMsg:
        pass
'''


def test_serde_coverage_passes_when_registered():
    found = lint({
        "src/repro/api/messages.py": _MESSAGES,
        "src/repro/api/serde.py": '''
            from repro.api import messages
            def _register(cls):
                return cls
            _register(messages.PingMsg)
            _register(messages.PongMsg)
        ''',
    }, [SerdeCoverageRule])
    assert found == []


def test_serde_coverage_flags_unregistered_and_stale():
    found = lint({
        "src/repro/api/messages.py": _MESSAGES,
        "src/repro/api/serde.py": '''
            def _register(cls):
                return cls
            _register(PingMsg)
            _register(GhostMsg)
        ''',
    }, [SerdeCoverageRule])
    assert {(f.path.split("/")[-1], f.message.split(" ")[0])
            for f in found} == {("messages.py", "PongMsg"),
                                ("serde.py", "_register(GhostMsg)")}


def test_serde_coverage_reports_half_scope():
    found = lint({"src/repro/api/messages.py": _MESSAGES},
                 [SerdeCoverageRule])
    assert len(found) == 1 and "cannot cross-check" in found[0].message


# ---------------------------------------------------------------------------
# protocol-conformance
# ---------------------------------------------------------------------------

_PROTO = textwrap.dedent('''
    from typing import Protocol

    class Phase(Protocol):
        name: str

        def run(self, swarm, state):
            ...
''')


def test_protocol_conformance_suffix_binding():
    found = lint({
        "src/repro/api/phases.py": _PROTO + textwrap.dedent('''
            class GoodPhase:
                name = "good"
                def run(self, swarm, state):
                    return state

            class BadPhase:
                def run(self, swarm, state):
                    return state
        '''),
    }, [ProtocolConformanceRule])
    assert len(found) == 1
    assert "BadPhase" in found[0].message
    assert "name (attribute)" in found[0].message


def test_protocol_conformance_marker_and_inheritance():
    found = lint({
        "src/repro/api/phases.py": _PROTO,
        "src/repro/api/extra.py": '''
            class _Base:
                def run(self, swarm, state):
                    return state

            class Overlapped(_Base):  # swarmlint: implements=Phase
                def __init__(self):
                    self.name = "overlap"

            class Sneaky:  # swarmlint: implements=Phase
                name = "sneaky"
        ''',
    }, [ProtocolConformanceRule])
    assert len(found) == 1 and "Sneaky" in found[0].message
    assert "run" in found[0].message


def test_protocol_conformance_skips_unknown_bases():
    found = lint({
        "src/repro/api/phases.py": _PROTO + textwrap.dedent('''
            import thirdparty

            class VendoredPhase(thirdparty.Base):
                pass
        '''),
    }, [ProtocolConformanceRule])
    assert found == []       # out-of-scope base: cannot judge statically


# ---------------------------------------------------------------------------
# no-pickle-eval
# ---------------------------------------------------------------------------


def test_no_pickle_eval_flags_imports_and_calls():
    found = lint({"src/m.py": '''
        import pickle
        from dill import loads

        def f(s):
            return eval(s)
    '''}, [NoPickleEvalRule])
    assert [f.line for f in found] == [2, 3, 6]


def test_no_pickle_eval_ignores_lookalikes():
    found = lint({"src/m.py": '''
        import pickletools_unrelated as pt

        def f(model, s):
            return model.eval(), s.encode()
    '''}, [NoPickleEvalRule])
    assert found == []


# ---------------------------------------------------------------------------
# spawn-safety
# ---------------------------------------------------------------------------

_SPAWN_FIXTURE = {
    "src/repro/__init__.py": "",
    "src/repro/runtime/__init__.py": "",
    "src/repro/runtime/store_server.py": '''
        from repro.api import serde
    ''',
    "src/repro/api/__init__.py": '''
        from repro.api import helper
    ''',
}


def test_spawn_safety_flags_module_level_device_work():
    found = lint(dict(_SPAWN_FIXTURE, **{
        "src/repro/api/serde.py": '''
            import jax.numpy as jnp
            _SENTINEL = jnp.zeros((4,))
        ''',
        "src/repro/api/helper.py": '''
            import jax
            N = jax.device_count()
        ''',
    }), [SpawnSafetyRule])
    assert {(f.path.split("/")[-1], f.line) for f in found} == {
        ("serde.py", 3), ("helper.py", 3)}
    assert all("spawned store server" in f.message for f in found)


def test_spawn_safety_allows_lazy_and_out_of_closure():
    found = lint(dict(_SPAWN_FIXTURE, **{
        "src/repro/api/serde.py": '''
            import jax.numpy as jnp

            def zeros():
                return jnp.zeros((4,))     # lazy: runs per call, not import
        ''',
        "src/repro/api/helper.py": "",
        "src/repro/launch/train.py": '''
            import jax.numpy as jnp
            HOT = jnp.ones((2,))           # never imported by the spawn root
        ''',
    }), [SpawnSafetyRule])
    assert found == []


# ---------------------------------------------------------------------------
# actor-runtime
# ---------------------------------------------------------------------------

_ACTOR_FIXTURE = {
    "src/repro/__init__.py": "",
    "src/repro/runtime/__init__.py": "",
    "src/repro/runtime/store_server.py": "",
    "src/repro/runtime/actor.py": '''
        class ActorProcess:
            def run(self):
                pass

        class MinerActor(ActorProcess):
            pass
    ''',
}


def test_actor_runtime_flags_actor_without_process_base():
    found = lint(dict(_ACTOR_FIXTURE, **{
        "src/repro/rogue.py": '''
            class RogueActor:
                def setup(self):
                    pass
        ''',
    }), [ActorRuntimeRule])
    assert len(found) == 1
    assert "RogueActor" in found[0].message
    assert "ActorProcess" in found[0].message


def test_actor_runtime_flags_actor_outside_spawn_closure():
    found = lint(dict(_ACTOR_FIXTURE, **{
        "src/repro/outpost.py": '''
            from repro.runtime.actor import ActorProcess

            class OutpostActor(ActorProcess):
                pass
        ''',
    }), [ActorRuntimeRule])
    assert len(found) == 1
    assert "OutpostActor" in found[0].message
    assert "spawn import closure" in found[0].message
    # the in-closure subclass (MinerActor) produced no finding
    assert found[0].path.endswith("outpost.py")


def test_actor_runtime_flags_unregistered_msg_reference():
    found = lint(dict(_ACTOR_FIXTURE, **{
        "src/repro/api/serde.py": '''
            def _register(cls, tag):
                pass

            class HeartbeatMsg:
                pass

            _register(HeartbeatMsg, 7)
        ''',
        "src/repro/runtime/actor.py": '''
            class ActorProcess:
                def run(self):
                    pass

            class MinerActor(ActorProcess):
                def go(self):
                    return HeartbeatMsg, PhantomMsg
        ''',
    }), [ActorRuntimeRule])
    assert len(found) == 1
    assert "PhantomMsg" in found[0].message


def test_actor_runtime_skips_unknown_bases():
    found = lint(dict(_ACTOR_FIXTURE, **{
        "src/repro/vendored.py": '''
            import thirdparty

            class VendoredActor(thirdparty.Base):
                pass
        ''',
    }), [ActorRuntimeRule])
    assert found == []       # out-of-scope base: cannot judge statically


# ---------------------------------------------------------------------------
# scenario-conformance
# ---------------------------------------------------------------------------


def test_scenario_conformance_flags_missing_fault_seed():
    found = lint({"src/repro/scenarios/custom.py": '''
        from repro.scenarios.base import Scenario, RunEpochs

        def my_experiment():
            return Scenario(name="my-exp", phases=(RunEpochs(2),))
    '''}, [ScenarioConformanceRule])
    assert [f.line for f in found] == [5]
    assert "fault_seed" in found[0].message


def test_scenario_conformance_accepts_pinned_seed():
    found = lint({"src/repro/scenarios/custom.py": '''
        from repro.scenarios.base import Scenario, RunEpochs

        def keyword(seed=7):
            return Scenario(name="a", fault_seed=seed,
                            phases=(RunEpochs(1),))

        def positional():
            return Scenario("b", 11, (RunEpochs(1),))
    '''}, [ScenarioConformanceRule])
    assert found == []


def test_scenario_conformance_flags_key_literals_in_scenarios():
    found = lint({"src/repro/scenarios/custom.py": '''
        WATCH = "control/ep1/t0/loss"
    '''}, [ScenarioConformanceRule])
    assert [f.line for f in found] == [2]
    assert "KeySchema" in found[0].message


def test_scenario_conformance_scoped_to_scenarios_package():
    # the same source outside repro/scenarios/ is out of scope (other
    # rules own those namespaces)
    found = lint({"src/repro/runtime/elsewhere.py": '''
        def build(Scenario):
            return Scenario(name="x", phases=())
    '''}, [ScenarioConformanceRule])
    assert found == []


def test_scenario_conformance_suppression():
    found = lint({"src/repro/scenarios/custom.py": '''
        from repro.scenarios.base import Scenario

        def exempt():
            return Scenario(name="x", phases=())  # swarmlint: disable=scenario-conformance
    '''}, [ScenarioConformanceRule])
    assert found == []


# ---------------------------------------------------------------------------
# the repo gate + CLI contract
# ---------------------------------------------------------------------------


def test_repo_is_lint_clean_at_head():
    """The acceptance gate smoke.sh enforces: zero findings over src/."""
    assert lint_main([os.path.join(REPO, "src")]) == 0


def test_cli_exit_codes_and_flags(capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for cls in ALL_RULES:
        assert cls.name in out
    assert lint_main(["--rule", "no-such-rule", "src"]) == 2


def test_cli_fails_on_reintroduced_key_literal(tmp_path):
    """Re-introducing a key literal flips the exit code to 1 — the
    regression ISSUE 6 gates against."""
    bad = tmp_path / "src" / "repro" / "runtime"
    bad.mkdir(parents=True)
    (bad / "rogue.py").write_text('K = "weights/ep0/s0/m1"\n')
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(tmp_path / "src")],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC))
    assert proc.returncode == 1
    assert "[key-literal]" in proc.stdout


# ---------------------------------------------------------------------------
# schedule-registry
# ---------------------------------------------------------------------------

_PIPELINE_STUB = '''
    SCHEDULES = ("gpipe", "1f1b", "interleaved", "zerobubble")
'''


def test_schedule_registry_flags_unknown_literals():
    found = lint({
        "src/repro/core/pipeline.py": _PIPELINE_STUB,
        "src/repro/launch/rogue.py": '''
            def pick(spec, cfg):
                a = Spec(schedule="zb-h1")
                if spec.schedule == "1f1b ":
                    pass
                cfg.pipeline_schedule = "megatron"
        ''',
    }, [ScheduleRegistryRule])
    assert [f.line for f in found] == [3, 4, 6]
    assert all(f.rule == "schedule-registry" for f in found)


def test_schedule_registry_passes_registry_members_and_mint_module():
    found = lint({
        "src/repro/core/pipeline.py": _PIPELINE_STUB + '''
    def compile_timetable(schedule):
        if schedule == "not-a-schedule-but-allowed-here":
            pass
''',
        "src/repro/api/config.py": '''
            class SwarmConfig:
                pipeline_schedule: str = "gpipe"
            def mint(cfg):
                ok = cfg.pipeline_schedule in ("gpipe", "1f1b")
                return Spec(schedule=cfg.pipeline_schedule,
                            n_stages=4)
        ''',
    }, [ScheduleRegistryRule])
    assert found == []


def test_schedule_registry_inert_without_pipeline_module():
    found = lint({"src/repro/api/other.py": '''
        x = Spec(schedule="whatever")
    '''}, [ScheduleRegistryRule])
    assert found == []


def test_schedule_registry_suppression():
    found = lint({
        "src/repro/core/pipeline.py": _PIPELINE_STUB,
        "src/m.py": '''
            S = Spec(schedule="legacy")  # swarmlint: disable=schedule-registry
        ''',
    }, [ScheduleRegistryRule])
    assert found == []


# ---------------------------------------------------------------------------
# TraceWatch (retrace sanitizer)
# ---------------------------------------------------------------------------


def test_tracewatch_counts_and_asserts():
    import jax
    import jax.numpy as jnp

    from repro.analysis.retrace import RetraceError, TraceWatch

    f = jax.jit(lambda x: x * 2 + 1)
    with TraceWatch() as watch:
        with watch.region("warmup"):
            f(jnp.ones((4,)))
        with watch.region("steady"):
            f(jnp.ones((4,)))
            f(jnp.ones((4,)))
        with watch.region("drift"):
            f(jnp.ones((8,)))            # new shape: retrace
    assert watch.traces("warmup") > 0
    watch.assert_no_trace("steady")
    with pytest.raises(RetraceError, match="drift"):
        watch.assert_no_trace("drift")
    assert set(watch.report()) == {"warmup", "drift"}


def test_tracewatch_unregisters_on_exit():
    import jax
    import jax.numpy as jnp

    from repro.analysis.retrace import TraceWatch

    watch = TraceWatch()
    with watch:
        pass
    jax.jit(lambda x: x - 1)(jnp.ones((3,)))   # traced after exit
    assert watch.report() == {}


@pytest.mark.slow
def test_pipeline_steady_state_is_retrace_free():
    """All four compiled schedules: after one warmup step, further steps
    must hit the jit cache — the invariant behind the 1F1B lockstep fix
    (ISSUE 6), extended to interleaved/zerobubble by ISSUE 9.  The
    interleaved row runs 8 layers so they split into 4 x 2 chunks."""
    out = run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get, smoke_variant
        from repro.core.pipeline import (PipelineSpec, init_pipeline_params,
                                         pipeline_loss_and_grads)
        from repro.analysis.retrace import TraceWatch
        base = smoke_variant(get('llama3.2-1b')).model
        mesh = jax.make_mesh((1, 4), ('data', 'model'))
        B, S, M = 8, 16, 8
        r = np.random.RandomState(0)
        toks = r.randint(0, base.vocab_size, (B, S))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
        for sched, V in [("gpipe", 1), ("1f1b", 1),
                         ("zerobubble", 1), ("interleaved", 2)]:
            cfg = dataclasses.replace(base, n_layers=4 * V)
            spec = PipelineSpec(4, M, compress=True, bottleneck_dim=16,
                                schedule=sched, wire_codec="int8",
                                virtual_stages=V)
            params = init_pipeline_params(jax.random.key(0), cfg, spec)
            step = jax.jit(lambda p, b, c=cfg, s=spec:
                           pipeline_loss_and_grads(p, b, c, s, mesh))
            with mesh, TraceWatch() as watch:
                with watch.region("warmup"):
                    jax.block_until_ready(step(params, batch))
                with watch.region("steady"):
                    for _ in range(3):
                        jax.block_until_ready(step(params, batch))
                watch.assert_no_trace("steady")
            print(f"RES {sched} {watch.traces('steady')}")
    """, devices=4)
    assert out.count("RES") == 4
    for line in out.splitlines():
        if line.startswith("RES"):
            assert line.split()[2] == "0", line


# ---------------------------------------------------------------------------
# CheckedStore (store sanitizer)
# ---------------------------------------------------------------------------


@pytest.fixture
def sanitizer():
    from repro.analysis.checked_store import StoreSanitizer
    with StoreSanitizer() as s:
        yield s


def test_checked_store_rejects_malformed_namespace_key(sanitizer):
    from repro.analysis.checked_store import CheckedStoreError
    from repro.runtime.state_store import StateStore

    store = StateStore()
    with pytest.raises(CheckedStoreError, match="malformed"):
        store.put("weights/bogus", np.zeros(2), actor="m0")
    store.put("scratch/anything-goes", np.zeros(2))   # non-namespace: ok


def test_checked_store_write_after_publish_policy(sanitizer):
    from repro.analysis.checked_store import CheckedStoreError
    from repro.runtime.state_store import StateStore

    store = StateStore()
    store.put("weights/ep0/s0/m1", np.zeros(2), actor="m1")
    store.put("weights/ep0/s0/m1", np.zeros(2), actor="m1")  # idempotent
    with pytest.raises(CheckedStoreError, match="write-after-publish"):
        store.put("weights/ep0/s0/m1", np.ones(2), actor="evil")
    # activations: the fault model re-publishes deliberately — recorded,
    # not fatal (catching it is the validators' job)
    store.put("activations/ep0/t0/s0/m1", np.zeros(2), actor="m1")
    store.put("activations/ep0/t0/s0/m1", np.ones(2), actor="byz")
    assert sanitizer.report().get("write-after-publish") == 1


def test_checked_store_gc_and_reput_is_clean(sanitizer):
    from repro.runtime.state_store import StateStore

    store = StateStore()
    store.put("weights/ep0/s0/m1", np.zeros(2), actor="m1")
    store.delete_prefix("weights/ep0")
    store.put("weights/ep0/s0/m1", np.ones(2), actor="m1")  # fresh epoch
    assert sanitizer.report() == {}


def test_checked_store_records_read_before_write(sanitizer):
    from repro.runtime.state_store import StateStore, StoreKeyError

    store = StateStore()
    with pytest.raises(StoreKeyError):
        store.get("scores/ep9/v0/m0", actor="validator-0")
    rec = sanitizer.records[-1]
    assert (rec.kind, rec.actor) == ("read-before-write", "validator-0")


def test_checked_store_uninstall_restores_originals():
    from repro.analysis.checked_store import StoreSanitizer
    from repro.runtime.state_store import StateStore

    before = (StateStore.put, StateStore.fetch_entry, StateStore.get_entry)
    with StoreSanitizer():
        assert StateStore.put is not before[0]
    assert (StateStore.put, StateStore.fetch_entry,
            StateStore.get_entry) == before
    StateStore().put("weights/not-a-valid-key", np.zeros(1))  # unchecked
