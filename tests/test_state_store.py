"""Store hygiene regressions: prefix boundaries, retention GC, async joins.

Three latent bugs the in-process path never surfaced (found while
building the socket transport, where store hygiene is load-bearing):

  * ``delete_prefix``/``keys`` used raw ``startswith``, so the epoch-GC
    prefix ``activations/ep1`` also deleted ``activations/ep10+`` and the
    audit walk for stage ``s1`` leaked ``s10+`` keys;
  * the weights/ and scores/ planes were never garbage-collected — long
    runs grew the store without bound;
  * ``ValidationPhase`` KeyError'd on a miner registered mid-epoch (no
    epoch-start snapshot to replay from).
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    InProcessTransport,
    KeySchema,
    SharingPhase,
    Swarm,
    SwarmConfig,
    SyncPhase,
    TrainingPhase,
    ValidationPhase,
)
from repro.api.phases import EpochState
from repro.configs import get, smoke_variant
from repro.runtime import StateStore


def _mcfg(n_layers=2):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


# ---------------------------------------------------------------------------
# prefix-boundary regressions (fail on the old raw-startswith behaviour)
# ---------------------------------------------------------------------------

def _epoch_collision_store():
    store = StateStore()
    for e in (1, 10, 11, 100):
        store.put(f"activations/ep{e}/t0/tokens", np.zeros(2))
        store.put(f"activations/ep{e}/t0/s0/m0", np.zeros(2))
    return store


def test_keys_ep1_does_not_match_ep10():
    store = _epoch_collision_store()
    ks = KeySchema()
    got = store.keys(ks.activations_prefix(1))
    assert got == ["activations/ep1/t0/s0/m0", "activations/ep1/t0/tokens"]


def test_delete_prefix_ep1_leaves_ep10_alone():
    store = _epoch_collision_store()
    ks = KeySchema()
    assert store.delete_prefix(ks.activations_prefix(1)) == 2
    surviving = store.keys()
    assert len(surviving) == 6
    assert all(k.split("/")[1] in ("ep10", "ep11", "ep100")
               for k in surviving)


def test_stage_prefix_s1_does_not_match_s10():
    store = StateStore()
    ks = KeySchema(version=2)
    for s in (1, 10, 12):
        store.put(ks.shard_upload(0, s, 0, 0), np.zeros(2))
        store.put(ks.shard_reduced(0, s, 0, 1), np.zeros(2))
    got = store.keys(ks.stage_weights_prefix(0, 1))
    assert got == ["weights/ep0/s1/m0/shard0",
                   "weights/ep0/s1/shard0/reduced/m1"]
    assert store.delete_prefix(ks.stage_weights_prefix(0, 1)) == 2
    assert len(store.keys("weights/ep0")) == 4


def test_exact_key_and_trailing_slash_and_empty_prefix():
    store = StateStore()
    store.put("weights/ep1/s0/m1", np.zeros(2))
    store.put("weights/ep1/s0/m10", np.zeros(2))
    # an exact key is its own segment boundary
    assert store.keys("weights/ep1/s0/m1") == ["weights/ep1/s0/m1"]
    # trailing slash keeps its literal meaning (seed-era callers)
    assert len(store.keys("weights/")) == 2
    # empty prefix covers everything
    assert len(store.keys("")) == 2
    assert store.delete_prefix("") == 2


def test_in_process_transport_inherits_boundary_semantics():
    tp = InProcessTransport()
    tp.put("scores/ep2/v0/m1", np.zeros(1))
    tp.put("scores/ep20/v0/m1", np.zeros(1))
    assert tp.keys("scores/ep2") == ["scores/ep2/v0/m1"]
    assert tp.delete_prefix("scores/ep2") == 1
    assert tp.exists("scores/ep20/v0/m1")


# ---------------------------------------------------------------------------
# retention-window GC (weights/ + scores/ planes)
# ---------------------------------------------------------------------------

def _epochs_present(tp, namespace):
    return sorted({int(k.split("/")[1][2:]) for k in tp.keys(namespace)})


def _gc_cfg(**kw):
    # inner_steps=6 so every miner clears b_min each epoch: the weight
    # plane gets artifacts every epoch, which is what the GC must prune
    return SwarmConfig(seed=0, n_stages=2, miners_per_stage=2, inner_steps=6,
                       b_min=1, batch_size=2, seq_len=16, validators=1, **kw)


def test_default_keeps_every_epoch_for_replay():
    swarm = Swarm.create(_mcfg(), _gc_cfg())
    swarm.run(3)
    assert _epochs_present(swarm.transport, "weights/") == [0, 1, 2]
    assert _epochs_present(swarm.transport, "scores/") == [0, 1, 2]
    # activations are still GC'd per epoch, as always
    assert swarm.transport.keys("activations/") == []


def test_retention_window_bounds_the_store():
    swarm = Swarm.create(_mcfg(), _gc_cfg(retain_epochs=2))
    swarm.run(5)
    assert _epochs_present(swarm.transport, "weights/") == [3, 4]
    assert _epochs_present(swarm.transport, "scores/") == [3, 4]


def test_retention_window_one_keeps_only_current_epoch():
    swarm = Swarm.create(_mcfg(), _gc_cfg(retain_epochs=1))
    swarm.run(3)
    assert _epochs_present(swarm.transport, "weights/") == [2]
    assert _epochs_present(swarm.transport, "scores/") == [2]


def test_retained_trajectory_unchanged():
    """GC only removes *finished* epochs' artifacts: the loss trajectory
    is identical with and without a retention window."""
    keep = Swarm.create(_mcfg(), _gc_cfg()).run(3)
    gc = Swarm.create(_mcfg(), _gc_cfg(retain_epochs=1)).run(3)
    assert [s.mean_loss for s in gc] == [s.mean_loss for s in keep]


def test_retention_window_validated():
    with pytest.raises(AssertionError):
        _gc_cfg(retain_epochs=0)


# ---------------------------------------------------------------------------
# async join mid-epoch (ROADMAP scenario: blocked on a ValidationPhase bug)
# ---------------------------------------------------------------------------

def test_validation_skips_snapshotless_mid_epoch_joiner():
    """Old behaviour: ``state.snapshots[uid]`` KeyError'd the moment a
    validator's random draw picked a miner registered after epoch start."""
    swarm = Swarm.create(
        _mcfg(), SwarmConfig(seed=0, n_stages=2, miners_per_stage=1,
                             inner_steps=2, b_min=1, batch_size=2,
                             seq_len=16, validators=8),
        phases=[])
    state = EpochState(epoch=0, snapshots={u: m.snapshot()
                                           for u, m in swarm.miners.items()})
    TrainingPhase().run(swarm, state)
    joiner = swarm.register_miner(stage=0)          # mid-epoch join
    ValidationPhase().run(swarm, state)             # must not raise
    assert len(state.validation) == 8
    assert all(r.miner_uid != joiner.uid for r in state.validation)


def test_validation_no_op_when_nobody_has_a_snapshot():
    swarm = Swarm.create(
        _mcfg(), SwarmConfig(seed=0, n_stages=1, miners_per_stage=1,
                             inner_steps=1, b_min=1, batch_size=2,
                             seq_len=16, validators=2),
        phases=[])
    state = EpochState(epoch=0, snapshots={})
    ValidationPhase().run(swarm, state)
    assert state.validation == []


class _JoinPhase:
    """Scenario phase: one miner joins between training and validation."""
    name = "join"

    def __init__(self, stage: int, at_epoch: int = 0):
        self.stage = stage
        self.at_epoch = at_epoch
        self.joined: list[int] = []

    def run(self, swarm, state):
        if state.epoch == self.at_epoch:
            self.joined.append(swarm.register_miner(stage=self.stage).uid)


def test_async_join_scenario_full_timeline():
    """ROADMAP async-join scenario: a custom phase list, no core edits.
    The joiner is skipped by validators in its join epoch, receives the
    anchor at the next full sync, and is trackable from the next epoch."""
    join = _JoinPhase(stage=0)
    swarm = Swarm.create(
        _mcfg(), SwarmConfig(seed=0, n_stages=2, miners_per_stage=2,
                             inner_steps=4, b_min=1, batch_size=2,
                             seq_len=16, validators=6),
        phases=[TrainingPhase(), join, ValidationPhase(), SharingPhase(),
                SyncPhase()])
    stats = swarm.run(2)
    (uid,) = join.joined
    assert uid in swarm.miners
    # epoch 0: every verdict targets a snapshotted miner, never the joiner
    assert all(r.miner_uid != uid for r in stats[0].validation)
    assert len(stats[0].validation) == 6
    # epoch 1: the joiner has an epoch-start snapshot and is now eligible
    # (and with 6 validators over 5 miners, seed 0 does track it)
    assert any(r.miner_uid == uid for r in stats[1].validation)
    assert np.isfinite(stats[-1].mean_loss)
    # it participated in training after its first full sync
    assert swarm.miners[uid].batches_done > 0
