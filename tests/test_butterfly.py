"""Butterfly All-Reduce (paper §5): plan structure, reduce correctness,

fault math, agreement matrix, O(1) bandwidth."""
import itertools

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import butterfly


def test_plan_covers_every_pair_once():
    plan = butterfly.make_plan(6, 1000, seed=1)
    assert plan.n_shards == 15          # C(6,2)
    assert sorted(map(tuple, map(sorted, plan.pairs))) == sorted(
        itertools.combinations(range(6), 2))


def test_plan_shards_partition_vector():
    plan = butterfly.make_plan(5, 997, seed=2)   # prime length: uneven shards
    covered = []
    for s in range(plan.n_shards):
        lo, hi = plan.shard_bounds(s)
        covered.extend(range(lo, hi))
    assert covered == list(range(997))


def test_each_miner_reduces_one_shard_per_partner():
    plan = butterfly.make_plan(7, 1000, seed=3)
    for m in range(7):
        assert len(plan.shards_of(m)) == 6      # N-1


def test_reduce_equals_mean():
    plan = butterfly.make_plan(4, 500, seed=0)
    uploads = {m: np.random.RandomState(m).randn(500).astype(np.float32)
               for m in range(4)}
    merged, valid, agree = butterfly.reduce_shards(plan, uploads)
    np.testing.assert_allclose(
        merged, np.mean([uploads[m] for m in range(4)], axis=0), atol=1e-5)
    assert valid.all() and agree.all()


def test_missing_upload_masked_not_fatal():
    plan = butterfly.make_plan(5, 300, seed=0)
    uploads = {m: np.full(300, float(m), np.float32) for m in range(5)}
    del uploads[2]                               # miner 2 never uploaded
    merged, valid, _ = butterfly.reduce_shards(plan, uploads)
    np.testing.assert_allclose(merged, np.full(300, (0 + 1 + 3 + 4) / 4.0),
                               atol=1e-5)
    assert valid.all()                           # reducers still alive


def test_both_reducers_down_loses_only_their_shard():
    plan = butterfly.make_plan(5, 1000, seed=0)
    uploads = {m: np.ones(1000, np.float32) for m in range(5)}
    reducer_ok = [True] * 5
    reducer_ok[1] = reducer_ok[3] = False        # pair (1,3) both dead
    merged, valid, _ = butterfly.reduce_shards(plan, uploads, reducer_ok)
    dead_shards = [s for s, p in enumerate(plan.pairs)
                   if set(p) <= {1, 3}]
    assert len(dead_shards) == 1
    assert not valid[dead_shards[0]]
    # C(5,2) - C(2,2) = 9 of 10 shards valid
    assert valid.sum() == 9


@given(n=st.integers(2, 40), k_frac=st.floats(0, 1))
@settings(max_examples=50, deadline=None)
def test_valid_fraction_formula_matches_combinatorics(n, k_frac):
    k = int(n * k_frac)
    expected = 1.0 if n < 2 else (
        (n * (n - 1) // 2 - k * (k - 1) // 2) / (n * (n - 1) // 2))
    assert butterfly.valid_shard_fraction(n, k) == pytest.approx(expected)


def test_paper_fig7b_claims():
    """Paper: at 10% failures >99% weights retained; tolerant to 35%."""
    assert butterfly.valid_shard_fraction(50, 5) > 0.99
    assert butterfly.valid_shard_fraction(50, 17) > 0.88   # ~35% failures


def test_agreement_matrix_exposes_tamperer():
    plan = butterfly.make_plan(6, 600, seed=0)
    uploads = {m: np.random.RandomState(m).randn(600).astype(np.float32)
               for m in range(6)}
    copies = butterfly.reduce_with_copies(plan, uploads, tamper={2: 0.5})
    agree = butterfly.agreement_matrix(plan, copies)
    off_diag = ~np.eye(6, dtype=bool)
    # miner 2 disagrees with every partner; the rest agree fully
    assert np.nanmin(agree[2][np.arange(6) != 2]) == 0.0
    honest = [i for i in range(6) if i != 2]
    assert np.nanmin(agree[np.ix_(honest, honest)][
        ~np.eye(5, dtype=bool)]) == 1.0


@given(n=st.integers(2, 64))
@settings(max_examples=30, deadline=None)
def test_transfer_volume_o1(n):
    """Per-miner traffic is 4W + 2W/N — bounded by 5W for any N (O(1))."""
    vol = butterfly.transfer_volume(n, 1.0)
    assert vol["per_miner_bytes"] <= 5.0
    assert vol["per_miner_bytes"] == pytest.approx(4 + 2 / n)
    # the central merger's ingest grows linearly — crossover proves O(1) wins
    if n > 5:
        assert vol["per_miner_bytes"] < vol["central_merger_bytes"]
