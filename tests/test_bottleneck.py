"""Bottleneck compression blocks (paper §4): ratios, residual flow, wire."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, smoke_variant
from repro.configs.base import BottleneckConfig
from repro.core import bottleneck as bn
from repro.models import build_model, transformer


def test_paper_headline_128x():
    """2048-d fp32 basis, 32-d bf16 wire -> the paper's 128x."""
    cfg = get("iota-bottleneck-1.5b").model
    rep = bn.compression_report(cfg)
    assert rep["ratio_vs_fp32"] == pytest.approx(128.0)
    assert rep["ratio_vs_bf16"] == pytest.approx(64.0)
    assert rep["wire_bytes_per_token"] == 64


def test_boundary_positions_spacing():
    assert bn.boundary_positions(16, 3) == [3, 8, 12]
    assert bn.boundary_positions(16, 0) == []
    # the paper's extreme case: 8 bottlenecks in 16 layers = 50% replaced
    pos = bn.boundary_positions(16, 8)
    assert pos == [0, 2, 4, 6, 8, 10, 12, 14]
    assert all(b - a >= 2 for a, b in zip(pos, pos[1:]))


def test_wire_capture_is_bottleneck_width():
    cfg = smoke_variant(get("iota-bottleneck-1.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = model.synth_batch(jax.random.key(1), 2, 16)
    wires = []
    lgts, _, _ = model.forward(params, batch, None, capture_wire=wires)
    assert len(wires) == cfg.model.bottleneck.n_bottlenecks
    for z in wires:
        assert z.shape == (2, 16, cfg.model.bottleneck.bottleneck_dim)
        assert z.dtype == jnp.bfloat16


def test_gradients_flow_through_boundary():
    """The stated §4 property: residual pathway crosses the boundary through

    z, so upstream blocks still receive gradients."""
    cfg = smoke_variant(get("iota-bottleneck-1.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = model.synth_batch(jax.random.key(1), 2, 16)

    def loss(p):
        return model.loss_fn(p, batch, None)[0]

    grads = jax.grad(loss)(params)
    # first-segment block weights (upstream of every boundary) get signal
    g0 = grads["seg0"]["period"]["b0"]["attn"]["wq"]
    assert float(jnp.max(jnp.abs(g0))) > 0
    # and the boundary projections themselves train
    gb = grads["bnd0"]["boundary"]["w_down"]
    assert float(jnp.max(jnp.abs(gb))) > 0


def test_insert_mode_for_ssm():
    cfg = smoke_variant(get("xlstm-125m"))
    mcfg = dataclasses.replace(
        cfg.model, bottleneck=BottleneckConfig(n_bottlenecks=1,
                                               bottleneck_dim=8))
    layout = transformer.plan_layout(mcfg)
    assert layout.mode == "insert"
    cfg2 = dataclasses.replace(cfg, model=mcfg)
    model = build_model(cfg2)
    params = model.init(jax.random.key(0))
    batch = model.synth_batch(jax.random.key(1), 2, 16)
    lgts, _, _ = model.forward(params, batch, None)
    assert bool(jnp.all(jnp.isfinite(lgts)))


def test_replace_mode_block_count():
    cfg = get("iota-bottleneck-1.5b").model
    layout = transformer.plan_layout(cfg)
    assert layout.mode == "replace"
    assert layout.total_blocks() == cfg.n_layers


@pytest.mark.parametrize("n_b,dim,expected", [(3, 32, 128), (3, 128, 32),
                                              (8, 32, 128)])
def test_compression_ratio_table(n_b, dim, expected):
    """Fig 5's sweep: ratios are vs fp32 full width."""
    cfg = dataclasses.replace(
        get("iota-bottleneck-1.5b").model,
        bottleneck=BottleneckConfig(n_bottlenecks=n_b, bottleneck_dim=dim))
    assert cfg.bottleneck.compression_ratio(cfg.d_model) == pytest.approx(
        expected)
