"""Chaos suite: deterministic fault injection + crash-resume + failover.

Covers the docs/CHAOS.md surface without spawning fleets where possible:
``ChaosTransport``/``FaultSchedule`` determinism and fault semantics,
``DiskSnapshotCache`` corruption fallback (``SnapshotCorrupt``), the GC
retention pin, ``revise_plan`` graceful degradation (fixed merge
layout), butterfly reducer failover + tamper attribution, warm-standby
store mirroring with client failover, the ``WorkQueue`` chaos paths
(dead-swarm escalation, wakeup across a store failover, pipelined
replay through connection resets), supervisor progress for a stalled
child, and the scenario catalog contract.  One slow-marked test runs
the kill-and-resume scenario on a real spawned fleet and pins its loss
against the dense lockstep oracle.
"""
import dataclasses
import socket
import threading
import time

import numpy as np
import pytest

from repro.api import KeySchema, SocketTransport, Swarm, SwarmConfig
from repro.api.messages import HeartbeatMsg
from repro.api.phases import EpochDriver, revise_plan
from repro.api.transport import InProcessTransport, Transport
from repro.checkpoint import SnapshotCorrupt
from repro.configs import get, smoke_variant
from repro.core import butterfly
from repro.runtime.actor import ActorDied, ActorSupervisor, WorkQueue
from repro.runtime.chaos import ChaosTransport, FaultSchedule, wrap_transport
from repro.runtime.snapshot_cache import DiskSnapshotCache
from repro.runtime.store_server import StoreServer
from repro.scenarios import (
    SCENARIOS,
    KillMiner,
    RespawnMiner,
    RunEpochs,
    ScenarioPhase,
    kill_n_miners,
    run_scenario,
    slow_link,
    store_failover,
)

V4 = KeySchema(version=4)


def _mcfg(n_layers=1):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


# ---------------------------------------------------------------------------
# FaultSchedule + ChaosTransport: determinism and fault semantics
# ---------------------------------------------------------------------------

def test_fault_schedule_validates_probabilities():
    with pytest.raises(AssertionError):
        FaultSchedule(seed=1, drop_get=1.5)


def test_wrap_transport_is_identity_without_a_schedule():
    inner = InProcessTransport(schema=V4)
    assert wrap_transport(inner, None) is inner
    wrapped = wrap_transport(inner, FaultSchedule(seed=3))
    assert isinstance(wrapped, ChaosTransport)
    assert wrapped.inner is inner


def test_chaos_transport_satisfies_transport_protocol():
    t = ChaosTransport(InProcessTransport(schema=V4), FaultSchedule(seed=1))
    assert isinstance(t, Transport)


def _drive(tag: str) -> dict:
    t = ChaosTransport(
        InProcessTransport(schema=V4),
        FaultSchedule(seed=404, drop_get=0.3, latency_prob=0.4,
                      latency_s=0.0, drop_put=0.5),
        actor_tag=tag)
    arr = np.arange(8, dtype=np.float32)
    for i in range(20):
        t.put(V4.shard_reduced(0, 0, i, 0), arr, actor="m0")
        t.put(V4.weight_upload(0, 0, i), arr, actor="m0")
        t.get(V4.weight_upload(0, 0, i), actor="m0")
        t.exists(V4.weight_upload(0, 0, i))
    return t.chaos_report()


def test_same_seed_same_workload_same_fault_sequence():
    a, b = _drive("miner0"), _drive("miner0")
    assert a == b
    assert a["ops"] == 80
    # the schedule actually fired (the workload isn't trivially fault-free)
    assert a["retried_gets"] > 0 and a["delays"] > 0
    assert a["dropped_puts"] > 0


def test_dropped_puts_are_restricted_to_redundant_planes():
    t = ChaosTransport(InProcessTransport(schema=V4),
                       FaultSchedule(seed=1, drop_put=1.0))
    arr = np.ones(4, np.float32)
    digest = t.put(V4.shard_reduced(0, 0, 0, 0), arr, actor="m0")
    assert isinstance(digest, str) and digest    # fire-and-forget contract
    assert not t.inner.exists(V4.shard_reduced(0, 0, 0, 0))
    t.put(V4.weight_upload(0, 0, 0), arr, actor="m0")
    assert t.inner.exists(V4.weight_upload(0, 0, 0))    # not an eligible kind
    assert t.chaos_report()["dropped_puts"] == 1


def test_corrupted_puts_perturb_eligible_payloads_only():
    t = ChaosTransport(
        InProcessTransport(schema=V4),
        FaultSchedule(seed=1, corrupt_put=1.0, corrupt_scale=0.25))
    arr = np.ones(4, np.float32)
    t.put(V4.shard_reduced(0, 0, 0, 0), arr, actor="m0")
    t.put(V4.weight_upload(0, 0, 0), arr, actor="m0")
    bent = t.inner.get(V4.shard_reduced(0, 0, 0, 0))
    np.testing.assert_array_equal(np.asarray(bent), arr + np.float32(0.25))
    clean = t.inner.get(V4.weight_upload(0, 0, 0))
    np.testing.assert_array_equal(np.asarray(clean), arr)
    assert t.chaos_report()["corrupted_puts"] == 1


def test_dropped_gets_are_retried_not_surfaced():
    t = ChaosTransport(InProcessTransport(schema=V4),
                       FaultSchedule(seed=1, drop_get=1.0))
    arr = np.arange(4, dtype=np.float32)
    t.inner.put(V4.weight_upload(0, 0, 0), arr, actor="m0")
    out = t.get(V4.weight_upload(0, 0, 0), actor="m0")
    np.testing.assert_array_equal(np.asarray(out), arr)
    assert t.chaos_report()["retried_gets"] == 1


def test_partition_is_a_bounded_visibility_blackout():
    t = ChaosTransport(InProcessTransport(schema=V4),
                       FaultSchedule(seed=2, partition_every=5,
                                     partition_ops=3))
    key = V4.weight_upload(0, 0, 0)
    t.inner.put(key, np.ones(2, np.float32), actor="m0")
    seen = [t.exists(key) for _ in range(9)]
    # ops 1-4 visible; op 5 opens a 3-op blackout (ops 5-8); op 9 heals
    assert seen == [True] * 4 + [False] * 4 + [True]
    assert t.chaos_report()["partitions"] == 1


def test_wait_for_emulation_over_inprocess_inner():
    t = ChaosTransport(InProcessTransport(schema=V4), FaultSchedule(seed=3))
    key = V4.weight_upload(0, 0, 0)
    assert not t.wait_for(key, timeout=0.05)
    t.inner.put(key, np.ones(2, np.float32), actor="m0")
    assert t.wait_for(key, timeout=0.05)


# ---------------------------------------------------------------------------
# DiskSnapshotCache: corruption fallback + rolling retention
# ---------------------------------------------------------------------------

def _tree(val: float):
    return {"w": np.full((4, 3), val, np.float32),
            "step": np.asarray(7, np.int32)}


def test_bit_flip_quarantines_and_falls_back(tmp_path):
    cache = DiskSnapshotCache(str(tmp_path), keep=3)
    cache.save(0, _tree(1.0))
    cache.save(1, _tree(2.0))
    leaf = next(p for p in sorted((tmp_path / "ep_00000001").iterdir())
                if p.suffix == ".npy")
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0x01
    leaf.write_bytes(bytes(raw))

    with pytest.raises(SnapshotCorrupt):
        cache.restore(_tree(0.0), 1)

    got = cache.restore_latest(_tree(0.0))
    assert got is not None
    epoch, tree, meta = got
    assert epoch == 0 and meta["epoch"] == 0
    np.testing.assert_array_equal(tree["w"], np.full((4, 3), 1.0, np.float32))
    # the bad epoch is quarantined for inspection, never retried
    assert (tmp_path / "ep_00000001.corrupt").exists()
    assert cache.epochs() == [0]


def test_cache_keeps_a_bounded_rolling_window(tmp_path):
    cache = DiskSnapshotCache(str(tmp_path), keep=2)
    for e in range(4):
        cache.save(e, _tree(float(e)))
    assert cache.epochs() == [2, 3]
    assert cache.latest_epoch() == 3


def test_cache_requires_a_corruption_spare(tmp_path):
    with pytest.raises(AssertionError):
        DiskSnapshotCache(str(tmp_path), keep=1)


def test_empty_cache_restores_none(tmp_path):
    assert DiskSnapshotCache(str(tmp_path)).restore_latest(_tree(0.0)) is None


# ---------------------------------------------------------------------------
# GC retention pin: crash-resume replay keys survive a small window
# ---------------------------------------------------------------------------

def _epochs_present(tp, namespace):
    return sorted({int(k.split("/")[1][2:]) for k in tp.keys(namespace)})


def _gc_cfg(**kw):
    return SwarmConfig(seed=0, n_stages=2, miners_per_stage=2, inner_steps=6,
                       b_min=1, batch_size=2, seq_len=16, validators=1, **kw)


def test_retention_pin_semantics_take_the_minimum():
    driver = EpochDriver()
    driver.pin_retention("miner0", 5)
    driver.pin_retention("miner0", 3)     # a pin only ever moves down
    driver.pin_retention("miner0", 7)
    driver.pin_retention("miner2", 6)
    assert driver._pin_floor() == 3
    driver.release_retention("miner0")
    assert driver._pin_floor() == 6
    driver.release_retention("miner2")
    assert driver._pin_floor() is None


def test_retention_pin_holds_gc_floor_until_released():
    swarm = Swarm.create(_mcfg(2), _gc_cfg(retain_epochs=1))
    # a respawning miner pinned at epoch 0: its replay keys must survive
    # even though the window alone would keep only the newest epoch
    swarm.driver.pin_retention("miner0", 0)
    swarm.run(3)
    assert _epochs_present(swarm.transport, "weights/") == [0, 1, 2]
    swarm.driver.release_retention("miner0")
    swarm.run(1)
    assert _epochs_present(swarm.transport, "weights/") == [3]


# ---------------------------------------------------------------------------
# revise_plan: graceful degradation is pure and layout-preserving
# ---------------------------------------------------------------------------

def _plan():
    return {
        "stage_of": {0: 0, 1: 0, 2: 1, 3: 1},
        "ticks": ((0, (0, 2)), (1, (1, 3)), (2, (0, 3)), (3, (1, 2))),
        "qualified": {0: (0, 1), 1: (2, 3)},
    }


def test_revise_plan_substitutes_a_survivor_for_pending_ticks():
    rev, n, orphaned, dropped = revise_plan(
        _plan(), done_ticks={0}, dead_uid=0, survivor=1,
        gradient_missing=lambda t, uids: False)
    assert n == 1 and not orphaned and not dropped
    assert rev["ticks"] == ((0, (0, 2)), (1, (1, 3)), (2, (1, 3)),
                            (3, (1, 2)))
    assert rev["dead"] == (0,)


def test_revise_plan_never_rewrites_the_merge_layout():
    plan = _plan()
    rev, _, _, _ = revise_plan(plan, done_ticks=set(), dead_uid=0,
                               survivor=1,
                               gradient_missing=lambda t, uids: False)
    # fixed at plan time: actors may already be mid-reduce against it
    assert rev["qualified"] == plan["qualified"]
    assert rev["qualified"][0] == (0, 1)


def test_revise_plan_drops_ticks_without_a_survivor():
    rev, n, orphaned, dropped = revise_plan(
        _plan(), done_ticks=set(), dead_uid=2, survivor=None,
        gradient_missing=lambda t, uids: False)
    assert n == 0 and dropped == [0, 3]
    assert rev["dropped"] == (0, 3)
    assert all(t not in (0, 3) for t, _ in rev["ticks"])


def test_revise_plan_orphans_done_ticks_with_a_broken_backward():
    rev, n, orphaned, dropped = revise_plan(
        _plan(), done_ticks={0}, dead_uid=2, survivor=3,
        gradient_missing=lambda t, uids: t == 0)
    assert orphaned == [0] and rev["orphaned"] == (0,)
    assert n == 1       # tick 3 pending -> survivor 3
    assert rev["ticks"][3] == (3, (1, 3))


def test_revise_plan_accrues_the_dead_census():
    plan = dict(_plan(), dead=(5,), orphaned=(9,))
    rev, _, _, _ = revise_plan(plan, done_ticks=set(), dead_uid=1,
                               survivor=0,
                               gradient_missing=lambda t, uids: False)
    assert rev["dead"] == (1, 5)
    assert rev["orphaned"] == (9,)


# ---------------------------------------------------------------------------
# butterfly reducer failover: the surviving redundant copy is bit-exact
# ---------------------------------------------------------------------------

def _reduced_swarm(tamper_idx=None, tamper=0.5):
    tp = InProcessTransport(schema=V4)
    plan = butterfly.make_plan(4, 64, seed=0)
    rng = np.random.RandomState(7)
    vecs = rng.randn(4, 64).astype(np.float32)
    ex = butterfly.ButterflyExecutor(plan, tp, epoch=0, stage=0,
                                     uids=[10, 11, 12, 13], codec="none")
    for i in range(4):
        ex.upload_vector(i, vecs[i], actor=f"m{i}")
    for i in range(4):
        ex.run_reducer(i, actor=f"m{i}",
                       tamper=tamper if i == tamper_idx else 0.0)
    return tp, ex, vecs.mean(axis=0)


def test_losing_one_reducer_is_bit_invisible():
    tp, ex, oracle = _reduced_swarm()
    full, valid, _ = ex.collect()
    assert valid.all()
    np.testing.assert_allclose(full, oracle, rtol=1e-6)
    # kill reducer idx 1 after the reduce: delete every copy it uploaded
    for a in ex.assignments_for(1):
        assert tp.delete_prefix(a.reduced_key) == 1
    failed_over, valid, copies = ex.collect()
    assert valid.all()                       # every shard has a partner copy
    np.testing.assert_array_equal(failed_over, full)     # bit-exact failover
    assert all(idx != 1 for (_, idx) in copies)


def test_both_assignees_down_loses_the_shard():
    tp, ex, _ = _reduced_swarm()
    shard = ex.assignments_for(0)[0].shard
    i, j = ex.plan.pairs[shard]
    for idx in (i, j):
        tp.delete_prefix(ex.reduced_key(shard, idx))
    _, valid, _ = ex.collect()
    assert not valid[shard]
    assert valid.sum() == ex.plan.n_shards - 1


def test_failover_under_tamper_still_attributes_the_tamperer():
    tp, ex, oracle = _reduced_swarm(tamper_idx=1)
    merged, valid, _ = ex.collect()
    assert valid.all()
    # consensus weighting prefers the honest partner's copies
    np.testing.assert_allclose(merged, oracle, rtol=1e-6)
    agree = ex.last_agreement
    others = np.arange(4) != 1
    assert np.nanmean(agree[1][others]) == 0.0   # out of consensus everywhere
    for m in (0, 2, 3):
        row = agree[m][(np.arange(4) != m) & (np.arange(4) != 1)]
        assert np.all(row[~np.isnan(row)] == 1.0)


# ---------------------------------------------------------------------------
# warm-standby store + client failover
# ---------------------------------------------------------------------------

@pytest.fixture()
def mirrored():
    primary, standby = StoreServer(), StoreServer()
    primary.start()
    standby.start()
    primary.mirror_to(standby.address)
    yield primary, standby
    primary.stop()
    standby.stop()


def test_mirrored_standby_sees_primary_mutations(mirrored):
    primary, standby = mirrored
    with SocketTransport(primary.address, schema=V4) as t:
        t.put(V4.weight_upload(0, 0, 0), np.ones(4, np.float32), actor="m0")
        t.delete_prefix(V4.weights_prefix(9))
    with SocketTransport(standby.address, schema=V4) as t:
        assert t.exists(V4.weight_upload(0, 0, 0))
        np.testing.assert_array_equal(
            np.asarray(t.get(V4.weight_upload(0, 0, 0))),
            np.ones(4, np.float32))


def test_client_fails_over_to_the_standby(mirrored):
    primary, standby = mirrored
    key = V4.weight_upload(0, 0, 0)
    with SocketTransport(primary.address, failover=(standby.address,),
                         schema=V4) as t:
        t.put(key, np.arange(4, dtype=np.float32), actor="m0")
        primary.stop()
        # the next roundtrip dials the standby (sticky promotion) and
        # finds the mirrored key there
        assert t.exists(key)
        np.testing.assert_array_equal(np.asarray(t.get(key)),
                                      np.arange(4, dtype=np.float32))
        t.put(V4.weight_upload(0, 0, 1), np.ones(2, np.float32), actor="m0")
        assert t.exists(V4.weight_upload(0, 0, 1))


# ---------------------------------------------------------------------------
# WorkQueue chaos paths (satellite: dead swarm, failover wakeup, replay)
# ---------------------------------------------------------------------------

def test_dead_swarm_escalates_actor_died_not_timeout():
    def liveness():
        raise ActorDied("miner3", -9)

    q = WorkQueue(InProcessTransport(schema=V4), timeout=5.0,
                  liveness=liveness, liveness_every=1)
    t0 = time.monotonic()
    with pytest.raises(ActorDied):
        q.await_key(V4.weight_upload(0, 0, 0))
    assert time.monotonic() - t0 < 1.0       # escalated, not waited out


def test_wait_for_waiter_wakes_across_store_failover(mirrored):
    primary, standby = mirrored
    key = V4.weight_upload(1, 0, 0)
    got = {}
    with SocketTransport(primary.address, failover=(standby.address,),
                         schema=V4) as t:
        q = WorkQueue(t, timeout=30.0)

        def waiter():
            got["value"] = np.asarray(q.get(key, actor="m0"))

        th = threading.Thread(target=waiter, daemon=True)
        th.start()
        time.sleep(0.3)                      # park server-side on the primary
        primary.stop()
        with SocketTransport(standby.address, schema=V4) as other:
            other.put(key, np.full(3, 5.0, np.float32), actor="m1")
        th.join(timeout=20.0)
        assert not th.is_alive()
    np.testing.assert_array_equal(got["value"], np.full(3, 5.0, np.float32))


def test_pending_parallel_batch_replays_through_resets():
    server = StoreServer()
    server.start()
    try:
        inner = SocketTransport(server.address, schema=V4)
        t = ChaosTransport(inner, FaultSchedule(seed=11, reset_every=3))
        arrs = {i: np.full(8, float(i), np.float32) for i in range(10)}
        with t.parallel():
            for i, arr in arrs.items():
                t.put(V4.weight_upload(0, 0, i), arr, actor="m0")
        # every pipelined put survived the severed sockets via
        # reconnect-and-replay (SocketTransport._io)
        for i, arr in arrs.items():
            np.testing.assert_array_equal(
                np.asarray(t.get(V4.weight_upload(0, 0, i), actor="m0")),
                arr)
        assert t.chaos_report()["resets"] >= 3
        t.close()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# supervisor progress: a stalled child keeps its last heartbeat
# ---------------------------------------------------------------------------

class _FakeProc:
    def __init__(self, alive):
        self._alive = alive
        self.exitcode = None if alive else -9

    def is_alive(self):
        return self._alive


def _dead_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_progress_keeps_last_heartbeat_of_stalled_child():
    sup = ActorSupervisor()
    sup.procs["miner0"] = _FakeProc(alive=True)
    sup.health["miner0"] = ("127.0.0.1", _dead_port())   # endpoint wedged
    sup.last_seen["miner0"] = HeartbeatMsg(
        "miner0", epoch=4, items_done=7, state="awaiting")
    out = sup.progress()
    assert out["miner0"].epoch == 4
    assert out["miner0"].items_done == 7
    assert out["miner0"].state == "awaiting"


def test_check_carries_the_casualtys_last_heartbeat():
    sup = ActorSupervisor()
    sup.procs["miner1"] = _FakeProc(alive=False)
    sup.last_seen["miner1"] = HeartbeatMsg(
        "miner1", epoch=2, items_done=5, state="train")
    with pytest.raises(ActorDied) as ei:
        sup.check()
    assert ei.value.actor == "miner1"
    assert "epoch=2" in str(ei.value) and "state='train'" in str(ei.value)


# ---------------------------------------------------------------------------
# scenario catalog contract
# ---------------------------------------------------------------------------

def test_catalog_scenarios_declare_seeds_and_phases():
    for name, build in SCENARIOS.items():
        sc = build()
        assert isinstance(sc.fault_seed, int)
        assert sc.phases and all(isinstance(p, ScenarioPhase)
                                 for p in sc.phases)
        assert sc.config is not None


def test_catalog_knobs_are_wired_to_the_seed():
    assert kill_n_miners(2).name == "kill-2-miners"
    assert store_failover().store_standby is True
    link = slow_link()
    assert link.schedule is not None
    assert link.schedule.seed == link.fault_seed


# ---------------------------------------------------------------------------
# end to end: kill-and-resume tracks the dense lockstep oracle
# ---------------------------------------------------------------------------

class _SnoopResume:
    """Scenario phase that records the respawned miner's crash-resume
    heartbeat (``resumed_from``) straight off the control plane."""
    name = "snoop-resume"

    def __init__(self, uid=0):
        self.uid = uid
        self.resumed_from = None

    def run(self, swarm, result):
        key = swarm.transport.schema.heartbeat(f"miner{self.uid}")
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if swarm.transport.exists(key):
                hb = swarm.transport.get(key, actor="test")
                self.resumed_from = hb.get("resumed_from")
                return
            time.sleep(0.05)


@pytest.mark.slow
def test_kill_and_resume_tracks_dense_oracle(tmp_path):
    base = kill_n_miners(1)
    snoop = _SnoopResume(uid=0)
    sc = dataclasses.replace(base, phases=(
        RunEpochs(1),
        KillMiner(uid=0, at_epoch=1, after_tick=1),
        RunEpochs(1),
        RespawnMiner(uid=0),
        snoop,
        RunEpochs(2),
    ))
    res = run_scenario(sc, _mcfg(2), snapshot_root=str(tmp_path))
    assert res.converged
    killed = res.kills == 1
    assert killed or any("missed" in n for n in res.notes)
    if killed:
        # the respawn resumed from a snapshot instead of restarting cold
        assert snoop.resumed_from is not None and snoop.resumed_from >= 0
        assert res.recovery_seconds > 0
    # the chaos run's final loss stays within a pinned tolerance of the
    # dense lockstep oracle's at the same seed and epoch count
    oracle = Swarm.create(_mcfg(2), sc.config).run(4)
    oracle_final = [s.mean_loss for s in oracle
                    if s.mean_loss == s.mean_loss][-1]
    assert res.final_loss <= oracle_final * 1.10
