"""Incentives (paper §3, App. A): step decay, proportional emissions,

stability simulation (Fig 9)."""
import numpy as np
import pytest

from repro.core import incentives


def test_step_decay():
    led = incentives.IncentiveLedger(gamma=10.0)
    led.record(0, 0, 5.0, t=0.0)
    assert led.raw_incentive(0, t_now=9.9) == 5.0     # inside gamma
    assert led.raw_incentive(0, t_now=10.1) == 0.0    # expired


def test_emissions_proportional_to_work():
    led = incentives.IncentiveLedger(gamma=100.0)
    led.record(0, 0, 30.0, 0.0)
    led.record(1, 0, 10.0, 0.0)
    em = led.emissions(t_now=1.0, total_emission=1.0)
    assert em[0] == pytest.approx(0.75)
    assert em[1] == pytest.approx(0.25)


def test_fixed_compensation_per_activation():
    """§3: linear reward — doubling backward passes doubles the share ratio."""
    led = incentives.IncentiveLedger(gamma=100.0)
    led.record(0, 0, 10.0, 0.0)
    led.record(1, 0, 20.0, 0.0)
    em = led.emissions(1.0)
    assert em[1] / em[0] == pytest.approx(2.0)


def test_n_scores_formula():
    assert incentives.expected_live_scores(10.0, 0.5) == 20.0


def test_fig9_stability_improves_with_gamma():
    """Appendix A: longer decay gamma (more live scores) -> lower emission

    variance; very short gamma is unstable."""
    cv_short = incentives.stability_simulation(1.0, 1.0, seed=1)["cv"]
    cv_long = incentives.stability_simulation(1.0, 16.0, seed=1)["cv"]
    assert cv_long < cv_short


def test_fig9_stability_improves_with_faster_sync():
    cv_slow = incentives.stability_simulation(8.0, 16.0, seed=2)["cv"]
    cv_fast = incentives.stability_simulation(0.5, 16.0, seed=2)["cv"]
    assert cv_fast < cv_slow


def test_prune_drops_expired():
    led = incentives.IncentiveLedger(gamma=1.0)
    led.record(0, 0, 1.0, 0.0)
    led.record(0, 1, 1.0, 5.0)
    led.prune(t_now=5.0)
    assert len(led.entries) == 1
