"""Wire-format round-trip coverage (`repro.api.serde`).

Every payload type the phases publish must cross the socket bit-exactly
AND digest identically on both sides of the wire — the store's tamper
evidence is only as strong as the serialization.  The payload zoo here is
built by the *same* code paths the phases use (``compression.encode``,
token batches, anchor vectors, score rows), not hand-rolled lookalikes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import serde
from repro.core import compression
from repro.runtime.state_store import StateStore, _digest, _nbytes


def _rt(obj):
    return serde.loads(serde.dumps(obj))


def _assert_same(a, b, path="$"):
    """Deep structural equality: types, dtypes, shapes, bits."""
    if isinstance(a, (np.ndarray, jnp.ndarray)):
        assert isinstance(b, np.ndarray), (path, type(b))
        a = np.asarray(a)
        assert a.dtype == b.dtype, (path, a.dtype, b.dtype)
        assert a.shape == b.shape, (path, a.shape, b.shape)
        assert a.tobytes() == b.tobytes(), path
    elif isinstance(a, dict):
        assert isinstance(b, dict) and list(a) == list(b), path  # order too
        for k in a:
            _assert_same(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        assert type(b) is type(a) and len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_same(x, y, f"{path}[{i}]")
    else:
        assert type(b) is type(a) and b == a, (path, a, b)


# ---------------------------------------------------------------------------
# the phase payload zoo
# ---------------------------------------------------------------------------

def _vec(n=700, seed=0):
    return np.random.RandomState(seed).randn(n).astype(np.float32)


PAYLOADS = {
    # TrainingPhase: pipeline-entry token batch (int32)
    "tokens": jnp.asarray(
        np.random.RandomState(1).randint(0, 512, (4, 32)), jnp.int32),
    # TrainingPhase: boundary activations (fp32 and the bf16 wire dtype)
    "activation_f32": jnp.asarray(
        np.random.RandomState(2).randn(2, 16, 8), jnp.float32),
    "activation_bf16": jnp.asarray(
        np.random.RandomState(3).randn(2, 16, 8), jnp.float32
    ).astype(jnp.bfloat16),
    # TrainingPhase wire_codec="int8": gradient code dict + shape tuple
    "gradient_int8": dict(
        compression.encode(jnp.asarray(_vec(2 * 16 * 8)), "int8"),
        shape=(2, 16, 8)),
    # SharingPhase dense uploads, one per codec
    **{f"weights_{c}": compression.encode(jnp.asarray(_vec(seed=7)), c)
       for c in compression.CODECS},
    # SharingPhase sharded: a block-aligned shard slice of an int8 encode
    "shard_int8": compression.encode(
        jnp.asarray(_vec(1024, seed=8)[256:768]), "int8"),
    # SyncPhase: reduced copy (fp32 "none" payload) + anchor vector
    "reduced_copy": compression.encode(jnp.asarray(_vec(seed=9)), "none"),
    "anchor": _vec(seed=10),
    # ValidationPhase: score row
    "scores": np.asarray([12.0, 14, 12, 0.997], np.float32),
}


@pytest.mark.parametrize("name", sorted(PAYLOADS), ids=sorted(PAYLOADS))
def test_payload_roundtrip_bit_exact(name):
    payload = PAYLOADS[name]
    _assert_same(payload, _rt(payload), path=name)


@pytest.mark.parametrize("name", sorted(PAYLOADS), ids=sorted(PAYLOADS))
def test_payload_digest_and_nbytes_preserved(name):
    """The store digests tree leaves' raw bytes: serializing must not
    change what the server digests vs what the client digested."""
    payload = PAYLOADS[name]
    back = _rt(payload)
    assert _digest(back) == _digest(payload)
    assert _nbytes(back) == _nbytes(payload)


@pytest.mark.parametrize("name", sorted(PAYLOADS), ids=sorted(PAYLOADS))
def test_store_digest_identical_across_wire(name):
    """Digest end-to-end: a store fed the deserialized payload reports the
    same digest as a store fed the original (what the socket server does
    vs what the in-process transport does)."""
    payload = PAYLOADS[name]
    local = StateStore().put("k", payload, actor="a")
    remote = StateStore().put("k", _rt(payload), actor="a")
    assert remote.digest == local.digest
    assert remote.nbytes == local.nbytes


def test_decoded_codec_payloads_still_decode():
    """Deserialized codec dicts must flow through compression.decode
    unchanged — the sharded reduce decodes fetched payloads."""
    for codec in compression.CODECS:
        vec = jnp.asarray(_vec(seed=11))
        payload = _rt(compression.encode(vec, codec))
        out = np.asarray(compression.decode(payload, 700))
        ref = np.asarray(compression.decode(compression.encode(vec, codec),
                                            700))
        np.testing.assert_array_equal(out, ref)


def test_gradient_shape_tuple_survives():
    back = _rt(PAYLOADS["gradient_int8"])
    assert back["shape"] == (2, 16, 8)
    assert isinstance(back["shape"], tuple)
    g = jnp.reshape(compression.decode(back), back["shape"])
    assert g.shape == (2, 16, 8)


# ---------------------------------------------------------------------------
# scalar / container plane (request envelopes, store metadata)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("obj", [
    None, True, False, 0, -1, 2 ** 62, -(2 ** 62), 2 ** 100, -(2 ** 100),
    0.0, -1.5, 3.141592653589793, "", "épöch/ep1", b"", b"\x00\xff raw",
    [], (), {}, [1, [2, [3]]], (1, (2.5, "x")), {"a": {"b": (1, None)}},
    {1: "int key", ("t", 2): "tuple key"},
], ids=repr)
def test_scalar_container_roundtrip(obj):
    _assert_same(obj, _rt(obj))


def test_dict_insertion_order_preserved():
    d = {"z": 1, "a": 2, "m": 3}
    assert list(_rt(d)) == ["z", "a", "m"]


def test_numpy_scalar_roundtrips_as_zero_dim_array():
    back = _rt(np.float32(1.5))
    assert isinstance(back, np.ndarray) and back.shape == ()
    assert back.dtype == np.float32 and float(back) == 1.5


def test_nan_and_inf_survive():
    back = _rt({"v": np.asarray([np.nan, np.inf, -np.inf], np.float32)})
    assert np.isnan(back["v"][0]) and np.isposinf(back["v"][1])
    assert np.isneginf(back["v"][2])


def test_unsupported_type_fails_loud():
    with pytest.raises(TypeError, match="serde cannot encode"):
        serde.dumps(object())


def test_object_dtype_array_rejected():
    # tobytes() on object arrays would serialize raw pointers
    with pytest.raises(TypeError, match="object-dtype"):
        serde.dumps(np.asarray([{"a": 1}, None], dtype=object))


def test_truncated_and_trailing_buffers_rejected():
    buf = serde.dumps({"a": np.zeros(8, np.float32)})
    with pytest.raises(ValueError):
        serde.loads(buf[:-3])
    with pytest.raises(ValueError):
        serde.loads(buf + b"\x00")


# ---------------------------------------------------------------------------
# property-style fuzz: random payload trees (seeded; hypothesis-optional)
# ---------------------------------------------------------------------------

_DTYPES = (np.float32, np.int8, np.int32, np.uint8, np.float64, np.bool_,
           jnp.bfloat16, np.float16)


def _random_tree(rng, depth=0):
    roll = rng.randint(8 if depth < 3 else 5)
    if roll == 0:
        dtype = _DTYPES[rng.randint(len(_DTYPES))]
        shape = tuple(rng.randint(1, 5) for _ in range(rng.randint(0, 3)))
        raw = rng.randn(*shape) * 10
        return np.asarray(jnp.asarray(raw).astype(dtype))
    if roll == 1:
        return int(rng.randint(-10**9, 10**9))
    if roll == 2:
        return float(rng.randn())
    if roll == 3:
        return "".join(chr(rng.randint(32, 1000)) for _ in range(rng.randint(8)))
    if roll == 4:
        return [None, True, False][rng.randint(3)]
    if roll == 5:
        return {f"k{i}": _random_tree(rng, depth + 1)
                for i in range(rng.randint(4))}
    if roll == 6:
        return [_random_tree(rng, depth + 1) for _ in range(rng.randint(4))]
    return tuple(_random_tree(rng, depth + 1) for _ in range(rng.randint(4)))


@pytest.mark.parametrize("seed", range(25))
def test_fuzz_roundtrip_random_trees(seed):
    rng = np.random.RandomState(seed)
    tree = {"payload": _random_tree(rng), "meta": _random_tree(rng)}
    back = _rt(tree)
    _assert_same(tree, back)
    assert _digest(back) == _digest(tree)
    assert _nbytes(back) == _nbytes(tree)


try:  # the richer generator when hypothesis is installed (CI parity with
    # test_compression/test_properties — plain seeded fuzz above otherwise)
    from hypothesis import given, settings, strategies as st

    _scalars = (st.none() | st.booleans() | st.integers() |
                st.floats(allow_nan=False) | st.text(max_size=20) |
                st.binary(max_size=64))
    _trees = st.recursive(
        _scalars,
        lambda kids: (st.lists(kids, max_size=4) |
                      st.dictionaries(st.text(max_size=8), kids, max_size=4)),
        max_leaves=20)

    @given(_trees)
    @settings(max_examples=50, deadline=None)
    def test_hypothesis_roundtrip(tree):
        _assert_same(tree, _rt(tree))
except ImportError:  # pragma: no cover
    pass


# ---------------------------------------------------------------------------
# typed message envelopes: registry-driven round-trip
# ---------------------------------------------------------------------------
#
# Parametrized over MESSAGE_TYPES (the source of truth in api/messages.py)
# and cross-checked against the serde registry, so a new *Msg dataclass
# that skips the _register(...) block fails here AND in the swarmlint
# serde-coverage rule — before it can fail on a live socket.

import dataclasses

from repro.api import messages


def _sample_message(cls):
    """Instantiate with deterministic per-field values (fields are ints,
    strs and Optional[int]s; positions vary so swapped fields don't
    round-trip by accident)."""
    kwargs = {}
    for i, f in enumerate(dataclasses.fields(cls)):
        kwargs[f.name] = "int8" if "str" in str(f.type) else i + 2
    return cls(**kwargs)


@pytest.mark.parametrize(
    "cls", messages.MESSAGE_TYPES, ids=lambda c: c.__name__)
def test_message_registered_and_round_trips(cls):
    assert cls.__name__ in serde.registered_message_names(), (
        f"{cls.__name__} missing from the api/serde.py _register block")
    assert serde.message_type(cls.__name__) is cls
    msg = _sample_message(cls)
    back = serde.decode_message(serde.encode_message(msg))
    assert type(back) is cls
    for f in dataclasses.fields(cls):       # compare=False fields too
        assert getattr(back, f.name) == getattr(msg, f.name), f.name


def test_registry_has_no_stale_entries():
    defined = {c.__name__ for c in messages.MESSAGE_TYPES}
    assert set(serde.registered_message_names()) <= defined


def test_encode_message_rejects_unregistered():
    @dataclasses.dataclass(frozen=True)
    class RogueMsg:
        epoch: int

    with pytest.raises(TypeError, match="not a registered wire message"):
        serde.encode_message(RogueMsg(epoch=1))


def test_decode_message_rejects_unknown_envelope():
    with pytest.raises(ValueError, match="not a message envelope"):
        serde.decode_message(serde.dumps({"fields": {}}))
    with pytest.raises(ValueError, match="unknown message type"):
        serde.decode_message(serde.dumps({"__msg__": "GhostMsg",
                                          "fields": {}}))
