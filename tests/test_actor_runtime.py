"""Concurrent actor runtime: WorkQueue semantics, the ActorProcess
health/stop protocol, supervisor crash detection, ``wait_for`` blocking
waits, the StoreServer start/stop lifecycle, and the ``ActorSwarm``
facade guards.

The cheap tests drive ``ActorProcess`` bodies in *threads* against a
threaded ``StoreServer`` — same code paths as the spawned deployment
minus the interpreter startup — so crash-before-publish, slow-poller
and out-of-order completion are covered in milliseconds.  One
slow-marked test spawns a real fleet and checks bit-exact parity with
the in-process oracle (``examples/actor_swarm.py`` covers the dense AND
sharded variants at 2 epochs; here one epoch, dense, plus a
kill-a-child crash-surface check).
"""
import dataclasses
import socket
import threading
import time

import numpy as np
import pytest

from repro.api import KeySchema, SocketTransport, Swarm, SwarmConfig, serde
from repro.api.messages import (
    EpochPlanMsg, HeartbeatMsg, SnapshotMsg, TickLossMsg,
)
from repro.api.transport import InProcessTransport
from repro.configs import get, smoke_variant
from repro.configs.base import TrainConfig
from repro.runtime.actor import (
    ActorDied, ActorProcess, ActorSpec, ActorStopped, ActorSupervisor,
    ActorSwarm, WorkQueue,
)
from repro.runtime.network import FaultModel, MinerBehavior
from repro.runtime.store_server import StoreServer


def _mcfg(n_layers=1):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


@pytest.fixture(scope="module")
def server():
    srv = StoreServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def transport(server):
    tp = SocketTransport(server.address, schema=KeySchema(version=3))
    tp.reset_store()
    yield tp
    tp.close()


# ---------------------------------------------------------------------------
# WorkQueue: pull-based work discovery
# ---------------------------------------------------------------------------


def test_workqueue_returns_existing_key_immediately():
    tp = InProcessTransport()
    tp.put("job/ready", 7)
    q = WorkQueue(tp, timeout=1.0)
    assert q.get("job/ready") == 7


def test_workqueue_slow_poller_sees_late_publish():
    """The publisher lands *after* the consumer starts waiting."""
    tp = InProcessTransport()
    q = WorkQueue(tp, timeout=5.0)
    threading.Timer(0.1, lambda: tp.put("job/late", "done")).start()
    t0 = time.monotonic()
    assert q.get("job/late") == "done"
    assert time.monotonic() - t0 < 4.0


def test_workqueue_out_of_order_completion():
    """Results land in reverse order; awaiting in tick order still
    collects every one (the EventDriver's watermark pattern)."""
    tp = InProcessTransport()
    q = WorkQueue(tp, timeout=5.0)
    keys = [f"job/t{i}" for i in range(4)]

    def publish_reversed():
        for i, key in enumerate(reversed(keys)):
            time.sleep(0.02)
            tp.put(key, key)
    threading.Thread(target=publish_reversed, daemon=True).start()
    assert [q.get(k) for k in keys] == keys


def test_workqueue_timeout_is_a_timeout_error():
    q = WorkQueue(InProcessTransport(), timeout=0.05)
    with pytest.raises(TimeoutError, match="job/never"):
        q.await_key("job/never")


def test_workqueue_stop_event_raises_actor_stopped():
    stop = threading.Event()
    q = WorkQueue(InProcessTransport(), timeout=30.0, stop_event=stop)
    threading.Timer(0.05, stop.set).start()
    t0 = time.monotonic()
    with pytest.raises(ActorStopped):
        q.await_key("job/never")
    assert time.monotonic() - t0 < 5.0


def test_workqueue_crash_before_publish_surfaces_actor_died():
    """A peer dies before publishing the awaited key: the liveness hook
    turns the would-be 30s timeout into an immediate ``ActorDied``."""
    calls = {"n": 0}

    def liveness():
        calls["n"] += 1
        if calls["n"] >= 3:
            raise ActorDied("miner7", 1)

    q = WorkQueue(InProcessTransport(), timeout=30.0,
                  liveness=liveness, liveness_every=1)
    t0 = time.monotonic()
    with pytest.raises(ActorDied, match="miner7"):
        q.await_key("activations/never")
    assert time.monotonic() - t0 < 5.0


# ---------------------------------------------------------------------------
# SocketTransport.wait_for: server-side blocking wait
# ---------------------------------------------------------------------------


def test_wait_for_times_out_false(transport):
    t0 = time.monotonic()
    assert transport.wait_for("control/never", timeout=0.2) is False
    assert 0.1 <= time.monotonic() - t0 < 3.0


def test_wait_for_woken_by_another_clients_put(server, transport):
    other = SocketTransport(server.address, schema=KeySchema(version=3))
    try:
        threading.Timer(
            0.15, lambda: other.put("wake/key", 42, actor="other")).start()
        t0 = time.monotonic()
        assert transport.wait_for("wake/key", timeout=5.0) is True
        # woken by notify, not by timeout expiry
        assert time.monotonic() - t0 < 4.0
        assert transport.get("wake/key") == 42
    finally:
        other.close()


def test_workqueue_uses_wait_for_path_on_socket_transport(server, transport):
    other = SocketTransport(server.address, schema=KeySchema(version=3))
    try:
        q = WorkQueue(transport, timeout=10.0)
        threading.Timer(
            0.1, lambda: other.put("wake/late", "v", actor="other")).start()
        assert q.get("wake/late") == "v"
    finally:
        other.close()


# ---------------------------------------------------------------------------
# ActorProcess: health endpoint + epoch loop (threaded, no spawn cost)
# ---------------------------------------------------------------------------


class _StubWorkActor(ActorProcess):
    """ActorProcess body with a recording ``process_epoch`` — exercises
    the real setup/health/plan-loop/shutdown machinery in a thread."""

    def __init__(self, spec):
        super().__init__(spec)
        self.plans = []

    def process_epoch(self, plan):
        self.plans.append(plan)


def _spec(server, kind="miner", uid=0):
    return ActorSpec(kind, uid, 0, _mcfg(), SwarmConfig(n_stages=1),
                     TrainConfig(), server.address)


def _start_stub(server):
    import queue as queue_mod
    actor = _StubWorkActor(_spec(server))
    ready = queue_mod.Queue()
    thread = threading.Thread(target=actor.run, args=(ready,), daemon=True)
    thread.start()
    name, addr = ready.get(timeout=10.0)
    return actor, thread, name, addr


def test_health_ping_answers_heartbeat_and_stop_ends_loop(transport, server):
    actor, thread, name, addr = _start_stub(server)
    sup = ActorSupervisor()
    sup.health[name] = addr
    try:
        hb = sup.ping(name)
        assert isinstance(hb, HeartbeatMsg)
        assert hb.actor == "miner0"
        assert hb.epoch == 0 and hb.items_done == 0
    finally:
        sup.stop(name)
        thread.join(timeout=10.0)
    assert not thread.is_alive()
    assert actor.state == "stopped"
    # stopping again is idempotent even though the endpoint is gone
    sup.stop(name)


def test_stop_plan_ends_epoch_loop_without_processing(transport, server):
    transport.publish(EpochPlanMsg(0), {"stop": True, "epoch": 0},
                      actor="orchestrator")
    actor, thread, name, addr = _start_stub(server)
    thread.join(timeout=10.0)
    assert not thread.is_alive()
    assert actor.plans == []


def test_epoch_loop_processes_plans_in_order(transport, server):
    actor, thread, name, addr = _start_stub(server)
    try:
        transport.publish(EpochPlanMsg(0), {"stop": False, "epoch": 0},
                          actor="orchestrator")
        transport.publish(EpochPlanMsg(1), {"stop": True, "epoch": 1},
                          actor="orchestrator")
        thread.join(timeout=15.0)
        assert not thread.is_alive()
        assert [p["epoch"] for p in actor.plans] == [0]
        assert actor.epoch == 1
    finally:
        sup = ActorSupervisor()
        sup.health[name] = addr
        sup.stop(name)


# ---------------------------------------------------------------------------
# supervisor crash detection
# ---------------------------------------------------------------------------


class _DeadProc:
    exitcode = -9

    @staticmethod
    def is_alive():
        return False


def test_supervisor_check_turns_dead_child_into_actor_died():
    sup = ActorSupervisor()
    sup.procs["miner3"] = _DeadProc()
    with pytest.raises(ActorDied, match="miner3") as exc:
        sup.check()
    assert exc.value.exitcode == -9


# ---------------------------------------------------------------------------
# serde + key coverage for the control-plane envelopes
# ---------------------------------------------------------------------------


def test_new_control_messages_are_registered():
    names = serde.registered_message_names()
    for name in ("EpochPlanMsg", "HeartbeatMsg", "SnapshotMsg",
                 "TickLossMsg"):
        assert name in names


def test_heartbeat_envelope_roundtrips():
    hb = HeartbeatMsg("miner0", pid=123, epoch=4, items_done=7,
                      state="working")
    out = serde.decode_message(serde.encode_message(hb))
    assert out == hb and out.pid == 123 and out.state == "working"


def test_control_keys_parse_under_v3():
    schema = KeySchema(version=3)
    kinds = {}
    for msg in (EpochPlanMsg(2), SnapshotMsg(2, 5), TickLossMsg(2, 9),
                HeartbeatMsg("miner0")):
        key = msg.key(schema)
        assert key.startswith("control/")
        kinds[schema.parse(key).kind] = key
    assert set(kinds) == {"plan", "snapshot", "tick_loss", "heartbeat"}


# ---------------------------------------------------------------------------
# StoreServer lifecycle: 10 start/stop cycles leave nothing behind
# ---------------------------------------------------------------------------


def test_store_server_ten_start_stop_cycles_leave_no_leaks():
    before = {t for t in threading.enumerate()}
    addresses = []
    for i in range(10):
        srv = StoreServer().start()
        tp = SocketTransport(srv.address)
        tp.put(f"cycle/{i}", i)
        assert tp.get(f"cycle/{i}") == i
        tp.close()
        srv.stop()
        addresses.append(srv.address)
    # no server or handler threads survive their server
    leftover = [t for t in threading.enumerate()
                if t not in before and t.is_alive()
                and "store-server" in t.name]
    assert leftover == []
    # every stopped address refuses new connections
    with pytest.raises(OSError):
        socket.create_connection(addresses[-1], timeout=0.5)


def test_stop_unparks_blocked_waiters():
    """A shutdown must not wait out a parked ``wait`` handler: the stop
    flag + notify returns the waiter promptly as not-found."""
    srv = StoreServer().start()
    tp = SocketTransport(srv.address)
    result = {}

    def waiter():
        try:
            result["exists"] = tp.wait_for("never/published", timeout=4.0)
        except (OSError, ConnectionError) as exc:   # torn connection is
            result["error"] = exc                   # also a prompt return
    thread = threading.Thread(target=waiter, daemon=True)
    thread.start()
    time.sleep(0.2)          # let the wait park server-side
    t0 = time.monotonic()
    srv.stop()
    thread.join(timeout=3.0)
    assert not thread.is_alive()
    assert time.monotonic() - t0 < 3.0
    tp.close()


# ---------------------------------------------------------------------------
# ActorSwarm facade guards (no fleet spawned)
# ---------------------------------------------------------------------------


def test_create_rejects_unknown_runtime():
    with pytest.raises(ValueError, match="runtime"):
        Swarm.create(_mcfg(), SwarmConfig(), runtime="fibers")


def test_create_rejects_transport_override_for_actors():
    with pytest.raises(ValueError):
        Swarm.create(_mcfg(), SwarmConfig(), runtime="actors",
                     transport=InProcessTransport())


def test_create_rejects_store_address_for_inprocess():
    with pytest.raises(ValueError):
        Swarm.create(_mcfg(), SwarmConfig(),
                     store_address=("127.0.0.1", 1))


def test_actor_swarm_accepts_payload_corrupting_faults():
    # chaos-first runtime: tamper/free-ride are actor-owned now — the
    # behavior rides the spawn spec instead of being rejected
    faults = FaultModel({1: MinerBehavior(tamper_activations=0.5)})
    swarm = ActorSwarm(_mcfg(n_layers=2), SwarmConfig(n_stages=2),
                       faults=faults)
    try:
        specs = [ActorSpec("miner", m.uid, m.stage, swarm.cfg,
                           swarm.config, swarm.train_cfg,
                           swarm.store_address,
                           behavior=swarm.faults.behaviors.get(m.uid))
                 for m in swarm.miners.values()]
        by_uid = {s.uid: s for s in specs}
        assert by_uid[1].behavior is not None
        assert by_uid[1].behavior.tamper_activations == 0.5
        assert by_uid[0].behavior is None
    finally:
        swarm.shutdown()


def test_actor_swarm_accepts_schedule_only_faults():
    faults = FaultModel({1: MinerBehavior(drop_prob=0.5,
                                          straggle_factor=2.0)})
    swarm = ActorSwarm(_mcfg(n_layers=2), SwarmConfig(n_stages=2),
                       faults=faults)
    try:
        assert swarm.supervisor.names == []     # nothing spawned yet
    finally:
        swarm.shutdown()


# ---------------------------------------------------------------------------
# spawned fleet: parity with the in-process oracle + crash surface
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_actor_fleet_matches_in_process_and_surfaces_crashes():
    cfg = SwarmConfig(seed=3, n_stages=2, miners_per_stage=2,
                      inner_steps=2, b_min=1, batch_size=2, seq_len=16,
                      validators=1)
    mcfg = _mcfg(n_layers=2)

    swarm = Swarm.create(mcfg, cfg, runtime="actors")
    try:
        swarm.start()
        stats = swarm.run(1)
        # kill one child: the driver-side liveness hook must notice
        victim = swarm.supervisor.names[0]
        swarm.supervisor.procs[victim].terminate()
        swarm.supervisor.procs[victim].join(timeout=5.0)
        with pytest.raises(ActorDied, match=victim):
            swarm.check_liveness()
    finally:
        swarm.shutdown()

    local = Swarm.create(mcfg, cfg)
    ref = local.run(1)
    assert [s.mean_loss for s in stats] == [s.mean_loss for s in ref]
    assert [s.merged_stages for s in stats] == [s.merged_stages for s in ref]
    assert [[(r.miner_uid, r.score) for r in s.validation] for s in stats] \
        == [[(r.miner_uid, r.score) for r in s.validation] for s in ref]
