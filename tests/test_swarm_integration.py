"""End-to-end decentralized-runtime integration (paper §2-§3, §5-§6).

These are the system-behaviour tests: a real (tiny) model trained through
the simulated swarm with faults, adversaries and stragglers injected.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get, smoke_variant
from repro.runtime import FaultModel, MinerBehavior, Orchestrator, SwarmConfig


def _mcfg(n_layers=6):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


@pytest.fixture(scope="module")
def honest_run():
    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=10, b_min=2,
                     batch_size=4, seq_len=32, seed=0)
    orch = Orchestrator(_mcfg(), sw)
    stats = orch.run(6)
    return orch, stats


def test_swarm_loss_decreases(honest_run):
    _, stats = honest_run
    first, last = stats[0].mean_loss, stats[-1].mean_loss
    assert last < first - 0.05, (first, last)


def test_honest_miners_validate_clean(honest_run):
    _, stats = honest_run
    for s in stats:
        for r in s.validation:
            assert r.honest, (s.epoch, r)


def test_agreement_matrix_clean_when_honest(honest_run):
    _, stats = honest_run
    for s in stats:
        for stage, mat in s.agreement.items():
            off = mat[~np.isnan(mat)]
            assert (off == 1.0).all()


def test_b_eff_counts_only_qualifying(honest_run):
    orch, stats = honest_run
    for s in stats:
        expect = sum(b for b in s.batches.values() if b >= orch.swarm.b_min)
        assert s.b_eff == expect


def test_validator_catches_free_rider():
    sw = SwarmConfig(n_stages=3, miners_per_stage=3, inner_steps=12, b_min=2,
                     batch_size=2, seq_len=32, validators=6, seed=1)
    faults = FaultModel({1: MinerBehavior(free_ride=True)}, seed=1)
    orch = Orchestrator(_mcfg(), sw, faults=faults)
    stats = orch.run(3)
    verdicts = {}
    for s in stats:
        for r in s.validation:
            verdicts.setdefault(r.miner_uid, []).append(r.honest)
    # every time the cheater was audited it failed; honest miners never did
    if 1 in verdicts:
        assert not any(verdicts[1])
    for uid, vs in verdicts.items():
        if uid != 1:
            assert all(vs), (uid, vs)


def test_clasp_flags_free_rider_on_live_losses():
    sw = SwarmConfig(n_stages=3, miners_per_stage=3, inner_steps=40, b_min=2,
                     batch_size=2, seq_len=32, validators=0, seed=2)
    faults = FaultModel({4: MinerBehavior(free_ride=True)}, seed=2)
    orch = Orchestrator(_mcfg(), sw, faults=faults)
    stats = orch.run(3)
    rep = stats[-1].clasp
    # the free-rider has the highest z-score in the network by the last epoch
    assert int(np.argmax(rep.z_scores)) == 4


def test_dropped_miners_dont_halt_training():
    sw = SwarmConfig(n_stages=3, miners_per_stage=3, inner_steps=12, b_min=1,
                     batch_size=2, seq_len=32, seed=3)
    faults = FaultModel({0: MinerBehavior(drop_prob=0.7),
                         3: MinerBehavior(drop_prob=0.7)}, seed=3)
    orch = Orchestrator(_mcfg(), sw, faults=faults)
    stats = orch.run(3)
    for s in stats:
        # ticks mostly complete via SWARM rerouting to the live replicas
        assert s.stalled_ticks < sw.inner_steps / 2
        assert np.isfinite(s.mean_loss)


def test_straggler_finishes_fewer_batches():
    sw = SwarmConfig(n_stages=2, miners_per_stage=2, inner_steps=12, b_min=1,
                     batch_size=2, seq_len=32, seed=4)
    faults = FaultModel({0: MinerBehavior(straggle_factor=4.0)}, seed=4)
    orch = Orchestrator(_mcfg(4), sw, faults=faults)
    stats = orch.run(2)
    batches = stats[-1].batches
    peers = [batches[m] for m in batches if m != 0
             and orch.miners[m].stage == 0]
    assert batches[0] < max(peers), batches


def test_emissions_proportional_to_validated_work():
    sw = SwarmConfig(n_stages=2, miners_per_stage=2, inner_steps=10, b_min=1,
                     batch_size=2, seq_len=32, validators=4, seed=5)
    orch = Orchestrator(_mcfg(4), sw)
    stats = orch.run(3)
    em = stats[-1].emissions
    assert abs(sum(em.values()) - 1.0) < 1e-6
    # validated miners earn; totals track ledger scores
    t = (len(stats) - 1) * sw.sync_interval_hours
    for uid, share in em.items():
        raw = orch.ledger.raw_incentive(uid, t)
        if raw == 0:
            assert share <= max(em.values())


def test_new_miner_joins_at_full_sync():
    sw = SwarmConfig(n_stages=2, miners_per_stage=2, inner_steps=8, b_min=1,
                     batch_size=2, seq_len=32, seed=6)
    orch = Orchestrator(_mcfg(4), sw)
    orch.run(1)
    newbie = orch.register_miner(stage=1)
    # joiner starts from the stage anchor (same weights as the merged model)
    anchor_vec = np.asarray(
        orch.miners[newbie.uid].weights_vector())
    stats = orch.run(2)
    assert newbie.uid in stats[-1].batches
    assert stats[-1].batches[newbie.uid] > 0     # it worked after joining


def test_tamperer_breaks_weight_agreement():
    sw = SwarmConfig(n_stages=2, miners_per_stage=3, inner_steps=8, b_min=1,
                     batch_size=2, seq_len=32, seed=7)
    faults = FaultModel({1: MinerBehavior(tamper_weights=0.5)}, seed=7)
    orch = Orchestrator(_mcfg(4), sw, faults=faults)
    stats = orch.run(1)
    mat = stats[-1].agreement.get(0)
    assert mat is not None
    # find the tamperer's index among qualifying stage-0 miners: its rows
    # disagree (tampered uploads poison every shard it reduces... here the
    # upload itself differs so partners disagree with each other's copies)
    off = mat[~np.isnan(mat)]
    assert (off < 1.0).any()


def test_store_traffic_accounted():
    sw = SwarmConfig(n_stages=2, miners_per_stage=2, inner_steps=4, b_min=1,
                     batch_size=2, seq_len=16, seed=8)
    orch = Orchestrator(_mcfg(4), sw)
    orch.run(1)
    rep = orch.store.traffic_report()
    assert rep["uploaded"].get("activations", 0) > 0
    assert rep["uploaded"].get("weights", 0) > 0
    assert rep["total_bytes"] > 0
