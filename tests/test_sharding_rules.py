"""Sharding-rule unit tests: param specs, divisibility decisions, state specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import build_model
from repro.sharding.partition import MeshAxes, param_specs


def _ma(**kw):
    base = dict(batch=("data",), model_axis_size=16, data_axis_size=16)
    base.update(kw)
    return MeshAxes(**base)


def _specs_for(arch, **ma_kw):
    cfg = configs.get(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    return shapes, param_specs(shapes, _ma(fsdp=cfg.parallel.fsdp, **ma_kw))


def test_embed_vocab_sharded_over_model():
    shapes, specs = _specs_for("llama3.2-1b")
    assert specs["embeds"]["embed"] == P("model", None)
    assert specs["embeds"]["unembed"] == P("model", None)


def test_scanned_block_params_get_leading_replicated_dim():
    shapes, specs = _specs_for("llama3.2-1b")
    wq = specs["seg0"]["period"]["b0"]["attn"]["wq"]
    assert wq == P(None, None, "model")       # (layers, d_model, H*hd)
    assert shapes["seg0"]["period"]["b0"]["attn"]["wq"].shape[0] == 16


def test_fsdp_shards_second_dim_over_data():
    shapes, specs = _specs_for("qwen3-14b")     # fsdp=True
    wq = specs["seg0"]["period"]["b0"]["attn"]["wq"]
    assert wq == P(None, "data", "model")


def test_moe_experts_ep_over_model():
    shapes, specs = _specs_for("kimi-k2-1t-a32b")
    wg = specs["seg0"]["period"]["b0"]["moe"]["experts"]["w_gate"]
    assert wg == P(None, "model", "data", None)   # (layers, E, d, f)
    wo = specs["seg0"]["period"]["b0"]["moe"]["experts"]["w_out"]
    assert wo == P(None, "model", None, "data")


def test_kv_replicated_when_heads_dont_divide():
    # glm4: kv=2 on a 16-wide model axis -> kv projections replicated
    shapes, specs = _specs_for("glm4-9b", shard_kv_heads=False)
    wk = specs["seg0"]["period"]["b0"]["attn"]["wk"]
    assert wk[-1] is None


def test_norms_replicated():
    shapes, specs = _specs_for("llama3.2-1b")
    assert specs["final_norm"] in (P(), P(None))


def test_every_leaf_gets_a_spec_matching_rank():
    for arch in configs.all_arch_ids():
        shapes, specs = _specs_for(arch)
        flat_s = jax.tree_util.tree_leaves(shapes)
        td = jax.tree_util.tree_structure(shapes)
        flat_p = td.flatten_up_to(specs)
        for sh, sp in zip(flat_s, flat_p):
            assert isinstance(sp, P), (arch, sp)
            assert len(sp) <= len(sh.shape), (arch, sh.shape, sp)
            # every named axis must divide... or be the padded-head case
            for dim, name in zip(sh.shape, list(sp) + [None] * 8):
                if name in ("model",) and dim % 16 != 0:
                    assert dim in (40, 56) or dim >= 16, (arch, sh.shape, sp)


def test_decode_state_specs_cover_state():
    from repro.launch.shardings import decode_state_spec_tree
    from repro.configs.base import SHAPES
    for arch in ["llama3.2-1b", "jamba-v0.1-52b", "xlstm-125m",
                 "seamless-m4t-medium"]:
        cfg = configs.get(arch)
        model = build_model(cfg)
        shape = SHAPES["decode_32k"]
        st = model.decode_state_specs(shape)
        specs = decode_state_spec_tree(model, shape, _ma())
        flat_s = jax.tree_util.tree_leaves(st)
        td = jax.tree_util.tree_structure(st)
        flat_p = td.flatten_up_to(specs)
        assert len(flat_s) == len(flat_p)
        for sh, sp in zip(flat_s, flat_p):
            assert len(sp) <= len(sh.shape), (arch, sh.shape, sp)


def test_effective_accum_divides_batch():
    """grad_accum larger than the batch still runs (clamped internally)."""
    import dataclasses
    cfg = configs.smoke_variant(configs.get("llama3.2-1b"))
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, grad_accum=16))
    model = build_model(cfg)
    state = model.init_train_state(jax.random.key(0))
    batch = model.synth_batch(jax.random.key(1), 4, 16)
    ma = _ma(data_axis_size=2, model_axis_size=1)
    _, metrics = jax.jit(lambda s, b: model.train_step(s, b, ma))(
        state, batch)
    assert jnp.isfinite(metrics["loss"])
