"""Data pipeline determinism/sharding + optimizer math + schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import ByteTokenizer, DataConfig, SyntheticCorpus
from repro.data.pipeline import make_host_iterator
from repro.optim import adafactor, adamw, cosine_warmup, sgdm


def _cfg(**kw):
    base = dict(vocab_size=512, seq_len=32, batch_size=4, seed=0)
    base.update(kw)
    return DataConfig(**base)


def test_batches_deterministic():
    c1, c2 = SyntheticCorpus(_cfg()), SyntheticCorpus(_cfg())
    b1, b2 = c1.batch(5), c2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_batches_differ_across_steps_and_hosts():
    c = SyntheticCorpus(_cfg())
    assert not np.array_equal(c.batch(0)["tokens"], c.batch(1)["tokens"])
    assert not np.array_equal(c.batch(0, host_id=0, n_hosts=4)["tokens"],
                              c.batch(0, host_id=1, n_hosts=4)["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticCorpus(_cfg()).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_corpus_is_learnable():
    """The synthetic stream has structure: bigram counts are concentrated

    vs uniform (what lets convergence benches show real learning)."""
    c = SyntheticCorpus(_cfg(batch_size=16, seq_len=256))
    toks = np.concatenate([c.batch(s)["tokens"].reshape(-1)
                           for s in range(4)])
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs[(a, b)] = pairs.get((a, b), 0) + 1
    top100 = sum(sorted(pairs.values())[-100:])
    assert top100 / len(toks) > 0.05     # heavy head => predictable


def test_host_iterator_resumable():
    it = make_host_iterator(_cfg(), start_step=3)
    c = SyntheticCorpus(_cfg())
    np.testing.assert_array_equal(next(it)["tokens"], c.batch(3)["tokens"])


def test_tokenizer_roundtrip_ascii():
    tok = ByteTokenizer(2048, merge_bigrams=False)
    ids = tok.encode("hello world")
    assert tok.decode(ids) == "hello world"
    assert ids[0] == 1 and ids[-1] == 2          # BOS/EOS


def test_tokenizer_respects_vocab_bound():
    tok = ByteTokenizer(50304)
    ids = tok.encode("The quick brown fox jumps over the lazy dog" * 10)
    assert ids.max() < 50304 and ids.min() >= 0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adamw_first_step_direction():
    opt = adamw(lambda s: 0.1, beta1=0.9, beta2=0.999, weight_decay=0.0)
    params = {"w": jnp.ones(3)}
    grads = {"w": jnp.asarray([1.0, -1.0, 2.0])}
    st = opt.init(params)
    new, _ = opt.update(grads, st, params, jnp.zeros((), jnp.int32))
    # bias-corrected adam first step = -lr * sign(g) (approximately)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [0.9, 1.1, 0.9], atol=1e-3)


def test_sgdm_nesterov_matches_manual():
    opt = sgdm(lambda s: 1.0, momentum=0.5, nesterov=True)
    params = {"w": jnp.zeros(1)}
    g = {"w": jnp.ones(1)}
    st = opt.init(params)
    p1, st = opt.update(g, st, params, jnp.zeros((), jnp.int32))
    # m=1, step = g + 0.5*m = 1.5
    np.testing.assert_allclose(np.asarray(p1["w"]), [-1.5])


def test_adafactor_factored_state_shapes():
    opt = adafactor(lambda s: 1e-2)
    params = {"big": jnp.ones((256, 512)), "small": jnp.ones((4, 8))}
    st = opt.init(params)
    assert st["v"]["big"]["vr"].shape == (256,)
    assert st["v"]["big"]["vc"].shape == (512,)
    assert st["v"]["small"]["v"].shape == (4, 8)
    g = jax.tree.map(jnp.ones_like, params)
    new, st2 = opt.update(g, st, params, jnp.zeros((), jnp.int32))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(new))


def test_adafactor_memory_savings():
    """The factored state is ~2/(min dim) of adam's per-tensor footprint —

    the reason kimi-k2 fits pod HBM (DESIGN.md)."""
    from repro.common import tree_bytes
    params = {"w": jnp.ones((4096, 4096))}
    a_state = adamw(lambda s: 1.0).init(params)
    f_state = adafactor(lambda s: 1.0).init(params)
    assert tree_bytes(f_state) < tree_bytes(a_state) / 1000


def test_cosine_warmup_schedule():
    sched = cosine_warmup(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(5)) == pytest.approx(0.5)
    assert float(sched(100)) == pytest.approx(0.1, abs=1e-3)
    # monotone decay after warmup
    vals = [float(sched(s)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_optimizer_state_specs_structure():
    from jax.sharding import PartitionSpec as P
    params = {"w": jnp.ones((256, 512)), "b": jnp.ones((4,))}
    specs = {"w": P("model", None), "b": P()}
    shapes = jax.eval_shape(lambda: params)
    for opt in (adamw(lambda s: 1.0), sgdm(lambda s: 1.0),
                adafactor(lambda s: 1.0)):
        st_specs = opt.state_specs(specs, shapes)
        st = opt.init(params)
        # spec tree structure must cover every state leaf
        jax.tree.map(lambda leaf, spec: None, st, st_specs,
                     is_leaf=lambda x: isinstance(x, P))
        if opt.name == "adafactor":
            assert st_specs["v"]["w"]["vr"] == P("model")
            assert st_specs["v"]["w"]["vc"] == P(None)
