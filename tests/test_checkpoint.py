"""Checkpointing: atomic/integrity/async/elastic (fault-tolerance contract)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.ones((3,), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"), {"step": 7})
    restored, meta = restore_pytree(t, str(tmp_path / "ck"))
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_integrity_check_detects_corruption(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    # flip bytes in one leaf file
    victim = sorted(os.listdir(tmp_path / "ck"))[0]
    path = tmp_path / "ck" / victim
    arr = np.load(path)
    arr = np.asarray(arr).copy()
    arr.reshape(-1)[0] += 1
    np.save(path, arr)
    with pytest.raises(IOError, match="corruption"):
        restore_pytree(t, str(tmp_path / "ck"))


def test_elastic_partial_restore(tmp_path):
    """A template with extra/renamed leaves restores the matching subset."""
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    template = {"a": jnp.zeros((8, 16)),
                "nested": {"b": jnp.zeros(10, jnp.int32),
                           "c": jnp.zeros((3,), jnp.bfloat16),
                           "new_buffer": jnp.full((4,), -1.0)}}
    restored, _ = restore_pytree(template, str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["new_buffer"]),
                                  -1.0)  # kept from template


def test_manager_rolling_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, _tree(step))
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest_step() == 30
    restored, meta = mgr.restore(_tree())
    assert meta["step"] == 30


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    t = _tree(1)
    mgr.save(5, t)
    mgr.wait()
    restored, meta = mgr.restore(t)
    assert meta["step"] == 5


def test_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_train_driver_preemption_resume(tmp_path):
    """Kill the training driver mid-run; resume reproduces the uninterrupted

    trajectory (same final loss) — checkpoint/restart works end to end."""
    import subprocess
    import sys
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ, PYTHONPATH=src)
    base = [sys.executable, "-m", "repro.launch.train", "--arch",
            "llama3.2-1b", "--smoke", "--batch-size", "2", "--seq-len", "32",
            "--ckpt-every", "5", "--log-every", "5"]
    # uninterrupted 15 steps
    r0 = subprocess.run(base + ["--steps", "15", "--ckpt-dir",
                                str(tmp_path / "a")],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r0.returncode == 0, r0.stderr[-2000:]
    # killed at step 10, then resumed
    r1 = subprocess.run(base + ["--steps", "15", "--ckpt-dir",
                                str(tmp_path / "b"), "--kill-at-step", "10"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r1.returncode == 17
    r2 = subprocess.run(base + ["--steps", "15", "--ckpt-dir",
                                str(tmp_path / "b"), "--resume"],
                        capture_output=True, text=True, env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    # the async step-10 save may have been killed mid-write; atomicity
    # guarantees we resume from SOME intact checkpoint (5 or 10), and the
    # final-loss equality below proves the trajectory replays exactly
    assert ("resumed from step 10" in r2.stdout
            or "resumed from step 5" in r2.stdout)

    import json
    last0 = json.loads([l for l in r0.stdout.splitlines()
                        if l.startswith("{")][-1])
    last2 = json.loads([l for l in r2.stdout.splitlines()
                        if l.startswith("{")][-1])
    assert last0["step"] == last2["step"] == 15
    assert abs(last0["loss"] - last2["loss"]) < 1e-4
