"""End-to-end behaviour tests for the paper's system (top level).

The detailed suites live in the sibling test modules; this file asserts the
headline paper claims hold in one place:

  1. §4  — bottleneck compression (128x) trains with near-baseline loss
  2. §5  — butterfly all-reduce merges in O(1) bandwidth with 2x redundancy
  3. §6  — CLASP attribution flags adversaries from pathway losses
  4. §2-3 — the swarm (orchestrator/miners/validators) trains a real model
            under faults, with proportional emissions
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import bottleneck, butterfly, clasp
from repro.models import build_model


def test_claim_c3_bottleneck_trains_close_to_baseline():
    """Short-horizon version of Fig 5: the 128x-compressed model's loss curve

    stays within a modest gap of the uncompressed baseline."""
    from repro.data.pipeline import DataConfig, SyntheticCorpus

    def train(arch_id, steps=30):
        cfg = configs.smoke_variant(configs.get(arch_id))
        model = build_model(cfg)
        corpus = SyntheticCorpus(DataConfig(
            vocab_size=cfg.model.vocab_size, seq_len=64, batch_size=8,
            seed=0))
        state = model.init_train_state(jax.random.key(0))
        step = jax.jit(lambda s, b: model.train_step(s, b))
        losses = []
        for t in range(steps):
            batch = {k: jnp.asarray(v) for k, v in corpus.batch(t).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        return losses

    base = train("llama3.2-1b", steps=60)
    comp = train("iota-bottleneck-1.5b", steps=60)
    b_tail = sum(base[-5:]) / 5
    c_tail = sum(comp[-5:]) / 5
    assert b_tail < base[0] - 0.1              # both actually learn
    assert c_tail < comp[0] - 0.1
    assert c_tail - b_tail < 0.35              # near-baseline convergence


def test_claim_c4_butterfly_merge():
    plan = butterfly.make_plan(8, 4096, seed=0)
    uploads = {m: np.random.RandomState(m).randn(4096).astype(np.float32)
               for m in range(8)}
    merged, valid, agree = butterfly.reduce_shards(plan, uploads)
    np.testing.assert_allclose(
        merged, np.mean(list(uploads.values()), axis=0), atol=1e-5)
    vol = butterfly.transfer_volume(8, 4096 * 4)
    assert vol["per_miner_bytes"] < 5 * 4096 * 4          # O(1)
    assert valid.all() and agree.all()


def test_claim_c5_clasp():
    recs, layer_of = clasp.toy_simulation(
        clasp.ToyConfig(n_samples=4000), malicious=[6])
    rep = clasp.attribute(recs, 25, layer_of)
    assert set(np.where(rep.flagged)[0]) == {6}


def test_claim_c1_c2_swarm_trains_under_faults():
    from repro.runtime import (FaultModel, MinerBehavior, Orchestrator,
                               SwarmConfig)
    mcfg = dataclasses.replace(
        configs.smoke_variant(configs.get("llama3.2-1b")).model, n_layers=6)
    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=10, b_min=2,
                     batch_size=4, seq_len=32, seed=11)
    faults = FaultModel({5: MinerBehavior(drop_prob=0.5)}, seed=11)
    orch = Orchestrator(mcfg, sw, faults=faults)
    stats = orch.run(5)
    assert stats[-1].mean_loss < stats[0].mean_loss
    assert all(s.merged_stages >= 2 for s in stats[1:])
    assert abs(sum(stats[-1].emissions.values()) - 1.0) < 1e-6
