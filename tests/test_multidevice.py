"""Multi-device correctness (8 host devices via subprocess, since the device

count must be fixed before jax initialises): pipeline-engine equivalence,
butterfly mesh all-reduce, DiLoCo outer merge, MoE EP vs local path.
"""
import pytest

from conftest import run_py


@pytest.mark.slow
def test_pipeline_matches_sequential_when_uncompressed():
    """GPipe schedule + ppermute streaming must be numerically identical to

    applying the same stage blocks sequentially (compress=False)."""
    out = run_py("""
        import jax, jax.numpy as jnp, dataclasses, numpy as np
        from repro.configs import get, smoke_variant
        from repro.core.pipeline import (PipelineSpec, init_pipeline_params,
                                         pipeline_apply)
        from repro.models import blocks as blk

        cfg = dataclasses.replace(smoke_variant(get('llama3.2-1b')).model,
                                  n_layers=4)
        mesh = make_mesh((2, 4), ('data', 'model'))
        spec = PipelineSpec(n_stages=4, n_microbatches=2, compress=False)
        params = init_pipeline_params(jax.random.key(0), cfg, spec)
        x = jax.random.normal(jax.random.key(1), (2, 4, 16, cfg.d_model),
                              jnp.bfloat16)
        with mesh:
            y_pipe = jax.jit(lambda p, x: pipeline_apply(
                p, x, cfg, spec, mesh))(params, x)

        # sequential reference: apply all 4 stages' blocks in order
        def seq(params, x):
            pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None],
                                   (x.shape[0], 16))
            ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=pos)
            h = x
            for s in range(4):
                lp = jax.tree.map(lambda a: a[s], params['stages']['blocks'])
                def body(h, layer):
                    h, _, _ = blk.apply_block('attn_dense', layer, h, ctx, None)
                    return h, None
                h, _ = jax.lax.scan(body, h, lp)
            return h
        y_seq = jnp.stack([seq(params, x[i]) for i in range(2)])
        err = float(jnp.max(jnp.abs(y_pipe.astype(jnp.float32)
                                    - y_seq.astype(jnp.float32))))
        print('MAXERR', err)
    """)
    assert float(out.split("MAXERR")[1].strip()) < 0.1


@pytest.mark.slow
def test_butterfly_mesh_allreduce_and_diloco():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core.butterfly import butterfly_all_reduce_mesh
        from repro.core import diloco

        mesh = make_mesh((2, 4), ('pod', 'data'))
        x = jnp.arange(103, dtype=jnp.float32)        # odd length: padding
        with mesh:
            m, a = jax.jit(lambda x: butterfly_all_reduce_mesh(
                x, 'pod', mesh))(x)
            ok1 = bool(jnp.allclose(m, x)) and float(a) == 1.0

            params = {'w': jnp.full((33,), 2.0), 'b': jnp.ones((5,))}
            outer = diloco.outer_init(params)
            synced, new_outer, agree = jax.jit(
                lambda p, o: diloco.outer_merge_step(p, o, mesh, 'pod')
            )(params, outer)
            ok2 = bool(jnp.allclose(synced['w'], 2.0)) and float(agree) == 1.0
        print('OK', ok1 and ok2)
    """)
    assert "OK True" in out


@pytest.mark.slow
def test_moe_ep_matches_local_path():
    """Expert-parallel shard_map result == single-device routing result."""
    out = run_py("""
        import jax, jax.numpy as jnp, dataclasses, numpy as np
        from repro.configs import get, smoke_variant
        from repro.models import moe
        from repro.sharding.partition import make_mesh_axes

        cfg = smoke_variant(get('olmoe-1b-7b'))
        mcfg = dataclasses.replace(cfg.model,
            moe=dataclasses.replace(cfg.model.moe, capacity_factor=8.0))
        params = moe.init_moe(jax.random.key(0), mcfg)
        x = jax.random.normal(jax.random.key(1), (8, 16, mcfg.d_model),
                              jnp.float32)
        y_local, aux_local = moe.moe_ffn(params, x, mcfg, None)

        mesh = make_mesh((2, 4), ('data', 'model'))
        ma = make_mesh_axes(mesh, mcfg, cfg.parallel)
        with mesh:
            y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_ffn(
                p, x, mcfg, ma))(params, x)
        err = float(jnp.max(jnp.abs(y_ep - y_local)))
        print('MAXERR', err)
    """)
    assert float(out.split("MAXERR")[1].strip()) < 5e-2


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,4) mesh with sharded params/batch produces

    the same loss as unsharded execution — the distribution layer does not
    change the math."""
    out = run_py("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get, smoke_variant
        from repro.models import build_model
        from repro.sharding.partition import make_mesh_axes

        cfg = smoke_variant(get('llama3.2-1b'))
        model = build_model(cfg)
        state = model.init_train_state(jax.random.key(0))
        batch = model.synth_batch(jax.random.key(1), 8, 32)
        _, m1 = jax.jit(lambda s, b: model.train_step(s, b))(state, batch)

        mesh = make_mesh((2, 4), ('data', 'model'))
        ma = make_mesh_axes(mesh, cfg.model, cfg.parallel)
        with mesh:
            _, m2 = jax.jit(lambda s, b: model.train_step(s, b, ma))(
                state, batch)
        print('DIFF', abs(float(m1['loss']) - float(m2['loss'])))
    """)
    assert float(out.split("DIFF")[1].strip()) < 5e-3
