"""Real-socket transport: StoreServer + SocketTransport end to end.

The store lives behind a genuine TCP socket (threaded server here — same
wire format and failure surface as the separate-process deployment, which
``examples/multiprocess_swarm.py`` and a slow-marked test cover).  The
contracts under test:

  * every typed message round-trips with its digest intact,
  * ``StoreKeyError`` crosses the process boundary with full context,
  * prefix ops behave identically to the in-process store,
  * a full ``Swarm`` run (dense AND sharded store-and-forward sync)
    reproduces the ``InProcessTransport`` trajectory at the same seed,
  * the server-side per-actor byte accounting equals
    ``SimulatedNetworkTransport``'s link accounting for the same run.
"""
import dataclasses

import numpy as np
import pytest

from repro.api import (
    ActivationMsg,
    AnchorMsg,
    GradientMsg,
    InProcessTransport,
    KeySchema,
    NetworkModel,
    ScoreMsg,
    ShardReducedMsg,
    ShardUploadMsg,
    SimulatedNetworkTransport,
    SocketTransport,
    StoreKeyError,
    Swarm,
    SwarmConfig,
    Transport,
    WeightUploadMsg,
)
from repro.core import compression
from repro.runtime.state_store import _digest
from repro.runtime.store_server import StoreServer
from repro.configs import get, smoke_variant


def _mcfg(n_layers=2):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


@pytest.fixture(scope="module")
def server():
    srv = StoreServer().start()
    yield srv
    srv.stop()


@pytest.fixture()
def transport(server):
    tp = SocketTransport(server.address, schema=KeySchema(version=2))
    tp.reset_store()
    yield tp
    tp.close()


# ---------------------------------------------------------------------------
# wire plane
# ---------------------------------------------------------------------------

V2_MESSAGES = [
    ActivationMsg.tokens(0, 1),
    ActivationMsg(0, 1, stage=1, miner_uid=3),
    GradientMsg(0, 1, stage=1, miner_uid=3),
    WeightUploadMsg(0, stage=0, miner_uid=2),
    ShardUploadMsg(0, stage=0, miner_uid=2, shard=4),
    ShardReducedMsg(0, stage=0, shard=4, reducer_uid=2),
    AnchorMsg(0, stage=0),
    ScoreMsg(0, validator_uid=0, miner_uid=2),
]


def test_satisfies_transport_protocol(transport):
    assert isinstance(transport, Transport)


def test_every_message_roundtrips_with_digest(transport):
    rng = np.random.RandomState(0)
    for i, msg in enumerate(V2_MESSAGES):
        payload = rng.randn(16 + i).astype(np.float32)
        digest = transport.publish(msg, payload, actor=f"actor{i}")
        assert digest == _digest(payload)      # server digested same bytes
        got = transport.fetch(msg, actor=f"actor{i}")
        assert got.dtype == payload.dtype
        np.testing.assert_array_equal(got, payload)


def test_codec_dict_payload_roundtrips(transport):
    vec = np.random.RandomState(1).randn(700).astype(np.float32)
    payload = dict(compression.encode(vec, "int8"), shape=(700,))
    transport.put("weights/ep0/s0/m0/shard0", payload, actor="miner0")
    got = transport.get("weights/ep0/s0/m0/shard0", actor="miner1")
    assert got["codec"] == "int8" and got["shape"] == (700,)
    np.testing.assert_array_equal(
        np.asarray(compression.decode(got)),
        np.asarray(compression.decode(payload)))


def test_store_key_error_crosses_the_process_boundary(transport):
    transport.put("weights/ep0/s0/m0", np.zeros(4), actor="miner0")
    with pytest.raises(StoreKeyError) as ei:
        transport.get("weights/ep1/s0/merged", actor="miner3")
    err = ei.value
    assert isinstance(err, KeyError)
    assert err.key == "weights/ep1/s0/merged"
    assert err.actor == "miner3"
    assert err.nearest_prefix == "weights"
    assert "miner3" in str(err) and "weights" in str(err)
    with pytest.raises(StoreKeyError):
        transport.fetch(AnchorMsg(9, 0), actor="miner0")


def test_prefix_ops_match_in_process_semantics(transport):
    in_proc = InProcessTransport(schema=KeySchema(version=2))
    for tp in (transport, in_proc):
        for e in (1, 10):
            for t in (0, 1):
                tp.put(f"activations/ep{e}/t{t}/tokens", np.zeros(2))
    assert transport.keys("activations/ep1") == \
        in_proc.keys("activations/ep1")
    assert transport.delete_prefix("activations/ep1") == \
        in_proc.delete_prefix("activations/ep1") == 2
    assert transport.keys() == in_proc.keys()
    assert transport.exists("activations/ep10/t0/tokens")
    assert not transport.exists("activations/ep1/t0/tokens")


def test_server_survives_bad_requests(transport):
    # unknown op reports instead of killing the connection
    with pytest.raises(RuntimeError, match="UnknownOp"):
        transport._request({"op": "frobnicate"})
    transport.put("weights/ep0/s0/m1", np.zeros(4), actor="m")
    assert transport.exists("weights/ep0/s0/m1")   # connection still live


def test_unserializable_stored_payload_reports_instead_of_hanging(
        server, transport):
    # a shared in-process store can hold payloads serde cannot encode;
    # the get must come back as an error response, not a dead connection
    server.store.put("weights/ep0/s9/m0", {"obj": object()}, actor="local")
    with pytest.raises(RuntimeError, match="serialization failed"):
        transport.get("weights/ep0/s9/m0", actor="miner0")
    transport.put("weights/ep0/s9/m1", np.zeros(2), actor="m")
    assert transport.exists("weights/ep0/s9/m1")   # connection still live


def test_two_clients_share_one_store(server):
    a = SocketTransport(server.address)
    b = SocketTransport(server.address)
    a.reset_store()
    a.put("scores/ep0/v0/m1", np.asarray([1.0], np.float32), actor="v0")
    np.testing.assert_array_equal(
        b.get("scores/ep0/v0/m1", actor="v1"), [1.0])
    a.close()
    b.close()


def test_elapsed_and_wire_accounting_move(transport):
    before = transport.wire_report()["requests"]
    transport.put("weights/ep0/s0/m5", np.zeros(1024), actor="miner5")
    transport.get("weights/ep0/s0/m5", actor="miner6")
    wire = transport.wire_report()
    assert wire["requests"] == before + 2
    assert transport.elapsed_seconds() > 0.0
    links = transport.link_report()
    assert links["miner5"]["up_bytes"] == 1024 * 8
    assert links["miner6"]["down_bytes"] == 1024 * 8


# ---------------------------------------------------------------------------
# full swarm over the socket: trajectory + accounting parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["dense", "sharded"])
def parity(request, server):
    mode = request.param
    cfg = SwarmConfig(seed=0, n_stages=2, miners_per_stage=2, inner_steps=2,
                      b_min=1, batch_size=2, seq_len=16, validators=1,
                      sync_mode=mode)
    schema_v = 2 if mode == "sharded" else 1
    ref = Swarm.create(_mcfg(), cfg, transport=InProcessTransport(
        schema=KeySchema(version=schema_v)))
    ref_stats = ref.run(2)
    sim_tp = SimulatedNetworkTransport(NetworkModel.consumer(),
                                       schema=KeySchema(version=schema_v))
    sim_stats = Swarm.create(_mcfg(), cfg, transport=sim_tp).run(2)
    sock_tp = SocketTransport(server.address,
                              schema=KeySchema(version=schema_v))
    sock_tp.reset_store()
    sock = Swarm.create(_mcfg(), cfg, transport=sock_tp)
    sock_stats = sock.run(2)
    report = sock_tp.traffic_report()
    sock_tp.close()
    return mode, ref_stats, sim_tp, sim_stats, sock, sock_stats, report


def test_socket_swarm_reproduces_in_process_trajectory(parity):
    """Acceptance: the full epoch timeline over a real socket reproduces
    the InProcessTransport loss trajectory at the same seed, for both
    sync modes."""
    mode, ref_stats, _, _, _, sock_stats, _ = parity
    assert [s.mean_loss for s in sock_stats] == \
        [s.mean_loss for s in ref_stats], mode
    assert [s.b_eff for s in sock_stats] == [s.b_eff for s in ref_stats]
    assert [s.merged_stages for s in sock_stats] == \
        [s.merged_stages for s in ref_stats]


def test_server_accounting_matches_simulated_links(parity):
    """Acceptance: server-side traffic_report() per-actor bytes equal the
    SimulatedNetworkTransport link accounting for the same run."""
    mode, _, sim_tp, sim_stats, _, sock_stats, report = parity
    assert [s.mean_loss for s in sock_stats] == \
        [s.mean_loss for s in sim_stats], mode
    sim_links = sim_tp.link_report()
    assert sim_links, "simulated run recorded no links"
    for actor, s in sim_links.items():
        assert s["up_bytes"] == report["by_actor_up"].get(actor, 0), \
            (mode, actor)
        assert s["down_bytes"] == report["by_actor_down"].get(actor, 0), \
            (mode, actor)
    sim_store = sim_tp.store.traffic_report()
    assert report["uploaded"] == sim_store["uploaded"]
    assert report["downloaded"] == sim_store["downloaded"]


def test_sharded_wire_artifacts_reach_the_server(server):
    """The §5 store-and-forward reduce leaves its shard uploads + reduced
    copies on the REMOTE store — the trustless audit surface exists on
    the other side of the wire."""
    cfg = SwarmConfig(seed=0, n_stages=2, miners_per_stage=2, inner_steps=2,
                      b_min=1, batch_size=2, seq_len=16, validators=1,
                      sync_mode="sharded")
    tp = SocketTransport(server.address, schema=KeySchema(version=2))
    tp.reset_store()
    stats = Swarm.create(_mcfg(), cfg, transport=tp).run(1)
    kinds = {tp.schema.parse(k).kind for k in tp.keys("weights/")}
    assert {"shard_upload", "shard_reduced", "anchor"} <= kinds
    audits = stats[-1].reduce_audits
    assert audits and all(a.clean for a in audits)
    tp.close()


# ---------------------------------------------------------------------------
# separate-process deployment (spawn cost: slow-marked; smoke.sh covers it
# via examples/multiprocess_swarm.py)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_store_server_in_separate_process():
    import os

    from repro.runtime.store_server import spawn_store_server

    proc, addr = spawn_store_server()
    try:
        tp = SocketTransport(addr)
        pong = tp.ping()
        assert pong["pid"] == proc.pid != os.getpid()
        digest = tp.put("weights/ep0/s0/m0",
                        np.arange(8, dtype=np.float32), actor="miner0")
        got = tp.get("weights/ep0/s0/m0", actor="miner1")
        assert _digest(got) == digest
        with pytest.raises(StoreKeyError):
            tp.get("weights/ep1/s0/merged", actor="miner0")
        tp.stop_server()
        proc.join(timeout=10.0)
        assert proc.exitcode == 0
    finally:
        if proc.is_alive():  # pragma: no cover - cleanup on failure
            proc.terminate()
