"""Serve plane: decode timetable, v5 serve keys, StageProgram KV-cache
semantics, and the headline parity contract — the pipelined continuous-
batching ``ServeDriver`` emits tokens bit-identical to the sequential
``swarm_generate`` oracle at the same seed, on every transport.

Cheap tests run the driver in-process; one socket test pushes every
payload through a real ``StoreServer``; one slow-marked test spawns a
``ServeActor`` fleet.  The mid-flight admission regression pins the
continuous-batching invariant: admitting a request into a free lane
never changes tokens already streaming on other lanes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.keys import KeySchema
from repro.api.phases import ServeDriver, ServeRequest, StageServer
from repro.api.transport import InProcessTransport, SocketTransport
from repro.configs import get, smoke_variant
from repro.core.pipeline import ROLE_B, ROLE_F, ROLE_W, compile_timetable
from repro.launch.serve import build_servers, serve_swarm, swarm_generate
from repro.runtime import stage_model as sm
from repro.runtime.store_server import StoreServer


def _mcfg(n_layers):
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=n_layers)


def _spec(n_stages):
    return sm.SwarmModelSpec(_mcfg(n_layers=n_stages), n_stages)


def _prompts(spec, n, length, seed=1):
    return jax.random.randint(jax.random.key(seed), (n, length), 3,
                              spec.cfg.vocab_size, jnp.int32)


def _requests(spec, n, length, max_new=4, temperature=0.0):
    toks = _prompts(spec, n, length)
    return [ServeRequest(req=i, prompt=np.asarray(toks[i]), max_new=max_new,
                         temperature=temperature) for i in range(n)]


# ---------------------------------------------------------------------------
# decode timetable
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", [1, 2, 4])
@pytest.mark.parametrize("M", [1, 3, 8])
def test_decode_timetable_shape(P, M):
    tt = compile_timetable("decode", P, M)
    assert tt.n_slots == M + P - 1
    # forward-only: every cell is idle or F, lane m hits stage s at s + m
    roles = set(np.unique(tt.role).tolist())
    assert ROLE_B not in roles and ROLE_W not in roles
    for s in range(P):
        for m in range(M):
            t = s + m
            assert int(tt.role[s, t]) == ROLE_F
            assert int(tt.micro[s, t]) == m


def test_decode_timetable_ring_capacity_one():
    """Arrival slot == consumption slot: the decode schedule needs exactly
    one in-flight boundary payload per link, independent of lane count."""
    for M in (1, 4, 16):
        tt = compile_timetable("decode", 4, M)
        assert tt.z_ring == 1


def test_decode_bubble_fraction():
    tt = compile_timetable("decode", 4, 8)
    # (P-1)/(M+P-1) idle fraction per round
    assert abs(tt.bubble_fraction() - 3 / 11) < 1e-9


# ---------------------------------------------------------------------------
# v5 serve keys
# ---------------------------------------------------------------------------


def test_serve_keys_roundtrip():
    ks = KeySchema(version=5)
    for key, kind, fields in [
        (ks.serve_plan(), "serve_plan", {}),
        (ks.serve_round_plan(7), "serve_round_plan", {"round": 7}),
        (ks.serve_code(3, 1, 2), "serve_code",
         {"round": 3, "lane": 1, "stage": 2}),
        (ks.serve_request(9), "serve_request", {"req": 9}),
        (ks.serve_token(9, 4), "serve_token", {"req": 9, "index": 4}),
        (ks.serve_done(9), "serve_done", {"req": 9}),
    ]:
        parsed = ks.parse(key)
        assert parsed.kind == kind and parsed.fields == fields
    assert ks.serve_code(3, 1, 2).startswith(ks.serve_round_prefix(3))


def test_serve_keys_require_v5():
    with pytest.raises(ValueError):
        KeySchema(version=4).serve_plan()


# ---------------------------------------------------------------------------
# StageProgram serve plane
# ---------------------------------------------------------------------------


def test_stage_program_incremental_decode_matches_full_forward():
    """Prefill + token-at-a-time decode through the KV cache reproduces
    the no-cache forward on the same token stream (last-position logits)."""
    spec = _spec(1)
    prog = sm.StageProgram(spec, 0)
    params = sm.serve_stage_params(spec, 0, 0)
    toks = np.asarray(_prompts(spec, 1, 6))

    cache = prog.init_cache(1, 6)
    out = None
    for t in range(toks.shape[1]):
        out, cache = prog.decode_step(params, jnp.asarray(toks[:, t:t + 1]),
                                      cache)
    full = sm.stage_forward(params, jnp.asarray(toks), spec, "solo")
    np.testing.assert_allclose(np.asarray(out[0, -1], np.float32),
                               np.asarray(full[0, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_stage_server_lane_isolation():
    """Resetting / advancing one lane's cache leaves other lanes' caches
    bit-identical — the invariant admission safety rests on."""
    spec = _spec(1)
    srv = StageServer(spec, 0, sm.serve_stage_params(spec, 0, 0),
                      n_lanes=3, max_len=8)
    toks = jnp.asarray(_prompts(spec, 1, 4))
    _, srv.caches[1] = srv.program.decode_step(srv.params, toks,
                                               srv.caches[1])
    before = jax.tree.map(np.asarray, (srv.caches[0], srv.caches[2]))
    srv.reset_lane(1)
    _, srv.caches[1] = srv.program.decode_step(srv.params, toks,
                                               srv.caches[1])
    after = jax.tree.map(np.asarray, (srv.caches[0], srv.caches[2]))
    jax.tree.map(np.testing.assert_array_equal, before, after)


# ---------------------------------------------------------------------------
# greedy parity: pipelined driver == sequential oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("P", [1, 2, 4])
def test_greedy_parity_inprocess(P):
    spec = _spec(P)
    reqs = _requests(spec, 3, 5, max_new=4)
    records = serve_swarm(spec, reqs, n_lanes=2, max_len=9)
    oracle = swarm_generate(spec, 0, reqs)
    for r in reqs:
        assert records[r.req].tokens == oracle[r.req]


def test_parity_survives_temperature_sampling():
    """Sampling keys fold (seed, req, index) only — stochastic decode is
    reproducible and pipeline-order independent too."""
    spec = _spec(2)
    reqs = _requests(spec, 2, 5, max_new=4, temperature=0.8)
    records = serve_swarm(spec, reqs, n_lanes=2, max_len=9)
    oracle = swarm_generate(spec, 0, reqs)
    for r in reqs:
        assert records[r.req].tokens == oracle[r.req]


def test_greedy_parity_int8_wire():
    spec = _spec(2)
    reqs = _requests(spec, 2, 5, max_new=3)
    records = serve_swarm(spec, reqs, n_lanes=2, max_len=8,
                          wire_codec="int8")
    oracle = swarm_generate(spec, 0, reqs, wire_codec="int8")
    for r in reqs:
        assert records[r.req].tokens == oracle[r.req]


def test_greedy_parity_socket():
    """Every boundary code, round plan and token crosses a real socket
    store; the stream still bit-matches the oracle."""
    spec = _spec(2)
    reqs = _requests(spec, 3, 5, max_new=3)
    records = serve_swarm(spec, reqs, n_lanes=2, max_len=8,
                          transport="socket")
    oracle = swarm_generate(spec, 0, reqs)
    for r in reqs:
        assert records[r.req].tokens == oracle[r.req]


def test_mid_flight_admission_does_not_perturb_running_lanes():
    """Continuous batching: r2 arrives while r0/r1 are mid-decode and is
    admitted into the first freed lane.  r0/r1's tokens must be identical
    to a session where r2 never existed, and r2 still matches the oracle."""
    spec = _spec(2)
    base = _requests(spec, 3, 5, max_new=5)
    staggered = [dataclasses.replace(base[0]),
                 dataclasses.replace(base[1], max_new=2),
                 dataclasses.replace(base[2], arrival_round=1)]
    with_late = serve_swarm(spec, staggered, n_lanes=2, max_len=10)
    without = serve_swarm(spec, staggered[:2], n_lanes=2, max_len=10)
    for r in staggered[:2]:
        assert with_late[r.req].tokens == without[r.req].tokens
    oracle = swarm_generate(spec, 0, staggered)
    for r in staggered:
        assert with_late[r.req].tokens == oracle[r.req]


def test_driver_round_accounting_and_latency_records():
    spec = _spec(1)
    reqs = _requests(spec, 2, 4, max_new=3)
    tp = InProcessTransport(schema=KeySchema(version=5))
    driver = ServeDriver(spec, tp, n_lanes=2, max_len=7,
                         servers=build_servers(spec, 0, n_lanes=2,
                                               max_len=7))
    records = driver.run(reqs)
    # both lanes run all 3 tokens concurrently: exactly 3 rounds
    assert driver.rounds_run == 3
    for rec in records.values():
        assert len(rec.tokens) == 3
        assert rec.ttft is not None and rec.total is not None
        assert 0 <= rec.ttft <= rec.total
    # round-scoped keys are GC'd; the per-request artifacts remain
    assert not [k for k in tp.keys("serve/round")]
    assert tp.exists(tp.schema.serve_done(0))


@pytest.mark.slow
def test_greedy_parity_actor_fleet():
    """One spawned ServeActor process per stage, driven only by store
    plans — the fleet serves the oracle's exact token stream."""
    spec = _spec(2)
    reqs = _requests(spec, 2, 5, max_new=3)
    records = serve_swarm(spec, reqs, n_lanes=2, max_len=8,
                          transport="actors", timeout=300.0)
    oracle = swarm_generate(spec, 0, reqs)
    for r in reqs:
        assert records[r.req].tokens == oracle[r.req]
