"""Store-and-forward sharded butterfly sync (§5.1-5.3, KeySchema v2):

key schema round-trips, shard-coverage properties, executor correctness,
§5.3 byte accounting over SimulatedNetworkTransport, dense-vs-sharded
anchor parity, and store-side tamper detection."""
import dataclasses

import numpy as np
import pytest

try:        # the hypothesis property test skips alone, not the module
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    given = None

from repro.api import (
    KeySchema,
    NetworkModel,
    ShardReducedMsg,
    ShardUploadMsg,
    SimulatedNetworkTransport,
    Swarm,
    SwarmConfig,
    message_for_key,
)
from repro.configs import get, smoke_variant
from repro.core import butterfly
from repro.core.incentives import IncentiveLedger
from repro.runtime.network import FaultModel, MinerBehavior
from repro.runtime.validator import Validator


# ---------------------------------------------------------------------------
# KeySchema v2
# ---------------------------------------------------------------------------

V2_MESSAGES = [
    ShardUploadMsg(3, stage=1, miner_uid=7, shard=12),
    ShardReducedMsg(3, stage=1, shard=12, reducer_uid=5),
]


def test_v2_keys_layout():
    ks = KeySchema(version=2)
    assert ks.shard_upload(3, 1, 7, 12) == "weights/ep3/s1/m7/shard12"
    assert ks.shard_reduced(3, 1, 12, 5) == \
        "weights/ep3/s1/shard12/reduced/m5"
    assert ks.stage_weights_prefix(3, 1) == "weights/ep3/s1"


@pytest.mark.parametrize("msg", V2_MESSAGES, ids=lambda m: type(m).__name__)
def test_v2_key_parse_inverts_mint(msg):
    ks = KeySchema(version=2)
    assert message_for_key(msg.key(ks), ks) == msg


def test_v2_schema_still_mints_and_parses_v1_keys():
    v1, v2 = KeySchema(version=1), KeySchema(version=2)
    v1_keys = [v1.tokens(0, 2), v1.activation(0, 2, 1, 4),
               v1.gradient(0, 2, 1, 4), v1.weight_upload(1, 0, 3),
               v1.anchor(1, 0), v1.score(2, 1, 9)]
    for key in v1_keys:
        assert v2.parse(key) == v1.parse(key)
    # v1 minting methods produce byte-identical keys under v2
    assert v2.weight_upload(1, 0, 3) == v1.weight_upload(1, 0, 3)
    assert v2.anchor(1, 0) == v1.anchor(1, 0)


def test_v1_schema_rejects_v2_keys_and_minting():
    v1 = KeySchema(version=1)
    with pytest.raises(ValueError):
        v1.shard_upload(0, 0, 0, 0)
    with pytest.raises(ValueError):
        v1.shard_reduced(0, 0, 0, 0)
    with pytest.raises(ValueError):
        v1.parse("weights/ep0/s0/m1/shard2")
    with pytest.raises(ValueError):
        v1.parse("weights/ep0/s0/shard2/reduced/m1")


def test_shard_keys_cannot_shadow_v1_weight_upload():
    # the v1 weights pattern is anchored: shard keys never parse as it
    v2 = KeySchema(version=2)
    assert v2.parse("weights/ep0/s0/m1").kind == "weights"
    assert v2.parse("weights/ep0/s0/m1/shard2").kind == "shard_upload"


def _assert_covers_once_per_copy(n, length, align):
    """Every parameter index lands in exactly one shard, and every shard
    is assigned to exactly the two miners of its pair — i.e. the shard
    keys cover the vector once per redundant copy."""
    plan = butterfly.make_plan(n, length, seed=0, align=align)
    seen = np.zeros(length, np.int32)
    for s in range(plan.n_shards):
        lo, hi = plan.shard_bounds(s)
        assert 0 <= lo <= hi <= length
        if align > 1 and hi < length:
            assert lo % align == 0 and hi % align == 0
        seen[lo:hi] += 1
    assert (seen == 1).all()
    assignments = sum(len(plan.shards_of(m)) for m in range(n))
    assert assignments == 2 * plan.n_shards


@pytest.mark.parametrize("n,length,align", [
    (2, 1, 1), (4, 997, 1), (5, 4096, 256), (6, 1000, 256),
    (8, 300, 64), (3, 256, 256), (10, 5000, 256),
])
def test_shards_cover_vector_once_per_copy_sweep(n, length, align):
    _assert_covers_once_per_copy(n, length, align)


if given is not None:
    @given(n=st.integers(2, 10), length=st.integers(1, 5000),
           align=st.sampled_from([1, 64, 256]))
    @settings(max_examples=60, deadline=None)
    def test_shards_cover_vector_once_per_copy_property(n, length, align):
        _assert_covers_once_per_copy(n, length, align)


# ---------------------------------------------------------------------------
# executor: correctness + §5.3 byte accounting
# ---------------------------------------------------------------------------


def _run_executor(n, length, codec="none", tamper=None, skip_upload=()):
    tp = SimulatedNetworkTransport(NetworkModel.consumer(),
                                   schema=KeySchema(version=2))
    align = 256 if codec == "int8" else 1
    plan = butterfly.make_plan(n, length, seed=0, align=align)
    ex = butterfly.ButterflyExecutor(plan, tp, epoch=0, stage=0,
                                     uids=list(range(n)), codec=codec)
    vecs = {i: np.random.RandomState(i).randn(length).astype(np.float32)
            for i in range(n)}
    for i in range(n):
        if i not in skip_upload:
            ex.upload_vector(i, vecs[i], actor=f"miner{i}")
    for i in range(n):
        ex.run_reducer(i, actor=f"miner{i}",
                       tamper=(tamper or {}).get(i, 0.0))
    return tp, ex, vecs


def test_executor_reproduces_central_reduce():
    n, length = 5, 3000
    tp, ex, vecs = _run_executor(n, length)
    merged, valid, copies = ex.collect()
    assert valid.all()
    np.testing.assert_allclose(
        merged, np.mean([vecs[i] for i in range(n)], axis=0), atol=1e-5)
    # every shard has both redundant copies, and they agree
    assert len(copies) == 2 * ex.plan.n_shards
    agree = butterfly.agreement_matrix(ex.plan, copies)
    assert np.nanmin(agree) == 1.0


def test_executor_masks_missing_upload():
    n, length = 4, 1000
    tp, ex, vecs = _run_executor(n, length, skip_upload={2})
    merged, valid, _ = ex.collect()
    assert valid.all()                       # reducers alive: nothing lost
    want = np.mean([vecs[i] for i in range(n) if i != 2], axis=0)
    np.testing.assert_allclose(merged, want, atol=1e-5)


@pytest.mark.parametrize("n", [4, 6, 8])
def test_per_miner_bytes_match_closed_form(n):
    """SimulatedNetworkTransport per-miner accounted bytes = 4W + 2W/N
    within 5% (§5.3), fp32 payloads so W is unambiguous."""
    length = 100_000
    tp, ex, _ = _run_executor(n, length)
    merged, _, _ = ex.collect(actor="orchestrator")
    anchor_key = tp.schema.anchor(0, 0)
    tp.put(anchor_key, merged, actor="orchestrator")
    for i in range(n):
        tp.get(anchor_key, actor=f"miner{i}")
    w = length * 4
    closed = 4 * w + 2 * w / n
    rep = tp.link_report()
    for i in range(n):
        per = rep[f"miner{i}"]["up_bytes"] + rep[f"miner{i}"]["down_bytes"]
        assert abs(per - closed) / closed < 0.05, (n, i, per, closed)


def test_tampered_copy_does_not_poison_anchor():
    """Consensus-weighted assembly: when a shard's two copies disagree,
    collect() takes the copy from the reducer in better consensus, so a
    single tamperer cannot poison the merged anchor — it still equals the
    honest mean (= the dense oracle's merged vector)."""
    n, length = 6, 1200
    tp, ex, vecs = _run_executor(n, length, tamper={2: 0.5})
    merged, valid, _ = ex.collect()
    assert valid.all()
    np.testing.assert_allclose(
        merged, np.mean([vecs[i] for i in range(n)], axis=0), atol=1e-5)


def test_store_agreement_flags_tampering_reducer():
    n, length = 6, 1200
    tp, ex, _ = _run_executor(n, length, tamper={2: 0.5})
    uids, agree = butterfly.store_agreement(tp, 0, 0)
    assert uids == list(range(n))
    off = agree[2][np.arange(n) != 2]
    assert np.nanmax(off) == 0.0             # disagrees with every partner
    honest = [i for i in range(n) if i != 2]
    sub = agree[np.ix_(honest, honest)]
    assert np.nanmin(sub[~np.eye(n - 1, dtype=bool)]) == 1.0


def test_store_agreement_isolates_stage_prefix():
    """'weights/ep0/s1' is a plain string prefix of stage-12 keys: the
    audit must filter on the parsed stage, not just the prefix walk."""
    n, length = 4, 400
    tp = SimulatedNetworkTransport(NetworkModel.consumer(),
                                   schema=KeySchema(version=2))
    for stage, tamper in ((1, {2: 0.5}), (12, None)):
        plan = butterfly.make_plan(n, length, seed=0)
        ex = butterfly.ButterflyExecutor(plan, tp, epoch=0, stage=stage,
                                         uids=list(range(n)), codec="none")
        for i in range(n):
            ex.upload_vector(
                i, np.random.RandomState(i).randn(length).astype(np.float32),
                actor=f"miner{i}")
        for i in range(n):
            ex.run_reducer(i, actor=f"miner{i}",
                           tamper=(tamper or {}).get(i, 0.0))
    uids, agree = butterfly.store_agreement(tp, 0, 1)
    assert uids == list(range(n))
    assert np.nanmax(agree[2][np.arange(n) != 2]) == 0.0   # stage-1 tamperer
    uids12, agree12 = butterfly.store_agreement(tp, 0, 12)
    assert uids12 == list(range(n))
    assert np.nanmin(agree12) == 1.0                       # stage 12 clean


def test_replay_reduce_scores_missing_inputs_as_failed():
    """A reduce item whose inputs vanished from the store (GC'd or
    fabricated keys) is unverifiable — scored failed, never a crash."""
    from repro.runtime.miner import ReduceWorkItem

    tp = SimulatedNetworkTransport(NetworkModel.consumer(),
                                   schema=KeySchema(version=2))

    class _M:
        reduce_log = [ReduceWorkItem(
            0, ("weights/ep9/s0/m0/shard0", "weights/ep9/s0/m1/shard0"),
            "weights/ep9/s0/shard0/reduced/m0")]

    v = Validator(0, tp, IncentiveLedger(10.0))
    checked, passed, min_cos = v.replay_reduce(_M())
    assert (checked, passed) == (1, 0) and min_cos < 0.99


def test_validator_replay_reduce_catches_tamper():
    """Replaying the reduce log from store inputs: honest copies match,
    a tampered copy misses the cosine threshold."""
    from repro.runtime import stage_model as sm  # noqa: F401 (import check)

    class _FakeMiner:
        def __init__(self, uid, actor):
            self.uid, self.actor = uid, actor
            self.reduce_log = []

        def run_reduce(self, executor, idx, tamper=0.0):
            from repro.runtime.miner import ReduceWorkItem
            done = executor.run_reducer(idx, actor=self.actor, tamper=tamper)
            self.reduce_log.extend(
                ReduceWorkItem(a.shard, a.upload_keys, a.reduced_key)
                for a in done)

    n, length = 4, 800
    tp = SimulatedNetworkTransport(NetworkModel.consumer(),
                                   schema=KeySchema(version=2))
    plan = butterfly.make_plan(n, length, seed=0)
    ex = butterfly.ButterflyExecutor(plan, tp, epoch=0, stage=0,
                                     uids=list(range(n)), codec="none")
    for i in range(n):
        vec = np.random.RandomState(i).randn(length).astype(np.float32)
        ex.upload_vector(i, vec, actor=f"miner{i}")
    miners = [_FakeMiner(i, f"miner{i}") for i in range(n)]
    for i, m in enumerate(miners):
        m.run_reduce(ex, i, tamper=0.7 if i == 1 else 0.0)
    v = Validator(0, tp, IncentiveLedger(10.0))
    checked, passed, min_cos = v.replay_reduce(miners[0])
    assert checked == n - 1 and passed == checked and min_cos > 0.99
    checked, passed, min_cos = v.replay_reduce(miners[1])
    assert checked == n - 1 and passed == 0 and min_cos < 0.99


# ---------------------------------------------------------------------------
# swarm-level: dense oracle parity + scenario audit
# ---------------------------------------------------------------------------


def _mcfg():
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)


def _anchor_vecs(swarm):
    import jax
    import jax.numpy as jnp
    from jax.flatten_util import ravel_pytree
    return [np.asarray(ravel_pytree(jax.tree.map(
        lambda x: x.astype(jnp.float32), a))[0]) for a in swarm.anchors]


_BASE = dict(seed=0, n_stages=2, miners_per_stage=3, inner_steps=2,
             b_min=0, validators=1)


@pytest.fixture(scope="module")
def parity_runs():
    dense = Swarm.create(_mcfg(), SwarmConfig(**_BASE))
    dense_stats = dense.run(2)
    sharded = Swarm.create(_mcfg(),
                           SwarmConfig(**_BASE, sync_mode="sharded"))
    sharded_stats = sharded.run(2)
    return dense, dense_stats, sharded, sharded_stats


def test_sharded_anchors_match_dense_oracle(parity_runs):
    """Acceptance: store-and-forward sync reproduces the dense merged
    anchors to <= 1e-6 per stage (int8 share codec, block-aligned)."""
    dense, dense_stats, sharded, sharded_stats = parity_runs
    assert [s.merged_stages for s in sharded_stats] == \
        [s.merged_stages for s in dense_stats]
    assert sharded_stats[-1].merged_stages == 2
    for d, s in zip(_anchor_vecs(dense), _anchor_vecs(sharded)):
        assert np.abs(d - s).max() <= 1e-6


def test_sharded_trajectory_matches_dense(parity_runs):
    _, dense_stats, _, sharded_stats = parity_runs
    assert [s.mean_loss for s in sharded_stats] == \
        [s.mean_loss for s in dense_stats]
    assert [s.b_eff for s in sharded_stats] == \
        [s.b_eff for s in dense_stats]


def test_sharded_sync_populates_store_and_logs(parity_runs):
    _, _, sharded, sharded_stats = parity_runs
    schema = sharded.transport.schema
    kinds = {schema.parse(k).kind
             for k in sharded.transport.keys("weights/")}
    assert {"shard_upload", "shard_reduced", "anchor"} <= kinds
    # no dense weight uploads in sharded mode
    assert "weights" not in kinds
    # reducers logged their work for replay
    assert any(m.reduce_log for m in sharded.miners.values())
    # clean audit: every stage audited, nobody flagged
    audits = sharded_stats[-1].reduce_audits
    assert {a.stage for a in audits} == {0, 1}
    assert all(a.clean for a in audits)
    # agreement matrices ride EpochStats exactly like the dense path
    assert set(sharded_stats[-1].agreement) == {0, 1}


def test_scenario_tampering_reducer_flagged_by_validator():
    """Acceptance: a weight-tampering miner is flagged from the store's
    redundant reduced copies alone (ReduceAuditPhase -> audit_reduce)."""
    bad_uid = 1
    faults = FaultModel({bad_uid: MinerBehavior(tamper_weights=0.5)}, seed=0)
    swarm = Swarm.create(_mcfg(),
                         SwarmConfig(**_BASE, sync_mode="sharded"),
                         faults=faults)
    stats = swarm.run(1)
    audits = [a for a in stats[-1].reduce_audits if a.stage == 0]
    assert audits and all(bad_uid in a.flagged for a in audits)
    honest = [u for a in audits for u in a.uids if u != bad_uid]
    assert all(u not in a.flagged for a in audits for u in honest)


def test_sharded_swarm_rejects_v1_transport():
    tp = SimulatedNetworkTransport(NetworkModel.consumer())   # v1 schema
    with pytest.raises(ValueError, match="KeySchema v2"):
        Swarm.create(_mcfg(), SwarmConfig(**_BASE, sync_mode="sharded"),
                     transport=tp)
