"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.common import cosine_similarity, stable_hash, tree_flatten_to_vector
from repro.core import butterfly, compression, diloco
from repro.core.incentives import IncentiveLedger


@given(st.integers(2, 24), st.integers(10, 5000), st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_butterfly_plan_invariants(n, length, seed):
    plan = butterfly.make_plan(n, length, seed)
    # every shard assigned to exactly 2 distinct miners
    for (i, j) in plan.pairs:
        assert 0 <= i < n and 0 <= j < n and i != j
    # shard bounds tile [0, length) exactly
    total = sum(plan.shard_bounds(s)[1] - plan.shard_bounds(s)[0]
                for s in range(plan.n_shards))
    assert total == length
    # reduction load is balanced: each miner reduces exactly N-1 shards
    assert all(len(plan.shards_of(m)) == n - 1 for m in range(n))


@given(st.integers(2, 10), st.integers(0, 10), st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_butterfly_failures_match_formula_empirically(n, k_raw, seed):
    k = min(k_raw, n)
    rng = np.random.RandomState(seed)
    faulty = list(rng.choice(n, size=k, replace=False))
    plan = butterfly.make_plan(n, 64 * plan_len(n), seed)
    uploads = {m: np.ones(plan.vector_len, np.float32) for m in range(n)}
    ok = [m not in faulty for m in range(n)]
    _, valid, _ = butterfly.reduce_shards(plan, uploads, reducer_ok=ok)
    assert abs(valid.mean() - butterfly.valid_shard_fraction(n, k)) < 1e-9


def plan_len(n):
    return n * (n - 1) // 2


@given(st.sampled_from(["none", "bf16", "int8"]),
       st.integers(0, 20), st.floats(0.1, 100.0))
@settings(max_examples=40, deadline=None)
def test_codec_relative_error_bound(codec, seed, scale):
    v = jnp.asarray(np.random.RandomState(seed).randn(1024) * scale,
                    jnp.float32)
    r = compression.decode(compression.encode(v, codec), 1024)
    rel = float(jnp.max(jnp.abs(r - v))) / (float(jnp.max(jnp.abs(v))) + 1e-9)
    bound = {"none": 1e-7, "bf16": 0.01, "int8": 0.01}[codec]
    assert rel <= bound


@given(st.integers(0, 30))
@settings(max_examples=20, deadline=None)
def test_cosine_similarity_range_and_self(seed):
    rng = np.random.RandomState(seed)
    a = jnp.asarray(rng.randn(100), jnp.float32)
    b = jnp.asarray(rng.randn(100), jnp.float32)
    c = float(cosine_similarity(a, b))
    assert -1.0 - 1e-5 <= c <= 1.0 + 1e-5
    assert float(cosine_similarity(a, a)) == 1.0 or abs(
        float(cosine_similarity(a, a)) - 1.0) < 1e-5


@given(st.lists(st.tuples(st.integers(0, 7), st.floats(0, 100)),
                min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_emissions_sum_to_total_and_nonnegative(scores):
    led = IncentiveLedger(gamma=1000.0)
    for i, (m, s) in enumerate(scores):
        led.record(m, 0, s, 0.0)
    em = led.emissions(1.0, total_emission=1.0)
    assert abs(sum(em.values()) - 1.0) < 1e-6
    assert all(v >= 0 for v in em.values())


@given(st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_diloco_outer_reduces_to_average_with_lr1_no_momentum(seed):
    """DiLoCo with outer_lr=1, momentum=0 sets the anchor to avg(workers)."""
    rng = np.random.RandomState(seed)
    params = {"w": jnp.asarray(rng.randn(16), jnp.float32)}
    avg = {"w": jnp.asarray(rng.randn(16), jnp.float32)}
    out = diloco.outer_update(diloco.outer_init(params), avg,
                              outer_lr=1.0, outer_momentum=0.0)
    np.testing.assert_allclose(np.asarray(out.anchor["w"]),
                               np.asarray(avg["w"]), atol=1e-6)


@given(st.integers(0, 100), st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_stable_hash_deterministic_and_distinct(a, b):
    assert stable_hash("x", a, b) == stable_hash("x", a, b)
    if a != b:
        assert stable_hash("x", a) != stable_hash("x", b)


@given(st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_tree_flatten_roundtrip(seed):
    rng = np.random.RandomState(seed)
    tree = {"a": jnp.asarray(rng.randn(3, 4), jnp.float32),
            "b": {"c": jnp.asarray(rng.randn(7), jnp.bfloat16)}}
    vec, unflatten = tree_flatten_to_vector(tree)
    back = unflatten(vec)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-2)
        assert x.dtype == y.dtype


@given(st.integers(2, 12), st.integers(1, 40), st.floats(0.1, 0.9))
@settings(max_examples=30, deadline=None)
def test_beff_quorum_properties(n_miners, b_min, quorum):
    rng = np.random.RandomState(n_miners * 7 + b_min)
    batches = {m: int(rng.randint(0, 3 * b_min)) for m in range(n_miners)}
    beff = diloco.effective_batch(batches, b_min)
    assert beff == sum(b for b in batches.values() if b >= b_min)
    if diloco.should_merge(batches, b_min, quorum):
        qual = sum(1 for b in batches.values() if b >= b_min)
        assert qual >= max(1, int(n_miners * quorum))
