"""Schedule-equivalence + boundary-codec suite (PR 2, runs on CPU).

In-process tests validate the new fused Pallas boundary kernels and the
int8 wire codec against the ``kernels/ref.py`` oracles (interpret mode),
plus the honest wire-byte/stash accounting.  The subprocess test (marked
slow, like tests/test_multidevice.py — the stage count must be fixed
before jax initialises) checks that the explicit-backward 1F1B schedule
reproduces the GPipe golden loss AND gradients, per wire codec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from conftest import run_py
from repro.kernels import bottleneck_fused as bf
from repro.kernels import quant_stream as qs
from repro.kernels import ref

RNG = np.random.RandomState(0)


# ---------------------------------------------------------------------------
# fused gated decode (pipeline stage entry) vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 8, 128), (2, 17, 256), (3, 33, 512)])
@pytest.mark.parametrize("db", [16, 32])
def test_decode_gated_sweep(shape, db):
    d = shape[-1]
    z = jnp.asarray(RNG.randn(*shape[:-1], db), jnp.float32)
    w = jnp.asarray(RNG.randn(db, d) * 0.1, jnp.float32)
    a = jnp.asarray(0.7, jnp.float32)
    got = bf.bottleneck_decode_gated(z, w, a, out_dtype=jnp.float32,
                                     interpret=True)
    want = ref.bottleneck_decode_gated(z, w, a, out_dtype=jnp.float32)
    assert got.shape == shape[:-1] + (d,)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_decode_gated_grad_matches_ref():
    z = jnp.asarray(RNG.randn(6, 16), jnp.float32)
    w = jnp.asarray(RNG.randn(16, 128) * 0.1, jnp.float32)
    a = jnp.asarray(0.5, jnp.float32)

    def k(z, w, a):
        return jnp.sum(jnp.square(bf.bottleneck_decode_gated(
            z, w, a, out_dtype=jnp.float32, interpret=True)))

    def r(z, w, a):
        return jnp.sum(jnp.square(ref.bottleneck_decode_gated(
            z, w, a, out_dtype=jnp.float32)))

    gk = jax.grad(k, argnums=(0, 1, 2))(z, w, a)
    gr = jax.grad(r, argnums=(0, 1, 2))(z, w, a)
    for x, y in zip(gk, gr):
        assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# int8 wire codec: roundtrip oracle + straight-through symmetric backward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(2, 16, 8), (4, 16, 32), (3, 7, 16)])
def test_int8_wire_roundtrip_matches_oracle(shape):
    z = jnp.asarray(RNG.randn(*shape) * 3, jnp.float32)
    got = qs.int8_wire_roundtrip(z, interpret=True)
    want = ref.int8_wire_roundtrip(z)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)
    # quantization error bounded by half an LSB of the per-block scale
    err = np.abs(np.asarray(got) - np.asarray(z))
    assert err.max() <= float(jnp.max(jnp.abs(z))) / 127.0


def test_int8_wire_backward_quantizes_cotangent():
    """The custom_vjp ships gradients through the same int8 wire: the
    pulled-back cotangent equals the roundtripped cotangent (and is NOT the
    identity for a non-representable cotangent)."""
    z = jnp.asarray(RNG.randn(2, 8, 16), jnp.float32)
    g = jnp.asarray(RNG.randn(2, 8, 16) * 2, jnp.float32)
    _, vjp = jax.vjp(lambda z: qs.int8_wire_roundtrip(z, interpret=True), z)
    (gz,) = vjp(g)
    assert_allclose(np.asarray(gz), np.asarray(ref.int8_wire_roundtrip(g)),
                    rtol=1e-6, atol=1e-7)
    assert float(jnp.max(jnp.abs(gz - g))) > 0.0


def test_wire_block_selection():
    assert qs.wire_block(1024, 32) == 256       # 256 divides
    assert qs.wire_block(336, 16) == 16         # falls back to the code row
    assert ref.wire_code_block(1024, 32) == 256


# ---------------------------------------------------------------------------
# honest accounting: wire bytes per hop + schedule stats
# ---------------------------------------------------------------------------


def _mcfg():
    import dataclasses

    from repro.configs import get, smoke_variant
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=4)


def test_int8_wire_bytes_cut_at_least_1p9x():
    from repro.core.pipeline import PipelineSpec, wire_bytes_per_hop
    cfg = _mcfg()
    bf16 = PipelineSpec(4, 8, bottleneck_dim=32, wire_dtype=jnp.bfloat16)
    int8 = PipelineSpec(4, 8, bottleneck_dim=32, wire_codec="int8")
    b_bf16 = wire_bytes_per_hop(cfg, bf16, global_batch=64, seq=128)
    b_int8 = wire_bytes_per_hop(cfg, int8, global_batch=64, seq=128)
    n = 64 * 128 * 32
    assert b_bf16 == n * 2
    assert b_int8 == n + (n // 256) * 4         # scales accounted
    assert b_bf16 / b_int8 >= 1.9


def test_1f1b_stash_smaller_at_2x_microbatches():
    from repro.core.pipeline import PipelineSpec, schedule_stats
    cfg = _mcfg()
    kw = dict(n_microbatches=8, compress=True, bottleneck_dim=16)
    g = schedule_stats(cfg, PipelineSpec(n_stages=4, **kw), 8, 32)
    f = schedule_stats(cfg, PipelineSpec(n_stages=4, schedule="1f1b", **kw),
                       8, 32)
    # GPipe's checkpointed tick scan stashes one code per tick; the 1F1B
    # ring is capped at n_stages codes
    assert g["stash_codes"] == 8 + 4 - 1
    assert f["stash_codes"] == 4
    assert f["stash_bytes"] < g["stash_bytes"]
    assert f["bubble_fraction"] == g["bubble_fraction"]


def test_pipeline_spec_validation():
    from repro.core.pipeline import PipelineSpec, ScheduleError
    with pytest.raises(ScheduleError):
        PipelineSpec(2, 4, schedule="zb-h1")          # not in the registry
    with pytest.raises(ScheduleError):
        PipelineSpec(2, 4, schedule="interleaved")    # needs virtual_stages>1
    with pytest.raises(ScheduleError):
        PipelineSpec(2, 4, schedule="1f1b", virtual_stages=2)
    with pytest.raises(ScheduleError):
        # interleaved microbatches must split into groups of n_stages
        PipelineSpec(2, 5, schedule="interleaved", virtual_stages=2)
    with pytest.raises(AssertionError):
        PipelineSpec(2, 4, wire_codec="fp4")
    with pytest.raises(AssertionError):
        PipelineSpec(2, 4, compress=False, wire_codec="int8")
    # the valid corner constructs (and caches its compiled timetable)
    spec = PipelineSpec(2, 4, schedule="interleaved", virtual_stages=2)
    assert spec.n_chunks == 4
    assert spec.timetable().n_slots >= 2 * (2 * 4 + 2 - 1)


# ---------------------------------------------------------------------------
# schedule compiler: timetable validity, bubble targets, stash accounting
# ---------------------------------------------------------------------------

try:        # the hypothesis property test skips alone, not the module
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    given = None


def _assert_timetable_valid(tt):
    """The compiled-timetable contract, re-derived independently of the
    compiler's own _check pass: per-unit ordering F < B (< W), one-slot
    hop transit, matched sends, and ring occupancy within capacity."""
    from repro.core.pipeline import ROLE_B, ROLE_F, ROLE_W
    C, M, P = tt.n_chunks, tt.n_micro, tt.n_stages
    f, b, w = tt.f_slot, tt.b_slot, tt.w_slot
    has_w = (w >= 0).any()
    for c in range(C):
        d = c % P
        for m in range(M):
            assert 0 <= f[c, m] < b[c, m] < tt.n_slots
            if has_w:
                assert b[c, m] < w[c, m] < tt.n_slots
            # one-slot transit: a send is consumable the *next* slot
            if c > 0:
                assert f[c, m] >= f[c - 1, m] + 1
                # ...and the matching receive is recorded for the ring
                assert tt.z_arrive[d, f[c - 1, m] + 1] >= 0
            if c < C - 1:
                assert b[c, m] >= b[c + 1, m] + 1
                assert tt.g_arrive[d, b[c + 1, m] + 1] >= 0
            else:
                assert b[c, m] >= f[c, m] + 1
    # every work unit occupies exactly one (device, slot) cell
    assert (tt.role == ROLE_F).sum() == C * M
    assert (tt.role == ROLE_B).sum() == C * M
    assert (tt.role == ROLE_W).sum() == (C * M if has_w else 0)
    # ring stash never exceeds its declared capacity (interval counting)
    for d in range(P):
        events = []
        for c in range(d if d else P, C, P):   # chunks on d with c > 0
            for m in range(M):
                last = w[c, m] if has_w else b[c, m]
                events += [(f[c - 1, m] + 1, 1), (last + 1, -1)]
        cur = peak = 0
        for _, delta in sorted(events):
            cur += delta
            peak = max(peak, cur)
        assert peak <= tt.z_ring, (d, peak, tt.z_ring)


_GRID = [("gpipe", 2, 2, 1), ("gpipe", 3, 6, 1), ("gpipe", 4, 9, 1),
         ("1f1b", 2, 4, 1), ("1f1b", 2, 7, 1), ("1f1b", 3, 6, 1),
         ("1f1b", 4, 4, 1), ("1f1b", 4, 8, 1),
         ("zerobubble", 2, 4, 1), ("zerobubble", 3, 6, 1),
         ("zerobubble", 4, 8, 1), ("zerobubble", 4, 16, 1),
         ("interleaved", 2, 2, 2), ("interleaved", 2, 4, 3),
         ("interleaved", 3, 6, 2), ("interleaved", 4, 8, 2),
         ("interleaved", 4, 8, 4)]


@pytest.mark.parametrize("schedule,P,M,V", _GRID)
def test_compiled_timetable_is_valid(schedule, P, M, V):
    from repro.core.pipeline import compile_timetable
    _assert_timetable_valid(compile_timetable(schedule, P, M, V))


@pytest.mark.skipif(given is None, reason="property test needs hypothesis")
@settings(max_examples=40, deadline=None) if given else (lambda f: f)
@given(st.data()) if given else (lambda f: f)
def test_compiled_timetable_property(data):
    from repro.core.pipeline import SCHEDULES, compile_timetable
    schedule = data.draw(st.sampled_from(SCHEDULES))
    P = data.draw(st.integers(2, 5))
    V = data.draw(st.integers(2, 4)) if schedule == "interleaved" else 1
    if schedule == "interleaved":
        M = P * data.draw(st.integers(1, 3))
    else:
        M = data.draw(st.integers(1, 12))
    _assert_timetable_valid(compile_timetable(schedule, P, M, V))


def test_bubble_fraction_matches_closed_form():
    """gpipe/1f1b keep the (P-1)/(M+P-1) closed form, and schedule_stats
    now reports the timetable-*measured* idle fraction — both must agree."""
    from repro.core.pipeline import PipelineSpec, compile_timetable, \
        schedule_stats
    cfg = _mcfg()
    for schedule in ("gpipe", "1f1b"):
        for P, M in [(2, 4), (4, 8), (4, 4)]:
            tt = compile_timetable(schedule, P, M)
            closed = (P - 1) / (M + P - 1)
            assert abs(tt.bubble_fraction() - closed) < 1e-12
            if M >= P and cfg.n_layers % P == 0:
                spec = PipelineSpec(P, M, bottleneck_dim=16,
                                    schedule=schedule)
                stats = schedule_stats(cfg, spec, 8, 32)
                assert stats["bubble_fraction"] == \
                    pytest.approx(tt.bubble_fraction())


def test_new_schedules_shrink_the_bubble():
    """The acceptance targets at P=4/M=8: interleaved V=2 <= 0.158,
    zerobubble <= 0.14, both strictly below 1F1B's 0.2727."""
    from repro.core.pipeline import compile_timetable
    base = compile_timetable("1f1b", 4, 8).bubble_fraction()
    assert base == pytest.approx(3 / 11)
    inter = compile_timetable("interleaved", 4, 8, 2).bubble_fraction()
    zb = compile_timetable("zerobubble", 4, 8).bubble_fraction()
    assert inter <= 0.158 and inter < base
    # interleaved hits the (P-1)/(V*M+P-1) closed form exactly
    assert inter == pytest.approx(3 / 19)
    assert zb <= 0.14 and zb < base


def test_int8_stash_not_larger_than_bf16():
    """Regression pin for the BENCH_pipeline.json stash doubling: the
    explicit-schedule rings hold the int8 codes+scales pair, so the int8
    stash must come in *under* the bf16 stash, never above it."""
    from repro.core.pipeline import PipelineSpec, schedule_stats
    cfg = _mcfg()
    for schedule, V in [("1f1b", 1), ("zerobubble", 1), ("interleaved", 2)]:
        kw = dict(n_microbatches=8, bottleneck_dim=16, schedule=schedule,
                  virtual_stages=V)
        if V > 1:
            import dataclasses
            mcfg = dataclasses.replace(cfg, n_layers=8)
        else:
            mcfg = cfg
        s8 = schedule_stats(mcfg, PipelineSpec(
            4, wire_codec="int8", **kw), 8, 32)
        sb = schedule_stats(mcfg, PipelineSpec(
            4, wire_dtype=jnp.bfloat16, **kw), 8, 32)
        assert s8["stash_codes"] == sb["stash_codes"], schedule
        assert s8["stash_bytes"] <= sb["stash_bytes"], \
            (schedule, s8["stash_bytes"], sb["stash_bytes"])


def test_stage_model_virtual_chunk_partition():
    """The runtime-side (stage, v) -> chunk -> layers partition agrees
    with the pipeline engine's layout: chunk c = v * P + stage, layers
    covered exactly once in chunk order, and V == 1 degenerates to the
    seed's stage-granular mapping (role/layers_per_stage unchanged)."""
    from repro.runtime.stage_model import SwarmModelSpec
    cfg = _mcfg()   # 4 layers
    flat = SwarmModelSpec(cfg, 4)
    assert flat.n_chunks == 4 and flat.layers_per_chunk == 1
    assert [flat.role(s) for s in range(4)] == \
        ["first", "mid", "mid", "last"]
    assert list(flat.chunk_layers(2)) == [2]

    import dataclasses
    deep = SwarmModelSpec(dataclasses.replace(cfg, n_layers=8), 2,
                          n_virtual=2)
    assert deep.n_chunks == 4 and deep.layers_per_chunk == 2
    # interleaved layout: consecutive chunks on consecutive devices
    order = [(v * 2 + s, deep.chunk_index(s, v))
             for v in range(2) for s in range(2)]
    assert all(c == want for want, c in order)
    covered = [l for c in range(4)
               for l in deep.chunk_layers(c % 2, c // 2)]
    assert covered == list(range(8))
    assert deep.role(0, 0) == "first" and deep.role(1, 1) == "last"
    assert deep.role(0, 1) == "mid" and deep.role(1, 0) == "mid"


def test_swarm_config_mints_pipeline_spec():
    from repro.api.config import SwarmConfig
    sw = SwarmConfig(n_stages=4, bottleneck_dim=16,
                     pipeline_schedule="1f1b", wire_codec="int8",
                     pipeline_microbatches=8)
    spec = sw.pipeline_spec()
    assert (spec.n_stages, spec.schedule, spec.wire_codec) == (4, "1f1b",
                                                               "int8")
    assert spec.bottleneck_dim == 16
    # virtual stages ride through to the spec (and to its timetable)
    import dataclasses
    swv = dataclasses.replace(sw, pipeline_schedule="interleaved",
                              pipeline_virtual_stages=2)
    assert swv.pipeline_spec().n_chunks == 8
    # schedule names are validated against the compiler registry
    with pytest.raises(AssertionError):
        dataclasses.replace(sw, pipeline_schedule="zb-h1")


# ---------------------------------------------------------------------------
# swarm gradient wire (phases.TrainingPhase wire_codec="int8")
# ---------------------------------------------------------------------------


def test_swarm_int8_gradient_wire_trains_and_validates():
    import dataclasses

    from repro.api import Swarm, SwarmConfig
    base = SwarmConfig(n_stages=2, miners_per_stage=1, inner_steps=2,
                       b_min=1, batch_size=2, seq_len=16, validators=1,
                       seed=0)
    act_bytes = {}
    for codec in ("none", "int8"):
        swarm = Swarm.create(_mcfg(),
                             dataclasses.replace(base, wire_codec=codec))
        stats = swarm.run(1)
        assert np.isfinite(stats[-1].mean_loss)
        res = stats[-1].validation[0]
        # validator replay decodes the same int8 payloads the miner
        # trained on, so reproducibility auditing still passes
        assert res.passed == res.checked, (codec, res)
        rep = swarm.transport.traffic_report()
        act_bytes[codec] = rep["uploaded"]["activations"]
    # the gradient hand-offs ship as int8 codes: honest byte accounting
    # shows the activations namespace shrinking
    assert act_bytes["int8"] < act_bytes["none"], act_bytes


# ---------------------------------------------------------------------------
# schedule equivalence: 1F1B == GPipe golden (subprocess, 4 host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_1f1b_matches_gpipe_loss_and_grads():
    """Loss + every gradient leaf agree between the autodiff GPipe schedule
    and the explicit-backward 1F1B schedule, for each wire configuration
    (f32 wire tight, bf16/int8 at the same tolerance — the schedules share
    the boundary codecs, so agreement stays at float-roundoff level)."""
    out = run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get, smoke_variant
        from repro.core.pipeline import (PipelineSpec, init_pipeline_params,
                                         pipeline_loss_and_grads)
        cfg = dataclasses.replace(smoke_variant(get('llama3.2-1b')).model,
                                  n_layers=4)
        mesh = jax.make_mesh((1, 4), ('data', 'model'))
        B, S, M = 8, 16, 8
        r = np.random.RandomState(0)
        toks = r.randint(0, cfg.vocab_size, (B, S))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
        for tag, wd, codec in [("f32", jnp.float32, "none"),
                               ("bf16", jnp.bfloat16, "none"),
                               ("int8", jnp.bfloat16, "int8")]:
            spec = PipelineSpec(4, M, compress=True, bottleneck_dim=16,
                                wire_dtype=wd, wire_codec=codec)
            params = init_pipeline_params(jax.random.key(0), cfg, spec)
            with mesh:
                lg, gg = jax.jit(lambda p, b: pipeline_loss_and_grads(
                    p, b, cfg, spec, mesh))(params, batch)
                sp = dataclasses.replace(spec, schedule="1f1b")
                lf, gf = jax.jit(lambda p, b: pipeline_loss_and_grads(
                    p, b, cfg, sp, mesh))(params, batch)
            ff = {jax.tree_util.keystr(k): v for k, v
                  in jax.tree_util.tree_leaves_with_path(gf)}
            worst = 0.0
            for k, vg in jax.tree_util.tree_leaves_with_path(gg):
                vf = ff[jax.tree_util.keystr(k)]
                d = float(jnp.max(jnp.abs(vg.astype(jnp.float32)
                                          - vf.astype(jnp.float32))))
                sc = float(jnp.max(jnp.abs(vg.astype(jnp.float32)))) + 1e-8
                worst = max(worst, d / sc)
            print(f"RES {tag} {abs(float(lg) - float(lf)):.3e} {worst:.3e}")
    """)
    for line in out.splitlines():
        if not line.startswith("RES"):
            continue
        _, tag, dloss, dgrad = line.split()
        assert float(dloss) < 5e-6, (tag, dloss)
        assert float(dgrad) < 5e-5, (tag, dgrad)
    assert out.count("RES") == 3, out


@pytest.mark.slow
def test_new_schedules_match_gpipe_loss_and_grads():
    """zerobubble (same mesh) and interleaved (P=2 x V=2 over a 2-device
    subset mesh, against the *same* 4-chunk model gpipe runs as 4 stages)
    reproduce the GPipe golden loss and gradients per wire codec.  The
    interleaved comparison relies on init_pipeline_params folding RNG by
    global chunk index, so chunk c's params are identical whether laid out
    as gpipe stage c or interleaved slice [c % P, c // P]."""
    out = run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.core.pipeline import (PipelineSpec, init_pipeline_params,
                                         pipeline_loss_and_grads)
        cfg = dataclasses.replace(smoke_variant(get('llama3.2-1b')).model,
                                  n_layers=4)
        B, S, M = 8, 16, 4
        r = np.random.RandomState(0)
        toks = r.randint(0, cfg.vocab_size, (B, S))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}

        def leaves(g):
            return {jax.tree_util.keystr(k): np.asarray(v, np.float32)
                    for k, v in jax.tree_util.tree_leaves_with_path(g)}

        def worst_gap(fg, fo):
            worst = 0.0
            for k, vg in fg.items():
                vo = fo[k]
                if vo.shape != vg.shape:   # (P, V, ...) -> chunk order
                    vo = vo.transpose((1, 0) + tuple(range(2, vo.ndim))
                                      ).reshape(vg.shape)
                d = float(np.max(np.abs(vg - vo)))
                worst = max(worst, d / (float(np.max(np.abs(vg))) + 1e-8))
            return worst

        for tag, wd, codec in [("f32", jnp.float32, "none"),
                               ("bf16", jnp.bfloat16, "none"),
                               ("int8", jnp.bfloat16, "int8")]:
            kw = dict(compress=True, bottleneck_dim=16, wire_dtype=wd,
                      wire_codec=codec)
            golden = PipelineSpec(4, M, **kw)
            mesh4 = jax.make_mesh((1, 4), ('data', 'model'))
            pg = init_pipeline_params(jax.random.key(0), cfg, golden)
            with mesh4:
                lg, gg = jax.jit(lambda p, b: pipeline_loss_and_grads(
                    p, b, cfg, golden, mesh4))(pg, batch)
                zb = dataclasses.replace(golden, schedule="zerobubble")
                lz, gz = jax.jit(lambda p, b: pipeline_loss_and_grads(
                    p, b, cfg, zb, mesh4))(pg, batch)
            il = PipelineSpec(2, M, schedule="interleaved",
                              virtual_stages=2, **kw)
            mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(1, 2),
                         ('data', 'model'))
            pi = init_pipeline_params(jax.random.key(0), cfg, il)
            with mesh2:
                li, gi = jax.jit(lambda p, b: pipeline_loss_and_grads(
                    p, b, cfg, il, mesh2))(pi, batch)
            fg = leaves(gg)
            print(f"RES {tag} zerobubble {abs(float(lg)-float(lz)):.3e} "
                  f"{worst_gap(fg, leaves(gz)):.3e}")
            print(f"RES {tag} interleaved {abs(float(lg)-float(li)):.3e} "
                  f"{worst_gap(fg, leaves(gi)):.3e}")
    """)
    for line in out.splitlines():
        if not line.startswith("RES"):
            continue
        _, tag, sched, dloss, dgrad = line.split()
        assert float(dloss) < 5e-6, (tag, sched, dloss)
        assert float(dgrad) < 5e-5, (tag, sched, dgrad)
    assert out.count("RES") == 6, out


@pytest.mark.slow
def test_fused_boundary_matches_unfused_in_pipeline():
    """fuse_boundary=True (Pallas interpret kernels) and the inline-jnp
    boundary path agree through the full GPipe pipeline — the kernels are a
    drop-in for the hot path, not a different computation."""
    out = run_py("""
        import os
        os.environ["REPRO_FORCE_PALLAS_INTERPRET"] = "1"   # kernels, not oracle
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get, smoke_variant
        from repro.core.pipeline import (PipelineSpec, init_pipeline_params,
                                         pipeline_loss_fused)
        cfg = dataclasses.replace(smoke_variant(get('llama3.2-1b')).model,
                                  n_layers=4)
        mesh = jax.make_mesh((1, 4), ('data', 'model'))
        B, S, M = 8, 16, 4
        r = np.random.RandomState(1)
        toks = r.randint(0, cfg.vocab_size, (B, S))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
        losses = []
        for fuse in (True, False):
            spec = PipelineSpec(4, M, compress=True, bottleneck_dim=16,
                                wire_dtype=jnp.float32, fuse_boundary=fuse)
            params = init_pipeline_params(jax.random.key(0), cfg, spec)
            with mesh:
                # f32 compute: at bf16 the paths differ by one legitimate
                # rounding (the unfused decode casts before the alpha gate)
                l = jax.jit(lambda p, b: pipeline_loss_fused(
                    p, b, cfg, spec, mesh,
                    compute_dtype=jnp.float32))(params, batch)
            losses.append(float(l))
        print("DIFF", abs(losses[0] - losses[1]))
    """)
    assert float(out.split("DIFF")[1].strip()) < 1e-5
