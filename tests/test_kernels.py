"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,

run under interpret=True on CPU (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import (
    bottleneck_fused as bf,
    decode_attention as da,
    flash_attention as fa,
    quant_stream as qs,
    ref,
    shard_merge as sm,
)

RNG = np.random.RandomState(0)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# bottleneck encode / decode
# ---------------------------------------------------------------------------

ENC_SHAPES = [(1, 8, 128), (2, 17, 256), (4, 64, 512), (3, 33, 1024)]


@pytest.mark.parametrize("shape", ENC_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("db", [16, 32])
def test_bottleneck_encode_sweep(shape, dtype, db):
    d = shape[-1]
    x = jnp.asarray(RNG.randn(*shape), dtype)
    gamma = jnp.asarray(RNG.rand(d) + 0.5, jnp.float32)
    w = jnp.asarray(RNG.randn(d, db) * 0.05, jnp.float32)
    got = bf.bottleneck_encode(x, gamma, w, wire_dtype=jnp.float32,
                               interpret=True)
    want = ref.bottleneck_encode(x, gamma, w, wire_dtype=jnp.float32)
    assert got.shape == shape[:-1] + (db,)
    assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", ENC_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_decode_sweep(shape, dtype):
    d = shape[-1]
    db = 32
    z = jnp.asarray(RNG.randn(*shape[:-1], db), dtype)
    w = jnp.asarray(RNG.randn(db, d) * 0.1, jnp.float32)
    r = jnp.asarray(RNG.randn(*shape), dtype)
    a = jnp.asarray(0.5, jnp.float32)
    got = bf.bottleneck_decode(z, w, r, a, out_dtype=jnp.float32,
                               interpret=True)
    want = ref.bottleneck_decode(z, w, r, a, out_dtype=jnp.float32)
    assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


def test_bottleneck_encode_grad_matches_ref():
    x = jnp.asarray(RNG.randn(6, 128), jnp.float32)
    gamma = jnp.asarray(RNG.rand(128) + 0.5, jnp.float32)
    w = jnp.asarray(RNG.randn(128, 16) * 0.1, jnp.float32)

    def k(x, g, w):
        return jnp.sum(jnp.square(bf.bottleneck_encode(
            x, g, w, wire_dtype=jnp.float32, interpret=True)))

    def r(x, g, w):
        return jnp.sum(jnp.square(ref.bottleneck_encode(
            x, g, w, wire_dtype=jnp.float32)))

    gk = jax.grad(k, argnums=(0, 1, 2))(x, gamma, w)
    gr = jax.grad(r, argnums=(0, 1, 2))(x, gamma, w)
    for a, b in zip(gk, gr):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    # (B, Sq, Skv, H, KH, D, causal, offset)
    (1, 64, 64, 4, 4, 32, True, 0),
    (2, 128, 128, 4, 2, 64, True, 0),          # GQA
    (1, 128, 128, 8, 1, 64, True, 0),          # MQA
    (2, 64, 64, 4, 4, 32, False, 0),           # bidirectional (encoder)
    (1, 16, 144, 4, 2, 32, True, 128),         # decode-ish: q_offset
    (1, 100, 100, 2, 2, 64, True, 0),          # non-multiple of block
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Skv, H, KH, D, causal, off = case
    q = jnp.asarray(RNG.randn(B, Sq, H, D), dtype)
    k = jnp.asarray(RNG.randn(B, Skv, KH, D), dtype)
    v = jnp.asarray(RNG.randn(B, Skv, KH, D), dtype)
    got = fa.flash_attention(q, k, v, causal=causal, q_offset=off,
                             interpret=True)
    want = ref.attention(q, k, v, causal=causal, q_offset=off)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **_tol(dtype))


def test_flash_attention_blocked_vs_small_blocks():
    """Same result regardless of block partitioning (online softmax)."""
    q = jnp.asarray(RNG.randn(1, 256, 2, 64), jnp.float32)
    big = fa._flash_call(q.transpose(0, 2, 1, 3), q.transpose(0, 2, 1, 3),
                         q.transpose(0, 2, 1, 3), causal=True, q_offset=0,
                         scale=0.125, interpret=True, bq=256, bkv=256)
    small = fa._flash_call(q.transpose(0, 2, 1, 3), q.transpose(0, 2, 1, 3),
                           q.transpose(0, 2, 1, 3), causal=True, q_offset=0,
                           scale=0.125, interpret=True, bq=64, bkv=32)
    assert_allclose(np.asarray(big), np.asarray(small), rtol=2e-5, atol=2e-5)


DA_CASES = [
    # (B, Sq, S_max, kv_len, H, KH, D): q rows sit at [kv_len - Sq, kv_len)
    (1, 1, 64, 9, 4, 4, 32),                   # single-token decode
    (2, 1, 128, 65, 4, 2, 64),                 # GQA decode, 2 lanes
    (1, 16, 144, 16, 4, 2, 32),                # prefill into an empty cache
    (1, 8, 200, 108, 8, 1, 64),                # MQA, non-multiple of block
]


@pytest.mark.parametrize("case", DA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(case, dtype):
    B, Sq, Smax, L, H, KH, D = case
    q = jnp.asarray(RNG.randn(B, Sq, H, D), dtype)
    k = jnp.asarray(RNG.randn(B, Smax, KH, D), dtype)
    v = jnp.asarray(RNG.randn(B, Smax, KH, D), dtype)
    lens = jnp.full((B,), L, jnp.int32)
    off = L - Sq                           # absolute position of q row 0
    got = da.decode_attention(q, k, v, q_offset=off, kv_len=lens,
                              interpret=True, bkv=64)
    want = ref.attention(q, k, v, causal=True, q_offset=off, kv_len=lens)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **_tol(dtype))


def test_decode_attention_block_invariance():
    """Online softmax: result independent of the kv block partitioning,
    including blocks that fall entirely past the valid prefix."""
    q = jnp.asarray(RNG.randn(1, 1, 4, 32), jnp.float32)
    k = jnp.asarray(RNG.randn(1, 256, 4, 32), jnp.float32)
    lens = jnp.asarray([33], jnp.int32)
    big = da.decode_attention(q, k, k, q_offset=32, kv_len=lens,
                              interpret=True, bkv=256)
    small = da.decode_attention(q, k, k, q_offset=32, kv_len=lens,
                                interpret=True, bkv=32)
    assert_allclose(np.asarray(big), np.asarray(small), rtol=2e-5, atol=2e-5)


def test_flash_grad_matches_ref():
    q = jnp.asarray(RNG.randn(1, 64, 2, 32), jnp.float32)

    def k_loss(q):
        return jnp.sum(fa.flash_attention(q, q, q, interpret=True))

    def r_loss(q):
        return jnp.sum(ref.attention(q, q, q))

    assert_allclose(np.asarray(jax.grad(k_loss)(q)),
                    np.asarray(jax.grad(r_loss)(q)), rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# int8 stream codec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 2048, 256 * 513])
def test_quant_roundtrip_sweep(n):
    v = jnp.asarray(RNG.randn(n) * 5, jnp.float32)
    q1, s1 = qs.quantize_int8(v, interpret=True)
    q2, s2 = ref.quantize_int8(v)
    assert_allclose(np.asarray(q1), np.asarray(q2))
    assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    d1 = qs.dequantize_int8(q1, s1, interpret=True)
    d2 = ref.dequantize_int8(q2, s2)
    assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
    assert float(jnp.max(jnp.abs(d1 - v))) <= float(jnp.max(jnp.abs(v))) / 100


def test_quant_zero_block_safe():
    v = jnp.zeros(512, jnp.float32)
    q, s = qs.quantize_int8(v, interpret=True)
    assert_allclose(np.asarray(qs.dequantize_int8(q, s, interpret=True)), 0.0)


# ---------------------------------------------------------------------------
# butterfly shard merge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,l", [(2, 100), (8, 1000), (16, 20000), (5, 7)])
def test_shard_merge_sweep(m, l):
    shards = jnp.asarray(RNG.randn(m, l), jnp.float32)
    valid = jnp.asarray(RNG.rand(m) > 0.3)
    if not bool(valid.any()):
        valid = valid.at[0].set(True)
    got = sm.shard_merge(shards, valid, interpret=True)
    want = ref.shard_merge(shards, valid)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_shard_merge_all_invalid_is_zero():
    shards = jnp.ones((4, 64))
    got = sm.shard_merge(shards, jnp.zeros(4, bool), interpret=True)
    assert_allclose(np.asarray(got), 0.0)


# ---------------------------------------------------------------------------
# mamba selective scan (§Perf cell B kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", [(2, 64, 32, 8, 16, 32),
                                  (1, 128, 64, 16, 64, 64),
                                  (2, 96, 48, 8, 48, 32)])
def test_mamba_scan_kernel_sweep(case):
    from repro.kernels import mamba_scan as ms
    B, S, d_in, ds, bd, bs = case
    delta = jnp.asarray(np.abs(RNG.randn(B, S, d_in)) * 0.1, jnp.float32)
    x = jnp.asarray(RNG.randn(B, S, d_in), jnp.float32)
    b = jnp.asarray(RNG.randn(B, S, ds), jnp.float32)
    c = jnp.asarray(RNG.randn(B, S, ds), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(d_in, ds)), jnp.float32)
    got = ms.mamba_scan(delta, x, b, c, a, interpret=True, bd=bd, bs=bs)
    want = ms.mamba_scan_ref(delta, x, b, c, a)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_mamba_scan_kernel_state_carries_across_seq_blocks():
    """The VMEM h scratch must persist across sequential S-grid steps."""
    from repro.kernels import mamba_scan as ms
    B, S, d_in, ds = 1, 64, 16, 4
    delta = jnp.asarray(np.abs(RNG.randn(B, S, d_in)) * 0.2, jnp.float32)
    x = jnp.asarray(RNG.randn(B, S, d_in), jnp.float32)
    b = jnp.asarray(RNG.randn(B, S, ds), jnp.float32)
    c = jnp.asarray(RNG.randn(B, S, ds), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.randn(d_in, ds)), jnp.float32)
    # four sequence blocks of 16 vs a single block
    blocked = ms.mamba_scan(delta, x, b, c, a, interpret=True, bd=16, bs=16)
    single = ms.mamba_scan(delta, x, b, c, a, interpret=True, bd=16, bs=64)
    assert_allclose(np.asarray(blocked), np.asarray(single),
                    rtol=1e-5, atol=1e-6)
