"""CLASP (paper §6 / App. B): attribution, outlier detection, Fig 8."""
import numpy as np
import pytest

from repro.core import clasp


def _run(malicious, n_samples=4000, **kw):
    cfg = clasp.ToyConfig(n_samples=n_samples, **kw)
    recs, layer_of = clasp.toy_simulation(cfg, malicious)
    n = cfg.n_layers * cfg.miners_per_layer
    return recs, layer_of, n


def test_flags_planted_outliers_cond_mean():
    recs, layer_of, n = _run([3, 12])
    rep = clasp.attribute(recs, n, layer_of)
    assert set(np.where(rep.flagged)[0]) == {3, 12}


def test_flags_planted_outliers_regression():
    recs, layer_of, n = _run([3, 12, 13])
    rep = clasp.attribute_regression(recs, n, layer_of)
    assert set(np.where(rep.flagged)[0]) == {3, 12, 13}


def test_regression_sharper_with_colluding_bad_actors():
    """Two bad miners in the SAME layer contaminate each other's conditional

    mean baseline; the regression separates them anyway."""
    recs, layer_of, n = _run([10, 11], n_samples=6000)
    rep_mean = clasp.attribute(recs, n, layer_of)
    rep_reg = clasp.attribute_regression(recs, n, layer_of)
    honest = [i for i in range(n) if i not in (10, 11)]
    margin_reg = min(rep_reg.z_scores[[10, 11]]) - max(rep_reg.z_scores[honest])
    assert set(np.where(rep_reg.flagged)[0]) == {10, 11}
    assert margin_reg > 0


def test_fig8b_fair_miner_suppression():
    """Fig 8b: fair miners sharing a layer with bad actors show reduced

    conditional-mean contribution."""
    recs, layer_of, n = _run([7], n_samples=8000)
    rep = clasp.attribute(recs, n, layer_of)
    assert clasp.fair_miner_suppression(rep, [7]) < 0


def test_counts_match_sampling():
    recs, layer_of, n = _run([], n_samples=1000)
    rep = clasp.attribute(recs, n, layer_of)
    # every sample hits exactly one miner per layer
    assert rep.counts.sum() == 1000 * 5
    assert (rep.counts > 0).all()


def test_no_false_positives_when_honest():
    recs, layer_of, n = _run([], n_samples=5000)
    for fn in (clasp.attribute, clasp.attribute_regression):
        rep = fn(recs, n, layer_of)
        assert not rep.flagged.any()


def test_pathway_sampler_one_per_layer():
    rng = np.random.RandomState(0)
    layers = [[0, 1], [2, 3], [4, 5]]
    for p in clasp.sample_pathways(rng, layers, 100):
        assert len(p) == 3
        for s, m in enumerate(p):
            assert m in layers[s]
