"""Serve-plane throughput/latency matrix -> ``BENCH_serve.json``.

Runs the continuous-batching ``ServeDriver`` (docs/SERVE.md) over a
2-stage swarm at increasing lane concurrency, on the in-process store
AND through a real socket ``StoreServer``, and records one row per
(transport, lanes) cell: decode throughput (tok/s) and per-request
completion-latency percentiles.  Every run is parity-checked against
the sequential ``swarm_generate`` oracle before its numbers are
recorded — a row from a diverging stream would be meaningless.

``validate_artifact`` is the schema gate ``benchmarks/run.py --quick``
enforces; ``BENCH_QUICK=1`` runs a reduced matrix against a scratch
artifact.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get, smoke_variant

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_serve.json")
QUICK_ARTIFACT = os.path.join(tempfile.gettempdir(),
                              "BENCH_serve.quick.json")

SCHEMA_KEYS = {"schema", "rows", "derived"}
ROW_KEYS = {"transport", "lanes", "requests", "tokens", "tok_per_s",
            "p50_ms", "p99_ms", "parity_ok", "wall_seconds"}

N_STAGES = 2
PROMPT_LEN = 8


def artifact_path() -> str:
    return QUICK_ARTIFACT if os.environ.get("BENCH_QUICK", "0") == "1" \
        else ARTIFACT


def _quick() -> bool:
    return os.environ.get("BENCH_QUICK", "0") == "1"


def _spec():
    from repro.runtime import stage_model as sm

    mcfg = dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=N_STAGES)
    return sm.SwarmModelSpec(mcfg, N_STAGES)


def _requests(spec, n, max_new):
    from repro.api.phases import ServeRequest

    rng = np.random.default_rng(7)
    return [ServeRequest(req=i,
                         prompt=rng.integers(3, spec.cfg.vocab_size,
                                             PROMPT_LEN, dtype=np.int32),
                         max_new=max_new) for i in range(n)]


def run_matrix() -> list[dict]:
    from repro.launch.serve import serve_swarm, swarm_generate

    lanes_grid = (1, 2) if _quick() else (1, 2, 4)
    max_new = 4 if _quick() else 16
    spec = _spec()
    rows = []
    for transport in ("inprocess", "socket"):
        for lanes in lanes_grid:
            n_req = max(2 * lanes, 3) if _quick() else 3 * lanes
            reqs = _requests(spec, n_req, max_new)
            t0 = time.perf_counter()
            records = serve_swarm(spec, reqs, n_lanes=lanes,
                                  max_len=PROMPT_LEN + max_new,
                                  transport=transport)
            wall = time.perf_counter() - t0
            oracle = swarm_generate(spec, 0, reqs)
            parity = all(records[r.req].tokens == oracle[r.req]
                         for r in reqs)
            n_tok = sum(len(rec.tokens) for rec in records.values())
            totals = [rec.total for rec in records.values()]
            row = {
                "transport": transport,
                "lanes": lanes,
                "requests": n_req,
                "tokens": n_tok,
                "tok_per_s": round(n_tok / wall, 2),
                "p50_ms": round(float(np.percentile(totals, 50)) * 1e3, 2),
                "p99_ms": round(float(np.percentile(totals, 99)) * 1e3, 2),
                "parity_ok": parity,
                "wall_seconds": round(wall, 2),
            }
            rows.append(row)
            emit(f"serve/{transport}/l{lanes}", wall * 1e6 / max(n_tok, 1),
                 f"tok_per_s={row['tok_per_s']};p50_ms={row['p50_ms']};"
                 f"p99_ms={row['p99_ms']};parity={parity}")
    return rows


def write_artifact(rows: list[dict]) -> str:
    art = {
        "schema": "bench_serve/v1",
        "rows": rows,
        "derived": {
            "all_parity_ok": all(r["parity_ok"] for r in rows),
            "best_tok_per_s": max(r["tok_per_s"] for r in rows),
            "transports": sorted({r["transport"] for r in rows}),
        },
    }
    path = artifact_path()
    with open(path, "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)
    validate_artifact(path)
    return path


def validate_artifact(path: str | None = None) -> dict:
    path = path or artifact_path()
    with open(path) as f:
        art = json.load(f)
    assert art["schema"] == "bench_serve/v1", art["schema"]
    assert set(art) == SCHEMA_KEYS, set(art) ^ SCHEMA_KEYS
    assert art["rows"], "no serve rows"
    for row in art["rows"]:
        assert set(row) == ROW_KEYS, set(row) ^ ROW_KEYS
        assert row["parity_ok"] is True, \
            f"{row['transport']}/l{row['lanes']} diverged from the oracle"
        assert row["tok_per_s"] > 0 and row["tokens"] > 0, row
        assert 0 <= row["p50_ms"] <= row["p99_ms"], row
    # the headline claim: both store paths serve the oracle's stream
    assert set(art["derived"]["transports"]) == {"inprocess", "socket"}, \
        art["derived"]
    assert art["derived"]["all_parity_ok"], art["derived"]
    return art


def run() -> None:
    rows = run_matrix()
    write_artifact(rows)


if __name__ == "__main__":
    run()
