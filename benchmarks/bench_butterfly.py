"""Paper Fig 7a/7b + §5.3: agreement matrix, failure resilience, transfer

volume of Butterfly All-Reduce."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_call
from repro.common import human_bytes
from repro.core import butterfly


def fig7a_agreement_matrix() -> None:
    """50 miners, 10 deceptive: every deceptive reducer must be out of

    consensus with all partners; honest pairs all agree."""
    n, vec_len = 50, 20000
    deceptive = list(range(5, 15))
    plan = butterfly.make_plan(n, vec_len, seed=0)
    uploads = {m: np.random.RandomState(m).randn(vec_len).astype(np.float32)
               for m in range(n)}
    copies = butterfly.reduce_with_copies(
        plan, uploads, tamper={m: 0.3 for m in deceptive})
    mat = butterfly.agreement_matrix(plan, copies)
    flagged = [m for m in range(n)
               if np.nanmean(mat[m][np.arange(n) != m]) < 0.5]
    emit("fig7a_agreement/deceptive_flagged", 0.0,
         f"{len(set(flagged) & set(deceptive))}/10_true;"
         f"{len(set(flagged) - set(deceptive))}_false_pos")


def fig7b_failure_resilience() -> None:
    """Fraction of weights still averaged vs number of failed miners:

    formula C(N,2)-C(k,2) against explicit simulation, N=50."""
    n = 50
    rows = []
    for k in (0, 5, 10, 17, 25, 35):
        plan = butterfly.make_plan(n, n * (n - 1) * 2, seed=k)
        uploads = {m: np.ones(plan.vector_len, np.float32) for m in range(n)}
        rng = np.random.RandomState(k)
        dead = set(rng.choice(n, size=k, replace=False))
        ok = [m not in dead for m in range(n)]
        _, valid, _ = butterfly.reduce_shards(plan, uploads, reducer_ok=ok)
        sim = float(valid.mean())
        formula = butterfly.valid_shard_fraction(n, k)
        rows.append((k, sim, formula))
        emit(f"fig7b_resilience/k{k}", 0.0,
             f"simulated={sim:.4f};formula={formula:.4f}")
    # paper claims: <=10% failures keep >99%; training viable to ~35%
    k5 = [r for r in rows if r[0] == 5][0]
    k17 = [r for r in rows if r[0] == 17][0]
    emit("fig7b_claims", 0.0,
         f"10pct_failures_keep={k5[1]:.4f}(>0.99);"
         f"35pct_failures_keep={k17[1]:.4f}(>0.88)")


def sec53_transfer_volume() -> None:
    """§5.3 table: per-miner bytes 4W + 2W/N vs central merger N*W."""
    w = 100 * 2**20          # 100 MiB of layer weights
    for n in (5, 10, 25, 50, 100):
        vol = butterfly.transfer_volume(n, w)
        emit(f"sec53_transfer/n{n}", 0.0,
             f"per_miner={human_bytes(vol['per_miner_bytes'])};"
             f"central={human_bytes(vol['central_merger_bytes'])};"
             f"ratio={vol['central_merger_bytes']/vol['per_miner_bytes']:.2f}x")


def merge_throughput() -> None:
    """Wall-time of the (CPU, kernel-oracle) merge primitive itself."""
    import jax.numpy as jnp
    from repro.kernels import ops
    shards = jnp.asarray(np.random.randn(16, 1 << 20), jnp.float32)
    valid = jnp.ones(16, bool)
    us = time_call(lambda: ops.shard_merge(shards, valid))
    emit("butterfly_merge_16x1M", us, f"{16*(1<<20)*4/us*1e6/2**30:.1f}GiB/s")


def run() -> None:
    fig7a_agreement_matrix()
    fig7b_failure_resilience()
    sec53_transfer_volume()
    merge_throughput()


if __name__ == "__main__":
    run()
