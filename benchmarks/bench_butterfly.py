"""Paper Fig 7a/7b + §5.3: agreement matrix, failure resilience, transfer

volume of Butterfly All-Reduce — plus the *measured* store-and-forward
numbers, written to ``BENCH_butterfly.json`` (tracked across PRs):

  * per-miner bytes of a real ``ButterflyExecutor`` sync over
    ``SimulatedNetworkTransport`` vs the 4W + 2W/N closed form, N ∈ {4,6,8}
  * dense vs sharded ``SyncPhase`` on a tiny swarm: merged-anchor parity
    and wall-clock (host + simulated)

``BENCH_QUICK=1`` shrinks sizes and validates a scratch artifact
(the smoke.sh / ``run.py --quick`` schema gate).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, time_call
from repro.common import human_bytes
from repro.core import butterfly

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ARTIFACT = os.path.join(ROOT, "BENCH_butterfly.json")
QUICK_ARTIFACT = os.path.join(tempfile.gettempdir(),
                              "BENCH_butterfly.quick.json")


def artifact_path() -> str:
    return QUICK_ARTIFACT if os.environ.get("BENCH_QUICK", "0") == "1" \
        else ARTIFACT


SCHEMA_KEYS = {"schema", "config", "benchmarks", "sync", "derived"}
BENCH_KEYS = {"name", "n_miners", "w_bytes", "per_miner_bytes_mean",
              "per_miner_bytes_max", "closed_form_bytes", "rel_err_max"}
SYNC_KEYS = {"dense_sim_seconds", "sharded_sim_seconds", "dense_wall_us",
             "sharded_wall_us", "anchor_max_delta"}


def fig7a_agreement_matrix() -> None:
    """50 miners, 10 deceptive: every deceptive reducer must be out of

    consensus with all partners; honest pairs all agree."""
    n, vec_len = 50, 20000
    deceptive = list(range(5, 15))
    plan = butterfly.make_plan(n, vec_len, seed=0)
    uploads = {m: np.random.RandomState(m).randn(vec_len).astype(np.float32)
               for m in range(n)}
    copies = butterfly.reduce_with_copies(
        plan, uploads, tamper={m: 0.3 for m in deceptive})
    mat = butterfly.agreement_matrix(plan, copies)
    flagged = [m for m in range(n)
               if np.nanmean(mat[m][np.arange(n) != m]) < 0.5]
    emit("fig7a_agreement/deceptive_flagged", 0.0,
         f"{len(set(flagged) & set(deceptive))}/10_true;"
         f"{len(set(flagged) - set(deceptive))}_false_pos")


def fig7b_failure_resilience() -> None:
    """Fraction of weights still averaged vs number of failed miners:

    formula C(N,2)-C(k,2) against explicit simulation, N=50."""
    n = 50
    rows = []
    for k in (0, 5, 10, 17, 25, 35):
        plan = butterfly.make_plan(n, n * (n - 1) * 2, seed=k)
        uploads = {m: np.ones(plan.vector_len, np.float32) for m in range(n)}
        rng = np.random.RandomState(k)
        dead = set(rng.choice(n, size=k, replace=False))
        ok = [m not in dead for m in range(n)]
        _, valid, _ = butterfly.reduce_shards(plan, uploads, reducer_ok=ok)
        sim = float(valid.mean())
        formula = butterfly.valid_shard_fraction(n, k)
        rows.append((k, sim, formula))
        emit(f"fig7b_resilience/k{k}", 0.0,
             f"simulated={sim:.4f};formula={formula:.4f}")
    # paper claims: <=10% failures keep >99%; training viable to ~35%
    k5 = [r for r in rows if r[0] == 5][0]
    k17 = [r for r in rows if r[0] == 17][0]
    emit("fig7b_claims", 0.0,
         f"10pct_failures_keep={k5[1]:.4f}(>0.99);"
         f"35pct_failures_keep={k17[1]:.4f}(>0.88)")


def sec53_transfer_volume() -> None:
    """§5.3 table: per-miner bytes 4W + 2W/N vs central merger N*W."""
    w = 100 * 2**20          # 100 MiB of layer weights
    for n in (5, 10, 25, 50, 100):
        vol = butterfly.transfer_volume(n, w)
        emit(f"sec53_transfer/n{n}", 0.0,
             f"per_miner={human_bytes(vol['per_miner_bytes'])};"
             f"central={human_bytes(vol['central_merger_bytes'])};"
             f"ratio={vol['central_merger_bytes']/vol['per_miner_bytes']:.2f}x")


def merge_throughput() -> None:
    """Wall-time of the (CPU, kernel-oracle) merge primitive itself."""
    import jax.numpy as jnp
    from repro.kernels import ops
    shards = jnp.asarray(np.random.randn(16, 1 << 20), jnp.float32)
    valid = jnp.ones(16, bool)
    us = time_call(lambda: ops.shard_merge(shards, valid))
    emit("butterfly_merge_16x1M", us, f"{16*(1<<20)*4/us*1e6/2**30:.1f}GiB/s")


def store_and_forward_bytes(quick: bool) -> list[dict]:
    """Measured per-miner bytes of a full executor sync (shard uploads +
    reduce + reduced re-uploads + anchor download) vs 4W + 2W/N.

    Runs fp32 payloads (codec "none") so W is unambiguous — the closed
    form's units; the int8 sharing codec shrinks the upload/reduce legs by
    its ratio without changing the shape of the accounting."""
    from repro.api import KeySchema, NetworkModel, SimulatedNetworkTransport

    L = 50_000 if quick else 400_000
    records = []
    for n in ((4,) if quick else (4, 6, 8)):
        tp = SimulatedNetworkTransport(NetworkModel.consumer(),
                                       schema=KeySchema(version=2))
        plan = butterfly.make_plan(n, L, seed=0)
        ex = butterfly.ButterflyExecutor(plan, tp, epoch=0, stage=0,
                                         uids=list(range(n)), codec="none")
        vecs = {i: np.random.RandomState(i).randn(L).astype(np.float32)
                for i in range(n)}
        for i in range(n):
            ex.upload_vector(i, vecs[i], actor=f"miner{i}")
        for i in range(n):
            ex.run_reducer(i, actor=f"miner{i}")
        merged, valid, _ = ex.collect(actor="orchestrator")
        assert valid.all()
        np.testing.assert_allclose(
            merged, np.mean([vecs[i] for i in range(n)], axis=0), atol=1e-5)
        anchor_key = tp.schema.anchor(0, 0)
        tp.put(anchor_key, merged, actor="orchestrator")
        for i in range(n):
            tp.get(anchor_key, actor=f"miner{i}")

        w = L * 4
        closed = 4 * w + 2 * w / n
        rep = tp.link_report()
        per = [rep[f"miner{i}"]["up_bytes"] + rep[f"miner{i}"]["down_bytes"]
               for i in range(n)]
        rel = max(abs(p - closed) / closed for p in per)
        records.append({
            "name": f"store_forward_n{n}",
            "n_miners": n,
            "w_bytes": w,
            "per_miner_bytes_mean": float(np.mean(per)),
            "per_miner_bytes_max": float(max(per)),
            "closed_form_bytes": closed,
            "rel_err_max": round(rel, 6),
        })
        emit(f"sec53_measured/n{n}", 0.0,
             f"measured={human_bytes(float(np.mean(per)))};"
             f"closed_form={human_bytes(closed)};rel_err={rel:.4f}")
    return records


def dense_vs_sharded_sync(quick: bool) -> dict:
    """Tiny swarm, identical seeds: the sharded store-and-forward sync must
    reproduce the dense oracle's anchors; report both clocks."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    from jax.flatten_util import ravel_pytree

    from repro.api import (KeySchema, NetworkModel, SimulatedNetworkTransport,
                           Swarm, SwarmConfig)
    from repro.configs import get, smoke_variant

    mcfg = dc.replace(smoke_variant(get("llama3.2-1b")).model,
                      n_layers=2 if quick else 4)
    base = SwarmConfig(seed=0, n_stages=2, miners_per_stage=4,
                       inner_steps=2 if quick else 4, b_min=1, validators=1)
    out = {}
    anchors = {}
    for mode in ("dense", "sharded"):
        cfg = dc.replace(base, sync_mode=mode)
        tp = SimulatedNetworkTransport(
            NetworkModel.consumer(),
            schema=KeySchema(version=2 if mode == "sharded" else 1))
        sw = Swarm.create(mcfg, cfg, transport=tp)
        t0 = time.perf_counter()
        sw.run(1)
        out[f"{mode}_wall_us"] = round((time.perf_counter() - t0) * 1e6)
        out[f"{mode}_sim_seconds"] = round(tp.elapsed_seconds(), 4)
        anchors[mode] = [
            np.asarray(ravel_pytree(jax.tree.map(
                lambda x: x.astype(jnp.float32), a))[0])
            for a in sw.anchors]
    out["anchor_max_delta"] = float(max(
        np.abs(d - s).max() for d, s in zip(anchors["dense"],
                                            anchors["sharded"])))
    emit("sync_dense_vs_sharded", out["sharded_wall_us"],
         f"anchor_delta={out['anchor_max_delta']:.2e};"
         f"sim_s_dense={out['dense_sim_seconds']};"
         f"sim_s_sharded={out['sharded_sim_seconds']}")
    return out


def write_artifact(quick: bool) -> None:
    records = store_and_forward_bytes(quick)
    sync = dense_vs_sharded_sync(quick)
    art = {
        "schema": "bench_butterfly/v1",
        "config": {"quick": quick, "codec": "none",
                   "ns": [r["n_miners"] for r in records]},
        "benchmarks": records,
        "sync": sync,
        "derived": {
            "max_rel_err": max(r["rel_err_max"] for r in records),
            "o1_bandwidth_ok": all(r["rel_err_max"] < 0.05
                                   for r in records),
            "anchor_parity_ok": sync["anchor_max_delta"] <= 1e-6,
        },
    }
    path = artifact_path()
    with open(path, "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)
    validate_artifact(path)
    emit("butterfly_artifact", 0.0,
         f"{os.path.basename(path)};rel_err={art['derived']['max_rel_err']}")


def validate_artifact(path: str | None = None) -> dict:
    path = path or artifact_path()
    with open(path) as f:
        art = json.load(f)
    assert art["schema"] == "bench_butterfly/v1", art["schema"]
    assert set(art) == SCHEMA_KEYS, set(art) ^ SCHEMA_KEYS
    assert art["benchmarks"], "no benchmark records"
    for rec in art["benchmarks"]:
        assert set(rec) == BENCH_KEYS, set(rec) ^ BENCH_KEYS
    assert set(art["sync"]) == SYNC_KEYS, set(art["sync"]) ^ SYNC_KEYS
    assert art["derived"]["o1_bandwidth_ok"], \
        f"per-miner bytes off the 4W+2W/N closed form: {art['derived']}"
    assert art["derived"]["anchor_parity_ok"], \
        f"sharded anchors diverged from dense oracle: {art['derived']}"
    return art


def run() -> None:
    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    if not quick:
        fig7a_agreement_matrix()
        fig7b_failure_resilience()
        sec53_transfer_volume()
        merge_throughput()
    write_artifact(quick)


if __name__ == "__main__":
    run()
