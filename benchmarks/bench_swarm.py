"""§2.1 B_min/B_eff behaviour + §5.3 transfer analysis: swarm benchmark.

Four sections:
  * swarm_beff:      effective batch / stall rate as stragglers grow
                     (the orchestrator's robustness claim)
  * swarm_traffic:   store bytes per namespace for a reference run
  * swarm_transport: the SAME reference swarm under both transports —
    the in-process baseline, then simulated datacenter and consumer
    links, reporting simulated wall-clock, time-to-loss and per-link
    bytes (scenario-parameterised §5.3 transfer analysis)
  * swarm_socket:    the reference swarm over a REAL socket (StoreServer
    + SocketTransport, serde wire format), asserting the server-side
    per-actor byte accounting equals the simulated transport's link
    accounting and the trajectory is unchanged
  * swarm_actors:    the concurrent actor runtime (one OS process per
    miner/validator over the socket store) vs the SAME swarm driven
    lockstep over the same socket — measured steady-state wall-clock per
    epoch, asserting the actors' overlap beats the serialized timeline
    at an identical loss trajectory
"""
from __future__ import annotations

import dataclasses
import os
import time

from benchmarks.common import emit
from repro.api import (InProcessTransport, NetworkModel,
                       SimulatedNetworkTransport, Swarm, SwarmConfig)
from repro.common import human_bytes
from repro.configs import get, smoke_variant
from repro.runtime import FaultModel, MinerBehavior


def _mcfg():
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=6)


def _beff_section() -> None:
    for frac in (0.0, 0.25, 0.5):
        sw = SwarmConfig(n_stages=2, miners_per_stage=4, inner_steps=12,
                         b_min=2, batch_size=2, seq_len=32, validators=0,
                         seed=3)
        n_miners = sw.n_stages * sw.miners_per_stage
        n_slow = int(n_miners * frac)
        faults = FaultModel(
            {m: MinerBehavior(straggle_factor=4.0) for m in range(n_slow)},
            seed=3)
        swarm = Swarm.create(_mcfg(), sw, faults=faults)
        stats = swarm.run(2)
        s = stats[-1]
        emit(f"swarm_beff/straggler_frac{frac}", 0.0,
             f"b_eff={s.b_eff};stalls={s.stalled_ticks}/"
             f"{sw.inner_steps};merged={s.merged_stages}/{sw.n_stages}")


def _traffic_section() -> None:
    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=8, b_min=2,
                     batch_size=2, seq_len=32, validators=1, seed=4)
    swarm = Swarm.create(_mcfg(), sw)
    swarm.run(2)
    rep = swarm.transport.traffic_report()
    emit("swarm_traffic/activations", 0.0,
         human_bytes(rep["uploaded"].get("activations", 0)))
    emit("swarm_traffic/weights", 0.0,
         human_bytes(rep["uploaded"].get("weights", 0)))
    emit("swarm_traffic/total", 0.0, human_bytes(rep["total_bytes"]))


def _transport_section() -> None:
    """Same seed, same trajectory; only the link model differs."""
    scenarios = [
        ("in_process", InProcessTransport),
        ("sim_datacenter",
         lambda: SimulatedNetworkTransport(NetworkModel.datacenter())),
        ("sim_consumer",
         lambda: SimulatedNetworkTransport(NetworkModel.consumer())),
    ]
    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=8, b_min=2,
                     batch_size=2, seq_len=32, validators=1, seed=4)
    final_loss = {}
    for name, make in scenarios:
        transport = make()
        swarm = Swarm.create(_mcfg(), sw, transport=transport)
        stats = swarm.run(2)
        final_loss[name] = stats[-1].mean_loss
        clock = transport.elapsed_seconds()
        emit(f"swarm_transport/{name}", 0.0,
             f"sim_clock={clock:.2f}s;"
             f"time_to_loss={clock:.2f}s@{stats[-1].mean_loss:.3f}")
        links = transport.link_report()
        if links:
            busiest = max(links.items(), key=lambda kv: kv[1]["up_bytes"])
            emit(f"swarm_transport/{name}_links", 0.0,
                 f"links={len(links)};"
                 f"busiest={busiest[0]}:"
                 f"up={human_bytes(busiest[1]['up_bytes'])},"
                 f"down={human_bytes(busiest[1]['down_bytes'])},"
                 f"busy={busiest[1]['busy_seconds']:.2f}s")
    # determinism across transports is part of the API contract
    assert len(set(final_loss.values())) == 1, final_loss


def _overlap_section() -> None:
    """ROADMAP async-phases item: overlap Training-phase activation
    streaming with Sharing-phase uploads (phases.OverlappedTrainingSharing)
    and report the simulated seconds saved per epoch.  Same RNG order as
    the default timeline for fault-free swarms, so the loss trajectory is
    asserted identical — only the clock model sees the overlap."""
    from repro.api.phases import overlapped_phases

    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=8, b_min=2,
                     batch_size=2, seq_len=32, validators=1, seed=4)
    epochs = 2
    results = {}
    for name, phases in (("sequential", None), ("overlapped",
                                                overlapped_phases())):
        transport = SimulatedNetworkTransport(NetworkModel.consumer())
        swarm = Swarm.create(_mcfg(), sw, transport=transport, phases=phases)
        stats = swarm.run(epochs)
        results[name] = (transport.elapsed_seconds(), stats[-1].mean_loss)
    assert results["sequential"][1] == results["overlapped"][1], results
    saved = results["sequential"][0] - results["overlapped"][0]
    emit("swarm_overlap/training+sharing", 0.0,
         f"seq={results['sequential'][0]:.2f}s;"
         f"overlap={results['overlapped'][0]:.2f}s;"
         f"saved_per_epoch={saved / epochs:.2f}s;"
         f"loss_equal={results['sequential'][1]:.4f}")


def _socket_section() -> None:
    """Real sockets next to the simulated rows: same swarm, same seed, the
    store behind a StoreServer (threaded here — identical wire format to
    the separate-process deployment).  The §5.3 accounting parity is a
    hard assertion: server-side per-actor bytes == simulated per-link
    bytes, because both count StoreEntry.nbytes on the same calls."""
    from repro.api import SocketTransport
    from repro.runtime.store_server import StoreServer

    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=8, b_min=2,
                     batch_size=2, seq_len=32, validators=1, seed=4)
    sim_tp = SimulatedNetworkTransport(NetworkModel.consumer())
    sim_stats = Swarm.create(_mcfg(), sw, transport=sim_tp).run(2)

    server = StoreServer().start()
    try:
        tp = SocketTransport(server.address)
        sock_stats = Swarm.create(_mcfg(), sw, transport=tp).run(2)
        report = tp.traffic_report()
        wire = tp.wire_report()
        real_clock = tp.elapsed_seconds()
        tp.close()
    finally:
        server.stop()

    # trajectory is transport-invariant, accounting is parity-exact
    assert [s.mean_loss for s in sock_stats] == \
        [s.mean_loss for s in sim_stats]
    for actor, s in sim_tp.link_report().items():
        assert s["up_bytes"] == report["by_actor_up"].get(actor, 0), actor
        assert s["down_bytes"] == report["by_actor_down"].get(actor, 0), actor

    payload = sum(report["by_actor_up"].values()) + \
        sum(report["by_actor_down"].values())
    on_wire = wire["up_bytes"] + wire["down_bytes"]
    emit("swarm_socket/real_tcp", real_clock,
         f"loss={sock_stats[-1].mean_loss:.3f}(=sim);"
         f"payload={human_bytes(payload)};"
         f"wire={human_bytes(on_wire)}"
         f"(+{100.0 * (on_wire - payload) / max(payload, 1):.1f}% framing);"
         f"requests={wire['requests']};"
         f"per_actor_bytes=match_simulated")


def _actor_section() -> None:
    """The actor-runtime time-to-loss row, measured honestly: identical
    warmup on each side (two epochs — at seed 4 the validator tracks both
    stages across them, so every jit path is compiled; for actors the
    warmup also absorbs process spawn), then identical further epochs
    timed wall-clock.  The serialized row does the same compute over the
    same socket store in one process, so the gap is pure overlap —
    pipelined stages, validation replay streaming concurrently with
    training, and actors filling the socket round-trip gaps the
    serialized timeline spends blocked.  Both rows must land on the same
    trajectory (same seed, same measured epochs).

    Honesty requires hardware honesty too: actor processes overlap
    *compute*, so on a single-core machine there is no parallelism to
    measure — both rows time-slice one CPU and differ only by noise.
    The strict actor-beats-serialized assertion therefore applies when
    ≥ 2 cores are available; on one core the row is emitted flagged
    ``single_core`` (trajectory parity still asserted)."""
    from repro.api import SocketTransport
    from repro.runtime.store_server import StoreServer

    sw = SwarmConfig(n_stages=2, miners_per_stage=2, inner_steps=6, b_min=2,
                     batch_size=2, seq_len=32, validators=1, seed=4)
    mcfg = _mcfg()
    warmup, epochs, rounds = 2, 3, 3

    def measured_run(swarm):
        """Warmup, then ``rounds`` timed blocks of ``epochs``: returns the
        median per-epoch wall-clock + every measured epoch's stats."""
        swarm.run(warmup)
        stats, per_epoch = [], []
        for _ in range(rounds):
            t0 = time.perf_counter()
            stats.extend(swarm.run(epochs))
            per_epoch.append((time.perf_counter() - t0) / epochs)
        return sorted(per_epoch)[rounds // 2], stats

    server = StoreServer().start()
    try:
        tp = SocketTransport(server.address)
        sock_s, sock_stats = measured_run(Swarm.create(mcfg, sw,
                                                       transport=tp))
        tp.close()
    finally:
        server.stop()

    actors = Swarm.create(mcfg, sw, runtime="actors")
    try:
        actor_s, actor_stats = measured_run(actors)
    finally:
        actors.shutdown()

    assert [s.mean_loss for s in actor_stats] == \
        [s.mean_loss for s in sock_stats], "actor trajectory diverged"
    try:
        cores = len(os.sched_getaffinity(0))
    except AttributeError:          # non-Linux
        cores = os.cpu_count() or 1
    if cores >= 2:
        assert actor_s < sock_s, \
            f"actor runtime shows no overlap on {cores} cores: " \
            f"{actor_s:.2f}s/epoch >= {sock_s:.2f}s/epoch serialized"
        verdict = f"overlap_saves={100.0 * (1.0 - actor_s / sock_s):.0f}%"
    else:
        verdict = "single_core=no_overlap_measurable"
    emit("swarm_actors/steady_state_epoch", actor_s * 1e6,
         f"actor={actor_s:.2f}s/epoch;serialized_socket={sock_s:.2f}s/epoch;"
         f"{verdict};cores={cores};"
         f"time_to_loss@{actor_stats[-1].mean_loss:.3f}="
         f"{epochs * actor_s:.2f}s_vs_{epochs * sock_s:.2f}s;"
         f"median_of{rounds}")


def run() -> None:
    _beff_section()
    _traffic_section()
    _transport_section()
    _overlap_section()
    _socket_section()
    _actor_section()


if __name__ == "__main__":
    run()
