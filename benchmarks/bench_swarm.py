"""§2.1 B_min/B_eff behaviour + store traffic: swarm-level benchmark.

Reports effective batch and stall rate as the straggler fraction grows
(the orchestrator's robustness claim), plus store traffic per epoch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit
from repro.common import human_bytes
from repro.configs import get, smoke_variant
from repro.runtime import FaultModel, MinerBehavior, Orchestrator, SwarmConfig


def _mcfg():
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=6)


def run() -> None:
    for frac in (0.0, 0.25, 0.5):
        sw = SwarmConfig(n_stages=2, miners_per_stage=4, inner_steps=12,
                         b_min=2, batch_size=2, seq_len=32, validators=0,
                         seed=3)
        n_miners = sw.n_stages * sw.miners_per_stage
        n_slow = int(n_miners * frac)
        faults = FaultModel(
            {m: MinerBehavior(straggle_factor=4.0) for m in range(n_slow)},
            seed=3)
        orch = Orchestrator(_mcfg(), sw, faults=faults)
        stats = orch.run(2)
        s = stats[-1]
        emit(f"swarm_beff/straggler_frac{frac}", 0.0,
             f"b_eff={s.b_eff};stalls={s.stalled_ticks}/"
             f"{sw.inner_steps};merged={s.merged_stages}/{sw.n_stages}")

    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=8, b_min=2,
                     batch_size=2, seq_len=32, validators=1, seed=4)
    orch = Orchestrator(_mcfg(), sw)
    orch.run(2)
    rep = orch.store.traffic_report()
    emit("swarm_traffic/activations", 0.0,
         human_bytes(rep["uploaded"].get("activations", 0)))
    emit("swarm_traffic/weights", 0.0,
         human_bytes(rep["uploaded"].get("weights", 0)))
    emit("swarm_traffic/total", 0.0, human_bytes(rep["total_bytes"]))


if __name__ == "__main__":
    run()
