"""§2 'compressed sharing' + §4 wire budget: codec ratio/error/throughput

table over a 4M-element weight vector (the scale of one small layer)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import compression


def run() -> None:
    n = 1 << 22
    v = jnp.asarray(np.random.RandomState(0).randn(n) * 0.02, jnp.float32)
    for codec in compression.CODECS:
        payload = compression.encode(v, codec)
        ratio = compression.compression_ratio(payload, n)
        r = compression.decode(payload, n)
        err = float(jnp.max(jnp.abs(r - v)))
        us = time_call(lambda: compression.encode(v, codec), iters=3)
        emit(f"codec/{codec}", us,
             f"ratio={ratio:.1f}x;max_abs_err={err:.5f};"
             f"MBps={n*4/us:.0f}")
    # the internet-vs-datacenter motivation (paper §4): time to ship one
    # 100 MiB layer at 100 Mbps, per codec
    for codec in compression.CODECS:
        payload = compression.encode(v, codec)
        nbytes = compression.payload_bytes(payload) * (100 * 2**20) / (n * 4)
        secs = nbytes * 8 / 100e6
        emit(f"codec_wire_100Mbps/{codec}", 0.0, f"seconds={secs:.1f}")


if __name__ == "__main__":
    run()
