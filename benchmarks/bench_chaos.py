"""Chaos scenario matrix -> ``BENCH_chaos.json`` (tracked across PRs).

Runs the ``repro.scenarios`` catalog — deterministic fault-injection
experiments over the concurrent actor runtime (docs/CHAOS.md) — and
records one row per scenario: convergence under the fault mix, recovery
latency after kills/failovers, and how many ticks the EventDriver
re-planned onto survivors.  ``validate_artifact`` is the schema gate
``benchmarks/run.py --quick`` enforces: every row must have converged,
and the recovery/replan accounting must be present and sane.

``BENCH_QUICK=1`` runs a two-scenario subset (one kill-and-resume, one
store failover — the two recovery paths) against a scratch artifact.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

from benchmarks.common import emit
from repro.configs import get, smoke_variant
from repro.scenarios import SCENARIOS, run_scenario

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACT = os.path.join(ROOT, "BENCH_chaos.json")
QUICK_ARTIFACT = os.path.join(tempfile.gettempdir(),
                              "BENCH_chaos.quick.json")

QUICK_SCENARIOS = ("kill-n-miners", "store-failover")

SCHEMA_KEYS = {"schema", "scenarios", "derived"}
ROW_KEYS = {"scenario", "fault_seed", "epochs", "converged", "first_loss",
            "final_loss", "recovery_seconds", "replanned_ticks", "kills",
            "notes", "wall_seconds"}


def artifact_path() -> str:
    return QUICK_ARTIFACT if os.environ.get("BENCH_QUICK", "0") == "1" \
        else ARTIFACT


def _mcfg():
    return dataclasses.replace(smoke_variant(get("llama3.2-1b")).model,
                               n_layers=2)


def run_matrix(names) -> list[dict]:
    rows = []
    mcfg = _mcfg()
    for name in names:
        scenario = SCENARIOS[name]()
        t0 = time.perf_counter()
        with tempfile.TemporaryDirectory() as snap_root:
            result = run_scenario(scenario, mcfg, snapshot_root=snap_root)
        row = result.row()
        row["wall_seconds"] = round(time.perf_counter() - t0, 2)
        rows.append(row)
        emit(f"chaos/{name}", 0.0,
             f"converged={row['converged']};kills={row['kills']};"
             f"replanned={row['replanned_ticks']};"
             f"recovery_s={row['recovery_seconds']:.2f}")
    return rows


def write_artifact(rows: list[dict]) -> str:
    art = {
        "schema": "bench_chaos/v1",
        "scenarios": rows,
        "derived": {
            "all_converged": all(r["converged"] for r in rows),
            "total_kills": sum(r["kills"] for r in rows),
            "total_replanned_ticks": sum(r["replanned_ticks"]
                                         for r in rows),
        },
    }
    path = artifact_path()
    with open(path, "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)
    validate_artifact(path)
    return path


def validate_artifact(path: str | None = None) -> dict:
    path = path or artifact_path()
    with open(path) as f:
        art = json.load(f)
    assert art["schema"] == "bench_chaos/v1", art["schema"]
    assert set(art) == SCHEMA_KEYS, set(art) ^ SCHEMA_KEYS
    assert art["scenarios"], "no scenario rows"
    for row in art["scenarios"]:
        assert set(row) == ROW_KEYS, set(row) ^ ROW_KEYS
        assert row["converged"] is True, \
            f"{row['scenario']} did not converge under its fault mix: {row}"
        assert row["epochs"] >= 1, row
        assert row["recovery_seconds"] >= 0.0, row
        assert row["replanned_ticks"] >= 0, row
        assert isinstance(row["fault_seed"], int), row
    assert art["derived"]["all_converged"], art["derived"]
    return art


def run() -> None:
    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    names = QUICK_SCENARIOS if quick else tuple(SCENARIOS)
    rows = run_matrix(names)
    write_artifact(rows)


if __name__ == "__main__":
    run()
