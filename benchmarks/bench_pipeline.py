"""Pipeline-engine benchmark -> BENCH_pipeline.json (tracked across PRs).

Runs the §4 hot path — ``repro.core.pipeline`` via ``launch/train.py
--strategy pipeline`` — over the schedule x wire-codec grid on a small
dense config, in subprocesses (the stage count needs
``--xla_force_host_platform_device_count`` set *before* jax initialises,
which an already-running bench harness cannot do).

The artifact records, per benchmark: us/step, final loss after the fixed
step budget, on-wire bytes per boundary hop (int8 scales accounted), the
timetable-measured bubble fraction and the peak activation-stash
estimate.  The derived block checks the PR acceptance claims:
  * int8 wire codes cut wire_bytes_per_hop >= 1.9x vs bf16 at matching loss
  * 1F1B shrinks the stash vs GPipe at n_micro >= 2 * n_stages, with both
    schedules agreeing on loss to tolerance
  * zerobubble/interleaved(V=2) land strictly below 1F1B's bubble
    (<= 0.14 / <= 0.158 at P=4, M=8) at matching loss
  * the int8 stash never exceeds the bf16 stash on the ring schedules
    (the rings hold the codes+scales pair, not decoded activations)

``BENCH_QUICK=1`` shrinks the grid/steps (smoke.sh schema validation).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import emit

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ARTIFACT = os.path.join(ROOT, "BENCH_pipeline.json")
QUICK_ARTIFACT = os.path.join(tempfile.gettempdir(),
                              "BENCH_pipeline.quick.json")


def artifact_path() -> str:
    """Quick runs validate a scratch artifact; full runs refresh the
    committed one."""
    return QUICK_ARTIFACT if os.environ.get("BENCH_QUICK", "0") == "1" \
        else ARTIFACT

SCHEMA_KEYS = {"schema", "arch", "config", "benchmarks", "derived"}
BENCH_KEYS = {"name", "schedule", "virtual_stages", "wire_codec",
              "us_per_step", "final_loss", "wire_bytes_per_hop",
              "bubble_fraction", "peak_stash_bytes", "stash_codes",
              "grad_ring_codes", "loop_length"}


def _scenario(name: str, schedule: str, codec: str, cfg: dict,
              virtual_stages: int = 1) -> dict:
    """One training run in a subprocess; returns the benchmark record."""
    with tempfile.NamedTemporaryFile(suffix=".jsonl", delete=False) as f:
        metrics_path = f.name
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count="
                  f"{cfg['n_stages']}",
        PYTHONPATH=os.path.join(ROOT, "src"),
    )
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", cfg["arch"], "--smoke", "--strategy", "pipeline",
        "--pipeline-schedule", schedule, "--wire-codec", codec,
        "--pipeline-stages", str(cfg["n_stages"]),
        "--pipeline-microbatches", str(cfg["n_microbatches"]),
        "--bottleneck-dim", str(cfg["bottleneck_dim"]),
        "--steps", str(cfg["steps"]), "--batch-size", str(cfg["batch"]),
        "--seq-len", str(cfg["seq"]), "--log-every", str(cfg["steps"]),
        "--lr", "0.1", "--metrics-out", metrics_path,
    ]
    if virtual_stages > 1:
        # interleaved needs layers divisible by stages * virtual stages
        cmd += ["--pipeline-virtual-stages", str(virtual_stages),
                "--n-layers", str(cfg["n_stages"] * virtual_stages)]
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                              cwd=ROOT, timeout=1800)
        assert proc.returncode == 0, proc.stderr[-4000:]
        with open(metrics_path) as mf:
            records = [json.loads(line) for line in mf]
    finally:
        if os.path.exists(metrics_path):
            os.unlink(metrics_path)
    stats, final = records[0], records[-1]
    return {
        "name": name,
        "schedule": schedule,
        "virtual_stages": stats.get("virtual_stages", 1),
        "wire_codec": codec,
        "us_per_step": final["us_per_step"],
        "final_loss": round(final["loss"], 6),
        "wire_bytes_per_hop": stats["wire_bytes_per_hop"],
        # timetable-measured idle fraction (schedule_stats derives it from
        # the compiled Timetable, not the closed form)
        "bubble_fraction": round(stats["bubble_fraction"], 4),
        "peak_stash_bytes": stats["stash_bytes"],
        "stash_codes": stats["stash_codes"],
        "grad_ring_codes": stats.get("grad_ring_codes", 0),
        "loop_length": stats["loop_length"],
    }


def run() -> None:
    quick = os.environ.get("BENCH_QUICK", "0") == "1"
    cfg = {
        "arch": "llama3.2-1b",
        "n_stages": 2 if quick else 4,
        "n_microbatches": 4 if quick else 8,   # >= 2 * n_stages
        "batch": 4 if quick else 8,
        "seq": 16 if quick else 32,
        "steps": 6 if quick else 40,
        "bottleneck_dim": 16,
    }
    grid = [
        ("gpipe_bf16", "gpipe", "none", 1),
        ("gpipe_int8", "gpipe", "int8", 1),
        ("1f1b_bf16", "1f1b", "none", 1),
        ("1f1b_int8", "1f1b", "int8", 1),
        ("zerobubble_bf16", "zerobubble", "none", 1),
        ("zerobubble_int8", "zerobubble", "int8", 1),
        # V=2 doubles the layer count (8 layers as 4 stages x 2 chunks),
        # so us_per_step is not comparable to the 4-layer rows; the bubble
        # and stash columns are the point
        ("interleaved_v2_bf16", "interleaved", "none", 2),
        ("interleaved_v2_int8", "interleaved", "int8", 2),
    ]
    if quick:
        grid = [("gpipe_bf16", "gpipe", "none", 1),
                ("1f1b_int8", "1f1b", "int8", 1),
                ("zerobubble_bf16", "zerobubble", "none", 1),
                ("interleaved_v2_bf16", "interleaved", "none", 2)]

    benches = []
    for name, schedule, codec, v in grid:
        rec = _scenario(name, schedule, codec, cfg, virtual_stages=v)
        benches.append(rec)
        emit(f"pipeline/{name}", rec["us_per_step"],
             f"loss={rec['final_loss']};bytes_hop={rec['wire_bytes_per_hop']};"
             f"stash={rec['peak_stash_bytes']};"
             f"bubble={rec['bubble_fraction']}")

    by = {r["name"]: r for r in benches}

    def gap(a, b):
        return abs(a - b) / max(abs(a), abs(b), 1e-9)

    derived = {}
    if "gpipe_int8" in by:
        derived["int8_wire_cut_x"] = round(
            by["gpipe_bf16"]["wire_bytes_per_hop"]
            / by["gpipe_int8"]["wire_bytes_per_hop"], 3)
        derived["loss_gap_int8_vs_bf16"] = round(
            gap(by["gpipe_int8"]["final_loss"],
                by["gpipe_bf16"]["final_loss"]), 6)
    if "1f1b_bf16" in by:
        derived["stash_cut_1f1b_x"] = round(
            by["gpipe_bf16"]["peak_stash_bytes"]
            / by["1f1b_bf16"]["peak_stash_bytes"], 3)
        derived["loss_gap_1f1b_vs_gpipe"] = round(
            gap(by["1f1b_bf16"]["final_loss"],
                by["gpipe_bf16"]["final_loss"]), 6)
        derived["acceptance"] = {
            "int8_cut_ge_1p9x": derived.get("int8_wire_cut_x", 0) >= 1.9,
            "int8_loss_match_1pct": derived.get(
                "loss_gap_int8_vs_bf16", 1) < 0.01,
            "1f1b_stash_smaller_at_2x_micro": (
                cfg["n_microbatches"] >= 2 * cfg["n_stages"]
                and by["1f1b_bf16"]["peak_stash_bytes"]
                < by["gpipe_bf16"]["peak_stash_bytes"]),
            "1f1b_loss_match_1pct": derived["loss_gap_1f1b_vs_gpipe"] < 0.01,
        }
    # ISSUE 9 acceptance: the new schedules' timetable-measured bubbles
    # land strictly below 1F1B's, and the int8 ring stash regression
    # (codes stashed alongside decoded bf16) stays fixed
    acc = derived.setdefault("acceptance", {})
    base_bubble = by["1f1b_bf16"]["bubble_fraction"] if "1f1b_bf16" in by \
        else (cfg["n_stages"] - 1) / (cfg["n_microbatches"]
                                      + cfg["n_stages"] - 1)
    if "zerobubble_bf16" in by:
        zb = by["zerobubble_bf16"]
        acc["zerobubble_bubble_le_0p14"] = zb["bubble_fraction"] <= 0.14
        acc["zerobubble_beats_1f1b"] = zb["bubble_fraction"] < base_bubble
        derived["loss_gap_zerobubble_vs_gpipe"] = round(
            gap(zb["final_loss"], by["gpipe_bf16"]["final_loss"]), 6)
        acc["zerobubble_loss_match_1pct"] = \
            derived["loss_gap_zerobubble_vs_gpipe"] < 0.01
    if "interleaved_v2_bf16" in by:
        il = by["interleaved_v2_bf16"]
        acc["interleaved_bubble_le_0p158"] = il["bubble_fraction"] <= 0.158
        acc["interleaved_beats_1f1b"] = il["bubble_fraction"] < base_bubble
    for sched in ("1f1b", "zerobubble", "interleaved_v2"):
        b16, i8 = f"{sched}_bf16", f"{sched}_int8"
        if b16 in by and i8 in by:
            acc[f"{sched}_int8_stash_not_larger"] = (
                by[i8]["peak_stash_bytes"] <= by[b16]["peak_stash_bytes"])

    artifact = {
        "schema": "bench_pipeline/v2",
        "arch": f"{cfg['arch']} (smoke)",
        "config": {k: v for k, v in cfg.items() if k != "arch"},
        "quick": quick,
        "benchmarks": benches,
        "derived": derived,
    }
    out = artifact_path()
    with open(out, "w") as f:
        json.dump(artifact, f, indent=1)
        f.write("\n")
    emit("pipeline/artifact", 0.0, out)
    validate_artifact(out)


def validate_artifact(path: str | None = None) -> dict:
    """Schema gate used by `benchmarks/run.py --quick` and scripts/smoke.sh."""
    with open(path or artifact_path()) as f:
        art = json.load(f)
    missing = SCHEMA_KEYS - set(art)
    assert not missing, f"BENCH_pipeline.json missing keys: {missing}"
    assert art["schema"] == "bench_pipeline/v2", art["schema"]
    assert art["benchmarks"], "no benchmark records"
    for rec in art["benchmarks"]:
        miss = BENCH_KEYS - set(rec)
        assert not miss, f"benchmark {rec.get('name')} missing {miss}"
    return art


if __name__ == "__main__":
    run()
