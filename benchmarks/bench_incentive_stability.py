"""Paper Fig 9 (App. A): incentive stability vs (sync interval T_s, decay

gamma).  The figure's claim: syncing multiple times per hour keeps gamma
under 10h while staying stable."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import incentives


def run() -> None:
    sync_hours = [0.25, 0.5, 1.0, 2.0, 4.0]
    gammas = [1.0, 4.0, 10.0, 24.0]
    grid = {}
    for ts in sync_hours:
        for g in gammas:
            if g < ts:
                continue
            r = incentives.stability_simulation(ts, g, seed=0,
                                                horizon_hours=120.0)
            grid[(ts, g)] = r["cv"]
            emit(f"fig9_stability/ts{ts}_gamma{g}", 0.0,
                 f"cv={r['cv']:.4f};n_scores={r['n_scores']:.0f}")
    # the paper's operating point: sub-hour sync with gamma < 10h is stable
    op = grid[(0.5, 10.0)]
    worst = grid[(4.0, 4.0)]
    emit("fig9_claim/subhour_sync_gamma10h", 0.0,
         f"cv={op:.4f};vs_slow_sync={worst:.4f};stable={op < worst}")


if __name__ == "__main__":
    run()
