"""§Roofline summary bench: prints the (arch x shape x mesh) roofline table

from the dry-run results file if present (produced by
``python -m repro.launch.dryrun --all --out dryrun_all.json``); otherwise
computes two small cells live so ``-m benchmarks.run`` is self-contained.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_all.json")


def _emit_record(r: dict) -> None:
    if r.get("status") == "skipped":
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"SKIP:{r['reason'][:60]}")
        return
    if r.get("status") != "ok":
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
             f"ERROR:{r.get('error', '?')[:80]}")
        return
    mem = (r.get("memory_per_device") or {}).get("total_bytes", 0) / 2**30
    emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
         f"t_comp={r['t_compute']:.4f}s;t_mem={r['t_memory']:.4f}s;"
         f"t_coll={r['t_collective']:.4f}s;bound={r['bottleneck']};"
         f"useful={r['useful_fraction']:.2f};mem={mem:.1f}GiB")


def run() -> None:
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            records = json.load(f)
        for r in records:
            _emit_record(r)
        ok = sum(1 for r in records if r.get("status") == "ok")
        emit("roofline/summary", 0.0,
             f"{ok}_ok/{len(records)}_cells")
        return
    # fallback: two small cells computed in a subprocess (needs the 512
    # fake-device env, which must not leak into this process)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    for arch, shape in (("xlstm-125m", "train_4k"),
                        ("llama3.2-1b", "decode_32k")):
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--out", "/tmp/_bench_cell.json"],
            capture_output=True, text=True, timeout=1800,
            env=dict(os.environ, PYTHONPATH=src))
        if proc.returncode == 0:
            with open("/tmp/_bench_cell.json") as f:
                for r in json.load(f):
                    _emit_record(r)
        else:
            emit(f"roofline/{arch}/{shape}", 0.0, "ERROR:dryrun_failed")


if __name__ == "__main__":
    run()
