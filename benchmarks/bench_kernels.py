"""Kernel-level benchmark: VMEM working sets per BlockSpec tiling + CPU

oracle throughput (the TPU numbers come from the §Roofline dry-run; this
table documents that every kernel's working set fits the ~16 MiB VMEM/core
budget at its production tiling)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.common import human_bytes
from repro.kernels import ops


def vmem_working_sets() -> None:
    cases = [
        # kernel, tiling description, bytes resident per grid step
        ("flash_attention", "bq=bkv=512,D=128,bf16",
         (512 * 128 * 2) * 3 + 512 * 128 * 4 + 512 * 2 * 4),
        ("bottleneck_encode", "rows=256,d=7168,db=128",
         256 * 7168 * 2 + 7168 * 128 * 4 + 256 * 128 * 2 + 7168 * 4),
        ("bottleneck_decode", "rows=256,d=7168,db=128",
         256 * 128 * 2 + 128 * 7168 * 4 + 2 * 256 * 7168 * 2),
        ("quant_stream", "rows=512,block=256",
         512 * 256 * 4 + 512 * 256 + 512 * 4),
        ("shard_merge", "miners=16,cols=16384",
         16 * 16384 * 4 + 16384 * 4 + 16 * 4),
    ]
    budget = 16 * 2**20
    for name, tiling, nbytes in cases:
        emit(f"kernel_vmem/{name}", 0.0,
             f"{tiling};working_set={human_bytes(nbytes)};"
             f"fits_16MiB={nbytes < budget}")


def oracle_throughput() -> None:
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 512, 2048), jnp.bfloat16)
    gamma = jnp.ones(2048, jnp.float32)
    wd = jnp.asarray(rng.randn(2048, 32) * 0.02, jnp.float32)
    us = time_call(lambda: ops.bottleneck_encode(x, gamma, wd))
    emit("bottleneck_encode_8x512x2048", us,
         f"{8*512*2048*2/us:.0f}MBps_in")

    q = jnp.asarray(rng.randn(1, 1024, 8, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 1024, 2, 64), jnp.bfloat16)
    us = time_call(lambda: ops.flash_attention(q, k, k))
    emit("attention_1x1024_gqa", us, f"seq=1024;gqa=4:1")


def run() -> None:
    vmem_working_sets()
    oracle_throughput()


if __name__ == "__main__":
    run()
