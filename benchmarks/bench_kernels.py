"""Kernel-level benchmark: VMEM working sets per BlockSpec tiling + CPU

oracle throughput (the TPU numbers come from the §Roofline dry-run; this
table documents that every kernel's working set fits the ~16 MiB VMEM/core
budget at its production tiling)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.common import human_bytes
from repro.kernels import ops


def vmem_working_sets() -> None:
    cases = [
        # kernel, tiling description, bytes resident per grid step
        ("flash_attention", "bq=bkv=512,D=128,bf16",
         (512 * 128 * 2) * 3 + 512 * 128 * 4 + 512 * 2 * 4),
        ("bottleneck_encode", "rows=256,d=7168,db=128",
         256 * 7168 * 2 + 7168 * 128 * 4 + 256 * 128 * 2 + 7168 * 4),
        ("bottleneck_decode", "rows=256,d=7168,db=128",
         256 * 128 * 2 + 128 * 7168 * 4 + 2 * 256 * 7168 * 2),
        ("quant_stream", "rows=512,block=256",
         512 * 256 * 4 + 512 * 256 + 512 * 4),
        ("shard_merge", "miners=16,cols=16384",
         16 * 16384 * 4 + 16384 * 4 + 16 * 4),
    ]
    budget = 16 * 2**20
    for name, tiling, nbytes in cases:
        emit(f"kernel_vmem/{name}", 0.0,
             f"{tiling};working_set={human_bytes(nbytes)};"
             f"fits_16MiB={nbytes < budget}")


def oracle_throughput() -> None:
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 512, 2048), jnp.bfloat16)
    gamma = jnp.ones(2048, jnp.float32)
    wd = jnp.asarray(rng.randn(2048, 32) * 0.02, jnp.float32)
    us = time_call(lambda: ops.bottleneck_encode(x, gamma, wd))
    emit("bottleneck_encode_8x512x2048", us,
         f"{8*512*2048*2/us:.0f}MBps_in")

    q = jnp.asarray(rng.randn(1, 1024, 8, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 1024, 2, 64), jnp.bfloat16)
    us = time_call(lambda: ops.flash_attention(q, k, k))
    emit("attention_1x1024_gqa", us, f"seq=1024;gqa=4:1")


def boundary_codec_table() -> None:
    """Pipeline-boundary hot path (core/pipeline): the fused Pallas
    encode/decode and the int8 wire codec vs their jnp oracles.  On CPU the
    Pallas numbers are the *interpret-mode emulation* (correctness path);
    the fusion win — one HBM read of x, one write of the 64x-smaller code —
    is a TPU claim measured by the §Roofline dry-run."""
    import jax

    from repro.kernels import bottleneck_fused as bf
    from repro.kernels import quant_stream as qs
    from repro.kernels import ref

    rng = np.random.RandomState(1)
    B, S, D, DB = 8, 128, 2048, 32
    x = jnp.asarray(rng.randn(B, S, D), jnp.bfloat16)
    gamma = jnp.ones(D, jnp.float32)
    wd = jnp.asarray(rng.randn(D, DB) * 0.02, jnp.float32)
    wu = jnp.asarray(rng.randn(DB, D) * 0.1, jnp.float32)
    alpha = jnp.asarray(0.5, jnp.float32)
    z = jnp.asarray(rng.randn(B, S, DB), jnp.float32)

    enc_ref = jax.jit(lambda x: ref.bottleneck_encode(x, gamma, wd))
    enc_pal = jax.jit(lambda x: bf.bottleneck_encode(x, gamma, wd,
                                                     interpret=True))
    emit("boundary/encode_ref_jnp", time_call(enc_ref, x), f"{B}x{S}x{D}")
    emit("boundary/encode_pallas_interpret", time_call(enc_pal, x),
         f"{B}x{S}x{D}->db{DB}")

    dec_ref = jax.jit(lambda z: ref.bottleneck_decode_gated(z, wu, alpha))
    dec_pal = jax.jit(lambda z: bf.bottleneck_decode_gated(z, wu, alpha,
                                                           interpret=True))
    emit("boundary/decode_ref_jnp", time_call(dec_ref, z), f"db{DB}->{D}")
    emit("boundary/decode_pallas_interpret", time_call(dec_pal, z),
         f"db{DB}->{D}")

    rt = jax.jit(lambda z: qs.int8_wire_roundtrip(z, interpret=True))
    us = time_call(rt, z)
    nb = qs.wire_nbytes(z.shape)
    emit("boundary/int8_wire_roundtrip", us,
         f"bytes={nb};vs_bf16={z.size * 2 / nb:.2f}x")


def run() -> None:
    vmem_working_sets()
    oracle_throughput()
    boundary_codec_table()


if __name__ == "__main__":
    run()
