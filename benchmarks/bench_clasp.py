"""Paper Fig 8 (+ App. B): CLASP loss contributions, sorted by value and by

network position; detection reliability across seeds."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import clasp


def fig8_contributions() -> None:
    cfg = clasp.ToyConfig(n_samples=5000)
    malicious = [3, 12]
    recs, layer_of = clasp.toy_simulation(cfg, malicious)
    n = cfg.n_layers * cfg.miners_per_layer
    rep = clasp.attribute(recs, n, layer_of)

    # (a) sorted by value: bad actors produce the largest contributions
    order = np.argsort(-np.nan_to_num(rep.mean_loss))
    top2 = set(order[:2].tolist())
    emit("fig8a_sorted_by_value", 0.0,
         f"top2={sorted(top2)};malicious={malicious};"
         f"match={top2 == set(malicious)}")

    # (b) sorted by position: fair miners in bad layers are suppressed
    suppression = clasp.fair_miner_suppression(rep, malicious)
    emit("fig8b_position_suppression", 0.0,
         f"fair_in_bad_layer_minus_clean={suppression:+.4f}(expected<0)")


def detection_reliability() -> None:
    """Detection rate for both attribution rules across 20 seeds."""
    hits_mean = hits_reg = fp = 0
    trials = 20
    for seed in range(trials):
        cfg = clasp.ToyConfig(n_samples=3000, seed=seed)
        rng = np.random.RandomState(seed)
        bad = sorted(rng.choice(25, size=2, replace=False).tolist())
        recs, layer_of = clasp.toy_simulation(cfg, bad)
        r1 = clasp.attribute(recs, 25, layer_of)
        r2 = clasp.attribute_regression(recs, 25, layer_of)
        hits_mean += set(np.where(r1.flagged)[0]) >= set(bad)
        hits_reg += set(np.where(r2.flagged)[0]) >= set(bad)
        fp += len(set(np.where(r2.flagged)[0]) - set(bad))
    emit("fig8_detection_rate/cond_mean", 0.0, f"{hits_mean}/{trials}")
    emit("fig8_detection_rate/regression", 0.0,
         f"{hits_reg}/{trials};false_pos_total={fp}")


def run() -> None:
    fig8_contributions()
    detection_reliability()


if __name__ == "__main__":
    run()
