"""Paper Fig 5: convergence of the bottleneck-Llama vs the uncompressed

baseline at 32x / 64x / 128x compression (fp32 basis).

CPU-scale reproduction: a reduced-width Llama3 family model trained on the
structured synthetic corpus for a few hundred steps; reported: the final
train loss per variant and the gap to baseline.  The paper's claim under
test: 'increasing the compression ratio from 32x to 128x resulted in only a
slight degradation in convergence' and near-baseline convergence overall.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import configs
from repro.api import (NetworkModel, SimulatedNetworkTransport, Swarm,
                       SwarmConfig)
from repro.configs.base import BottleneckConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import build_model

STEPS = 150
SEQ = 128
BATCH = 16


def train_variant(n_bottlenecks: int, bottleneck_dim: int, steps=STEPS):
    cfg = configs.smoke_variant(configs.get("iota-bottleneck-1.5b"))
    mcfg = dataclasses.replace(
        cfg.model,
        d_model=128, n_layers=8, n_heads=8, n_kv_heads=4, d_head=16,
        d_ff=512, vocab_size=2048,
        bottleneck=BottleneckConfig(n_bottlenecks=n_bottlenecks,
                                    bottleneck_dim=bottleneck_dim))
    cfg = dataclasses.replace(cfg, model=mcfg)
    model = build_model(cfg)
    corpus = SyntheticCorpus(DataConfig(vocab_size=2048, seq_len=SEQ,
                                        batch_size=BATCH, seed=0))
    state = model.init_train_state(jax.random.key(0))
    step = jax.jit(lambda s, b: model.train_step(s, b))
    losses = []
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(t).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    tail = sum(losses[-10:]) / 10
    return losses, tail


def run() -> None:
    # ratios are vs fp32 at this reduced width (d_model=128): dim 8 -> 32x,
    # dim 4 -> 64x, dim 2 -> 128x — same geometry as the paper's 2048/32
    variants = [
        ("baseline", 0, 0),
        ("bottleneck_32x", 3, 8),
        ("bottleneck_64x", 3, 4),
        ("bottleneck_128x", 3, 2),
    ]
    results = {}
    for name, n_b, dim in variants:
        losses, tail = train_variant(n_b, dim)
        results[name] = (losses[0], tail)
        emit(f"fig5_convergence/{name}", 0.0,
             f"first={losses[0]:.3f};final={tail:.3f}")
    base = results["baseline"][1]
    for name in ("bottleneck_32x", "bottleneck_64x", "bottleneck_128x"):
        gap = results[name][1] - base
        emit(f"fig5_gap/{name}", 0.0, f"gap_to_baseline={gap:+.3f}")
    # the paper's 32x->128x claim: degradation between ratios is slight
    slight = results["bottleneck_128x"][1] - results["bottleneck_32x"][1]
    emit("fig5_claim/32x_to_128x_degradation", 0.0, f"delta={slight:+.3f}")
    swarm_convergence()


def swarm_convergence() -> None:
    """Same question through the decentralized path: does the swarm facade
    (wire-compressed stages, DiLoCo merges) still converge — and what would
    the trajectory cost in simulated wall-clock over consumer links?"""
    mcfg = dataclasses.replace(
        configs.smoke_variant(configs.get("llama3.2-1b")).model, n_layers=6)
    sw = SwarmConfig(n_stages=3, miners_per_stage=2, inner_steps=10, b_min=2,
                     batch_size=4, seq_len=32, validators=0, seed=0)
    transport = SimulatedNetworkTransport(NetworkModel.consumer())
    swarm = Swarm.create(mcfg, sw, transport=transport)
    stats = swarm.run(4)
    first, last = stats[0].mean_loss, stats[-1].mean_loss
    emit("fig5_swarm/convergence", 0.0,
         f"first={first:.3f};final={last:.3f};delta={last - first:+.3f}")
    emit("fig5_swarm/sim_wall_clock", 0.0,
         f"{transport.elapsed_seconds():.1f}s_over_consumer_links")


if __name__ == "__main__":
    run()
