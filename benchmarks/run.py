"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Modules may additionally write machine-readable artifacts (tracked across
PRs): ``bench_pipeline`` writes ``BENCH_pipeline.json`` and
``bench_butterfly`` writes ``BENCH_butterfly.json`` at the repo root.

  fig5   bench_convergence        — bottleneck compression vs baseline
  fig7   bench_butterfly          — agreement matrix, resilience, §5.3 bytes
  fig8   bench_clasp              — CLASP attribution + detection rates
  fig9   bench_incentive_stability— stability vs (T_s, gamma)
  §2     bench_codecs             — compressed-sharing codec table
  §2.1   bench_swarm              — B_eff / straggler / store traffic
  kernels bench_kernels           — VMEM working sets + oracle throughput
  §4     bench_pipeline           — schedules x wire codecs -> BENCH_pipeline.json
  §Roofline bench_roofline        — dry-run roofline table
  chaos  bench_chaos              — fault-injection scenario matrix ->
                                    BENCH_chaos.json (docs/CHAOS.md)
  serve  bench_serve              — decode tok/s + latency vs lanes ->
                                    BENCH_serve.json (docs/SERVE.md)

Usage:
  python -m benchmarks.run [module-substring]
  python -m benchmarks.run --quick    # pipeline + butterfly benches only,
                                      # reduced budget, then validate the
                                      # JSON artifact schemas
"""
from __future__ import annotations

import os
import sys
import time
import traceback

# examples self-insert src/; the harness does the same so the smoke gate
# (`python -m benchmarks.run --quick`) works without PYTHONPATH=src
_SRC = os.path.abspath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

MODULES = [
    "benchmarks.bench_convergence",
    "benchmarks.bench_butterfly",
    "benchmarks.bench_clasp",
    "benchmarks.bench_incentive_stability",
    "benchmarks.bench_codecs",
    "benchmarks.bench_swarm",
    "benchmarks.bench_kernels",
    "benchmarks.bench_pipeline",
    "benchmarks.bench_roofline",
    "benchmarks.bench_chaos",
    "benchmarks.bench_serve",
]


def main() -> None:
    args = sys.argv[1:]
    quick = "--quick" in args
    args = [a for a in args if a != "--quick"]
    only = args[0] if args else None
    modules = MODULES
    if quick:
        # the fast CI gate: exercise the pipeline grid and the
        # store-and-forward butterfly sync at a reduced budget and
        # hard-validate both artifact schemas.  A module filter would
        # skip the benches and then validate stale/missing artifacts, so
        # it is ignored here.
        if only:
            print(f"# --quick runs only the artifact gates; "
                  f"ignoring filter {only!r}", flush=True)
            only = None
        os.environ["BENCH_QUICK"] = "1"
        modules = ["benchmarks.bench_pipeline", "benchmarks.bench_butterfly",
                   "benchmarks.bench_chaos", "benchmarks.bench_serve"]
    failures = 0
    for mod_name in modules:
        if only and only not in mod_name:
            continue
        t0 = time.time()
        print(f"# === {mod_name} ===", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failures += 1
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
    if quick and not failures:
        from benchmarks.bench_butterfly import (
            validate_artifact as validate_butterfly)
        from benchmarks.bench_pipeline import validate_artifact
        art = validate_artifact()
        print(f"# BENCH_pipeline.json schema OK "
              f"({len(art['benchmarks'])} records)", flush=True)
        art = validate_butterfly()
        print(f"# BENCH_butterfly.json schema OK "
              f"({len(art['benchmarks'])} records, "
              f"rel_err={art['derived']['max_rel_err']})", flush=True)
        from benchmarks.bench_chaos import (
            validate_artifact as validate_chaos)
        art = validate_chaos()
        print(f"# BENCH_chaos.json schema OK "
              f"({len(art['scenarios'])} scenarios, "
              f"all_converged={art['derived']['all_converged']})",
              flush=True)
        from benchmarks.bench_serve import (
            validate_artifact as validate_serve)
        art = validate_serve()
        print(f"# BENCH_serve.json schema OK "
              f"({len(art['rows'])} rows, "
              f"best_tok_per_s={art['derived']['best_tok_per_s']})",
              flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
