"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  fig5   bench_convergence        — bottleneck compression vs baseline
  fig7   bench_butterfly          — agreement matrix, resilience, §5.3 bytes
  fig8   bench_clasp              — CLASP attribution + detection rates
  fig9   bench_incentive_stability— stability vs (T_s, gamma)
  §2     bench_codecs             — compressed-sharing codec table
  §2.1   bench_swarm              — B_eff / straggler / store traffic
  kernels bench_kernels           — VMEM working sets + oracle throughput
  §Roofline bench_roofline        — dry-run roofline table
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    "benchmarks.bench_convergence",
    "benchmarks.bench_butterfly",
    "benchmarks.bench_clasp",
    "benchmarks.bench_incentive_stability",
    "benchmarks.bench_codecs",
    "benchmarks.bench_swarm",
    "benchmarks.bench_kernels",
    "benchmarks.bench_roofline",
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = 0
    for mod_name in MODULES:
        if only and only not in mod_name:
            continue
        t0 = time.time()
        print(f"# === {mod_name} ===", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failures += 1
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
