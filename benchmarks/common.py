"""Benchmark harness utilities: timing + the CSV contract of run.py."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (results block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py output contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")
