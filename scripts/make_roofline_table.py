"""Generate the §Dry-run / §Roofline markdown tables from dryrun_all.json."""
import json
import sys

HW = "v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"
PEAK = 197e12


def fmt(records, mesh):
    rows = []
    for r in records:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        mem = (r.get("memory_per_device") or {}).get("total_bytes", 0) / 2**30
        t_dom = max(r["t_compute"], r["t_memory"], r["t_collective"])
        ideal = r["model_flops"] / (r["chips"] * PEAK)
        frac = ideal / t_dom if t_dom > 0 else 0.0
        rows.append((r["arch"], r["shape"], r["t_compute"], r["t_memory"],
                     r["t_collective"], r["bottleneck"],
                     r["useful_fraction"], frac, mem,
                     r["compile_seconds"], r.get("t_memory_kernelized", 0.0)))
    return rows


def main():
    with open(sys.argv[1] if len(sys.argv) > 1 else "dryrun_all.json") as f:
        records = json.load(f)
    for mesh, chips in (("single_pod", 256), ("multi_pod", 512)):
        print(f"\n### {mesh} ({chips} chips) — {HW}\n")
        print("| arch | shape | t_comp (s) | t_mem (s) | t_mem_kern (s) |"
              " t_coll (s) | bound | useful | roofline frac | GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for row in fmt(records, mesh):
            a, s, tc, tm, tl, b, u, f, mem, cs, tmk = row
            print(f"| {a} | {s} | {tc:.4f} | {tm:.4f} | {tmk:.4f} |"
                  f" {tl:.4f} | {b} | {u:.2f} | {f:.3f} | {mem:.1f} |")
    # hillclimb candidate ranking
    print("\n### candidates\n")
    sp = fmt(records, "single_pod")
    worst = sorted(sp, key=lambda r: r[7])[:6]
    print("worst roofline fraction:")
    for r in worst:
        print(f"  {r[0]} x {r[1]}: frac={r[7]:.4f} bound={r[5]}")
    coll = sorted(sp, key=lambda r: -(r[4] / max(max(r[2], r[3], r[4]), 1e-12)
                                      if r[5] == 'collective' else
                                      r[4] / max(r[2], r[3], r[4], 1e-12)))[:6]
    print("most collective-bound (t_coll share):")
    for r in coll:
        share = r[4] / max(r[2], r[3], r[4])
        print(f"  {r[0]} x {r[1]}: t_coll={r[4]:.4f}s share={share:.2f}")


if __name__ == "__main__":
    main()
