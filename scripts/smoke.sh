#!/usr/bin/env bash
# Pre-commit smoke check: swarmlint gate + fast test subset + sanitized
# store/transport shards + the quickstart example + a 1F1B
# pipeline-engine quickstart + the benchmark-artifact schema gate.
#
#   scripts/smoke.sh            # from the repo root
#
# Runs the swarmlint static gate (`python -m repro.analysis src`, exit 1
# on any finding — rule catalog in docs/ANALYSIS.md), everything except
# tests marked `slow` (marker registered in pyproject.toml, which also
# sets pythonpath=src — no PYTHONPATH needed), a sanitized re-run of the
# store/transport shards (REPRO_CHECKED_STORE=1 installs the
# repro.analysis.checked_store KeySchema/digest sanitizer for the whole
# session), then drives examples/quickstart.py end to end at a reduced
# step count,
# the sharded store-and-forward sync quickstart (examples/sharded_sync.py:
# tiny N=4 swarm over SimulatedNetworkTransport, asserts merged-anchor
# parity with the dense path), the multi-process socket-transport gate
# (examples/multiprocess_swarm.py: StoreServer child process + real TCP,
# asserts dense AND sharded loss match the in-process transport at the
# same seed), the concurrent actor-runtime gate (examples/actor_swarm.py:
# every miner/validator its own spawned process over the EventDriver,
# asserts dense AND sharded trajectories bit-match the in-process swarm
# at the same seed), the chaos shard (examples/chaos_swarm.py: the
# kill-and-resume and store-failover scenarios from repro.scenarios on a
# real spawned fleet — docs/CHAOS.md), a short 1F1B+int8 pipelined
# training run
# (launch/train.py --strategy pipeline), an interleaved virtual-stage run
# (--pipeline-schedule interleaved --pipeline-virtual-stages 2, exercising
# the schedule compiler's V>1 chunk path), the serve shard
# (launch/serve.py --swarm over the socket store: pipelined
# continuous-batching decode, token parity vs the sequential oracle —
# docs/SERVE.md), and `benchmarks/run.py --quick`
# (reduced pipeline + butterfly + chaos-matrix + serve benches that
# hard-validate the BENCH_pipeline.json / BENCH_butterfly.json /
# BENCH_chaos.json / BENCH_serve.json schemas).
# This is the documented check to run before every commit; the full suite
# is `python -m pytest -q`.
set -euo pipefail
cd "$(dirname "$0")/.."

# Excluded from the smoke gate (run them via the full suite when relevant):
#   test_kernels.py      — interpret-mode Pallas sweeps, ~70s (green on CPU)
#   test_multidevice.py  — slow-marked subprocess suite (green on CPU)
#   test_system.py::test_claim_c3_...     — known-red since the seed
#     (baseline fails its own learning threshold at 60 steps)
echo "== smoke: swarmlint (repro.analysis) — any finding fails the commit =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis src

echo
echo "== smoke: fast test subset (excluding -m slow + kernel sweeps) =="
python -m pytest -q -m "not slow" \
    --ignore=tests/test_kernels.py \
    --ignore=tests/test_multidevice.py \
    --deselect "tests/test_system.py::test_claim_c3_bottleneck_trains_close_to_baseline" \
    tests

echo
echo "== smoke: sanitized store/transport shards (REPRO_CHECKED_STORE=1) =="
REPRO_CHECKED_STORE=1 python -m pytest -q -m "not slow" \
    tests/test_state_store.py tests/test_socket_transport.py

echo
echo "== smoke: quickstart example (reduced steps) =="
QUICKSTART_STEPS="${QUICKSTART_STEPS:-60}" python examples/quickstart.py

echo
echo "== smoke: sharded store-and-forward sync (N=4, simulated network) =="
python examples/sharded_sync.py

echo
echo "== smoke: multi-process socket transport (store in its own process) =="
python examples/multiprocess_swarm.py

echo
echo "== smoke: concurrent actor runtime (spawned miner/validator fleet) =="
ACTOR_SWARM_EPOCHS="${ACTOR_SWARM_EPOCHS:-2}" python examples/actor_swarm.py

echo
echo "== smoke: chaos shard (kill-and-resume + store failover) =="
CHAOS_SCENARIOS="${CHAOS_SCENARIOS:-kill-n-miners,store-failover}" \
python examples/chaos_swarm.py

echo
echo "== smoke: 1F1B pipeline quickstart (2 stages, int8 wire) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
python -m repro.launch.train --arch llama3.2-1b --smoke \
    --strategy pipeline --pipeline-schedule 1f1b --wire-codec int8 \
    --pipeline-microbatches 4 --steps 6 --batch-size 4 --seq-len 16 \
    --log-every 3

echo
echo "== smoke: interleaved pipeline quickstart (2 stages x 2 virtual) =="
XLA_FLAGS=--xla_force_host_platform_device_count=2 \
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
python -m repro.launch.train --arch llama3.2-1b --smoke \
    --strategy pipeline --pipeline-schedule interleaved \
    --pipeline-virtual-stages 2 --n-layers 4 --wire-codec int8 \
    --pipeline-microbatches 4 --steps 6 --batch-size 4 --seq-len 16 \
    --log-every 3

echo
echo "== smoke: serve plane (pipelined decode vs sequential oracle) =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
python -m repro.launch.serve --arch llama3.2-1b --smoke --swarm \
    --stages 2 --lanes 2 --requests 3 --prompt-len 8 --max-new 6 \
    --transport socket

echo
echo "== smoke: pipeline benchmark artifact schema (--quick) =="
python -m benchmarks.run --quick

echo
echo "smoke OK"
