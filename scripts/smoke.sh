#!/usr/bin/env bash
# Pre-commit smoke check: fast test subset + the quickstart example.
#
#   scripts/smoke.sh            # from the repo root
#
# Runs everything except tests marked `slow` (marker registered in
# pyproject.toml, which also sets pythonpath=src — no PYTHONPATH needed),
# then drives examples/quickstart.py end to end at a reduced step count.
# This is the documented check to run before every commit; the full suite
# is `python -m pytest -q`.
set -euo pipefail
cd "$(dirname "$0")/.."

# Excluded from the smoke gate (run them via the full suite when relevant):
#   test_kernels.py / test_multidevice.py — need accelerator hardware; red
#     on CPU-only containers since the seed
#   test_system.py::test_claim_c3_...     — known-red since the seed
#     (baseline fails its own learning threshold at 60 steps)
echo "== smoke: fast test subset (excluding -m slow + hardware suites) =="
python -m pytest -q -m "not slow" \
    --ignore=tests/test_kernels.py \
    --ignore=tests/test_multidevice.py \
    --deselect "tests/test_system.py::test_claim_c3_bottleneck_trains_close_to_baseline" \
    tests

echo
echo "== smoke: quickstart example (reduced steps) =="
QUICKSTART_STEPS="${QUICKSTART_STEPS:-60}" python examples/quickstart.py

echo
echo "smoke OK"
