"""Wire codecs for the *compressed sharing* stage (paper §2, stage 2) and

CLASP's top-k logit reporting (§6).

Uniform API over flat fp32 vectors:

    payload = encode(vec, codec)        # {"codec", "data", ...meta}
    vec2    = decode(payload, n)        # fp32 (n,)
    nbytes  = payload_bytes(payload)    # honest on-wire size

Codecs:
  * "none"  — fp32 passthrough (baseline / full-sync stage)
  * "bf16"  — 2x (the paper's activation wire dtype)
  * "int8"  — 4x+ blockwise symmetric (Pallas ``quant_stream`` kernel on TPU)
  * "topk"  — magnitude top-k sparsification (values bf16 + int32 indices),
              the DisTrO/Aji-Heafield-style gradient compression the paper
              cites for ~100-800x; ratio set by ``topk_frac``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import round_up
from repro.kernels import ops

CODECS = ("none", "bf16", "int8", "topk")
INT8_BLOCK = 256
# codecs whose encode commutes with INT8_BLOCK-aligned slicing (per-shard
# codes bit-equal slices of a whole-vector encode) — the sharded butterfly
# sync's parity precondition; topk is global over the vector
SLICEABLE_CODECS = ("none", "bf16", "int8")


def encode(vec: jax.Array, codec: str, topk_frac: float = 1 / 64) -> dict:
    vec = jnp.asarray(vec, jnp.float32)
    (n,) = vec.shape
    if codec == "none":
        return {"codec": "none", "data": vec}
    if codec == "bf16":
        return {"codec": "bf16", "data": vec.astype(jnp.bfloat16)}
    if codec == "int8":
        pad = round_up(n, INT8_BLOCK) - n
        q, scales = ops.quantize_int8(jnp.pad(vec, (0, pad)), block=INT8_BLOCK)
        return {"codec": "int8", "data": q, "scales": scales, "n": n}
    if codec == "topk":
        k = max(1, int(n * topk_frac))
        _, idx = jax.lax.top_k(jnp.abs(vec), k)
        vals = vec[idx]
        return {"codec": "topk", "data": vals.astype(jnp.bfloat16),
                "idx": idx.astype(jnp.int32), "n": n}
    raise ValueError(f"unknown codec {codec!r}")


def decode(payload: dict, n: int | None = None) -> jax.Array:
    codec = payload["codec"]
    if codec == "none":
        return payload["data"]
    if codec == "bf16":
        return payload["data"].astype(jnp.float32)
    if codec == "int8":
        full = ops.dequantize_int8(payload["data"], payload["scales"],
                                   block=INT8_BLOCK)
        return full[: payload["n"]]
    if codec == "topk":
        out = jnp.zeros((payload["n"],), jnp.float32)
        return out.at[payload["idx"]].set(payload["data"].astype(jnp.float32))
    raise ValueError(f"unknown codec {codec!r}")


def payload_bytes(payload: dict) -> int:
    total = 0
    for k, v in payload.items():
        if isinstance(v, (jax.Array, np.ndarray)):
            total += v.size * jnp.dtype(v.dtype).itemsize
    return total


def compression_ratio(payload: dict, n: int) -> float:
    return (n * 4) / max(payload_bytes(payload), 1)


# ---------------------------------------------------------------------------
# Top-k logits (CLASP §6: 'requiring miners to submit only top-k compressed
# logits, validators can recompute exact losses')
# ---------------------------------------------------------------------------


def topk_logits(logits: jax.Array, k: int = 64) -> dict:
    """(..., V) -> {values (..., k) bf16, idx (..., k) int32, lse (...)}.

    Keeping the exact logsumexp alongside the top-k values lets a validator
    recompute the *exact* per-token loss whenever the label is inside the
    top-k set (and bound it otherwise) — tamper-evident loss reporting in
    O(k) instead of O(V) bandwidth.
    """
    vals, idx = jax.lax.top_k(logits, k)
    return {"values": vals.astype(jnp.bfloat16), "idx": idx.astype(jnp.int32),
            "lse": jax.nn.logsumexp(logits, axis=-1).astype(jnp.float32)}


def loss_from_topk(payload: dict, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Recompute per-token NLL from a top-k report.  Returns (nll, exact_mask):

    exact where the label appears in the top-k indices; otherwise nll is a
    lower bound (label logit bounded by the k-th value)."""
    idx = payload["idx"]
    vals = payload["values"].astype(jnp.float32)
    lse = payload["lse"]
    hit = idx == labels[..., None]
    in_topk = jnp.any(hit, axis=-1)
    label_logit = jnp.where(
        in_topk,
        jnp.sum(jnp.where(hit, vals, 0.0), axis=-1),
        vals[..., -1],                     # bound by the smallest reported
    )
    return lse - label_logit, in_topk
