"""Pipeline-parallel engine (paper C1 + C3 on-mesh): GPipe and 1F1B

schedules in ``shard_map`` with the ``model`` mesh axis as the stage axis,
streaming microbatch activations stage-to-stage via ``ppermute`` — and, when
``compress=True``, streaming the paper's *bottleneck codes* (width d_b)
instead of full-width activations, cutting inter-stage bytes by
d_model/d_b (64x for the paper's 2048->32).  ``wire_codec="int8"`` quantizes
the codes on the wire (per-block symmetric int8, one fp32 scale per block),
doubling 64x to the paper's headline 128x.

Faithfulness map:
  miners on one layer-slice   -> devices in one model-axis row
  S3 activation hand-off      -> ppermute along ``model``
  bottleneck block at miner Tx-> encode at stage exit (stage owns W_down)
  post-bottleneck at miner Rx -> decode at stage entry (stage owns W_up of
                                 the previous boundary)
  DP across pipeline replicas -> ``data`` (x ``pod``) axes

Schedules (``PipelineSpec.schedule``):
  * ``"gpipe"``  — the golden reference: T = n_micro + n_stages - 1 ticks;
    autodiff through the tick scan gives the backward pipeline automatically
    (transpose of ppermute = reverse-direction ppermute), so gradients of
    the wire codes are compressed exactly like activations — the paper's
    symmetrical 128x.  The checkpointed tick body stashes one wire code per
    tick: stash ~ (n_micro + n_stages - 1) codes.
  * ``"1f1b"``   — one-forward-one-backward: an explicit-backward slot loop
    (``jax.vjp`` per stage inside the scan, ``jax.custom_vjp`` over the
    whole step so ``jax.grad`` still works) that caps in-flight microbatches
    at ``n_stages - stage``, shrinking the activation stash to a
    min(n_stages, n_micro)-slot ring of wire codes.  Slot timetable
    (equal F/B cost, slot granularity; stage s of P, micro m of M):
        f(s, m) = s + m              for m <  P - s   (warmup)
        f(s, m) = 2m + s             for m >= P - s   (steady: F paired
                                                       with B(s, m-(P-s)))
        b(s, m) = 2P - 1 - s + 2m
    Forward sends are consumed exactly one slot later (f(s+1,m) = f(s,m)+1),
    likewise backward sends, so each slot is one ppermute in each direction.
    F and B slots never collide on a stage (disjoint parity), matching the
    real schedule's one-unit-of-work-per-slot; in the lockstep SPMD body
    both paths are computed and mask-selected, which is the usual price of
    expressing an asymmetric schedule as one SPMD program.

Boundary codecs: the stage-exit encode (RMSNorm -> W_down -> wire cast) and
stage-entry decode (alpha * (z @ W_up)) run as fused Pallas kernels
(``kernels/bottleneck_fused.py``): one HBM read of the full-width x, one
write of the 64x-smaller code.  Dispatch follows the ``kernels/ops.py``
policy — compiled Pallas on TPU, the identical-math ref.py oracle on other
backends, the kernel bodies under interpret=True when
``REPRO_FORCE_PALLAS_INTERPRET=1`` (how the CPU equivalence suite pins
kernel == oracle).

Used by ``--strategy pipeline`` in launch/train.py + launch/dryrun.py and by
benchmarks/bench_pipeline.py (BENCH_pipeline.json).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops, quant_stream as qs
from repro.models import blocks as blk
from repro.models.layers import (
    dense_init,
    init_embeddings,
    next_token_loss,
    norm_init,
    rmsnorm,
)
from repro.models.layers import embed as embed_fn
from repro.models.layers import logits as logits_fn

from repro.common import shard_map_unchecked as _shard_map


SCHEDULES = ("gpipe", "1f1b")
WIRE_CODECS = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    n_stages: int
    n_microbatches: int
    compress: bool = True            # stream bottleneck codes, not residuals
    bottleneck_dim: int = 32
    wire_dtype: Any = jnp.bfloat16
    schedule: str = "gpipe"          # "gpipe" (golden) | "1f1b"
    wire_codec: str = "none"         # "none" | "int8" (quantized codes)
    fuse_boundary: bool = True       # fused Pallas boundary encode/decode

    def __post_init__(self):
        assert self.schedule in SCHEDULES, self.schedule
        assert self.wire_codec in WIRE_CODECS, self.wire_codec
        assert self.wire_codec == "none" or self.compress, \
            "int8 wire codec quantizes bottleneck codes; needs compress=True"

    def wire_width(self, cfg: ModelConfig) -> int:
        return self.bottleneck_dim if self.compress else cfg.d_model

    def carry_dtype(self):
        """On-device dtype of the wire carry.  int8 codes dequantize to
        exact f32 products (q * scale), so the carry holds f32; the on-wire
        bytes are what ``wire_bytes_per_hop`` accounts."""
        return jnp.float32 if self.wire_codec == "int8" else self.wire_dtype


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_pipeline_params(key, cfg: ModelConfig, spec: PipelineSpec) -> dict:
    """Stage-stacked layout: every leading axis ``n_stages`` shards over

    ``model``.  Stage s owns: its block slice, W_down of boundary s (encode
    at exit; unused on the last stage) and W_up of boundary s-1 (decode at
    entry; unused on stage 0)."""
    kinds = blk.period_kinds(cfg)
    assert kinds in (["attn_dense"], ["attn_moe"]), (
        "pipeline strategy supports uniform decoder stacks; "
        f"{cfg.arch_id} period={kinds}")
    kind = kinds[0]
    assert cfg.n_layers % spec.n_stages == 0, (cfg.n_layers, spec.n_stages)
    l_per = cfg.n_layers // spec.n_stages

    ks = jax.random.split(key, 4)
    stages = []
    for s in range(spec.n_stages):
        layers = [blk.init_block(jax.random.fold_in(ks[0], s * 1000 + l),
                                 kind, cfg) for l in range(l_per)]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers))
    stage_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *stages)

    d, db = cfg.d_model, spec.bottleneck_dim
    params = {
        "embeds": init_embeddings(ks[1], cfg),
        "final_norm": norm_init(cfg.d_model),
        "stages": {"blocks": stage_blocks},
    }
    if spec.compress:
        params["stages"]["enc_norm"] = jnp.ones((spec.n_stages, d), jnp.float32)
        params["stages"]["w_down"] = jnp.stack([
            dense_init(jax.random.fold_in(ks[2], s), d, db)
            for s in range(spec.n_stages)])
        params["stages"]["w_up_prev"] = jnp.stack([
            dense_init(jax.random.fold_in(ks[3], s), db, d,
                       scale=1.0 / np.sqrt(db))
            for s in range(spec.n_stages)])
        params["stages"]["alpha_dec"] = jnp.full((spec.n_stages,),
                                                 0.5, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Boundary codecs (fused Pallas hot path, jnp fallback kept as oracle path)
# ---------------------------------------------------------------------------


def _encode_boundary(x, stages, cfg: ModelConfig, spec: PipelineSpec,
                     codec: bool = True):
    """Stage exit: RMSNorm -> W_down -> wire cast, one fused kernel (one HBM
    read of full-width x, one write of the d_model/d_b-smaller code); then
    the optional differentiable int8 wire roundtrip.  Kernel dispatch
    follows the ops.py policy: compiled Pallas on TPU, the identical-math
    oracle elsewhere (REPRO_FORCE_PALLAS_INTERPRET=1 forces the kernel
    bodies under interpret, as the equivalence suite does)."""
    if not spec.compress:
        z = x.astype(spec.wire_dtype)
    elif spec.fuse_boundary:
        z = ops.bottleneck_encode(x, stages["enc_norm"], stages["w_down"],
                                  eps=cfg.norm_eps,
                                  wire_dtype=spec.carry_dtype())
    else:
        xn = rmsnorm(x, stages["enc_norm"], cfg.norm_eps)
        z = (xn.astype(jnp.float32) @ stages["w_down"].astype(jnp.float32)
             ).astype(spec.carry_dtype())
    if codec and spec.wire_codec == "int8":
        z = ops.int8_wire_roundtrip(z)
    return z


def _decode_boundary(z, stages, spec: PipelineSpec, compute_dtype):
    """Stage entry: alpha * (z @ W_up) — fused gated decode (one full-width
    write instead of matmul write + scale pass)."""
    if not spec.compress:
        return z.astype(compute_dtype)
    if spec.fuse_boundary:
        return ops.bottleneck_decode_gated(z, stages["w_up_prev"],
                                           stages["alpha_dec"],
                                           out_dtype=compute_dtype)
    r = (z.astype(jnp.float32) @ stages["w_up_prev"].astype(jnp.float32)
         ).astype(compute_dtype)
    return stages["alpha_dec"].astype(compute_dtype) * r


def _traced_zero(x) -> jax.Array:
    """A scalar f32 zero derived from a traced array.  Rank-0 *constants*
    inside a shard_map body break its transpose on jax<=0.4.x (the const is
    promoted to a body output whose P() spec fails _check_names), so scan
    carries must originate from traced values."""
    return x.ravel()[0].astype(jnp.float32) * 0.0


# ---------------------------------------------------------------------------
# The pipelined forward (GPipe)
# ---------------------------------------------------------------------------


def _stage_forward(stage_params, x, cfg: ModelConfig, kind: str,
                   positions, remat: bool):
    """Apply this stage's block slice (inner scan over layers)."""
    ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=positions)

    def body(h, layer_params):
        h, _, _ = blk.apply_block(kind, layer_params, h, ctx, None)
        return h, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def pipeline_apply(params, x_micro, cfg: ModelConfig, spec: PipelineSpec,
                   mesh, batch_axes: tuple[str, ...] = ("data",),
                   remat: bool = True):
    """x_micro: (n_micro, B, S, d_model) embedded microbatches (B = global

    batch / n_micro).  Returns (n_micro, B, S, d_model) block-stack outputs.
    """
    kind = blk.period_kinds(cfg)[0]
    n_stages, n_micro = spec.n_stages, spec.n_microbatches
    d_wire = spec.wire_width(cfg)
    S = x_micro.shape[2]
    positions = jnp.arange(S, dtype=jnp.int32)[None]

    def body(x_all, stages):
        # local views: x_all (n_micro, B_loc, S, D); stages leading dim == 1
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = x_all.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        compute_dtype = x_all.dtype

        z0 = jnp.zeros((B_loc, S, d_wire), spec.carry_dtype())
        out0 = jnp.zeros_like(x_all)

        def tick(carry, t):
            z, outputs = carry
            # ---- stage entry: ingest (stage 0) or decode the wire code ----
            x_in = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            r = _decode_boundary(z, stages, spec, compute_dtype)
            x = jnp.where(stage == 0, x_in, r)
            # ---- stage compute ----
            x = _stage_forward(stages["blocks"], x, cfg, kind, pos, remat)
            # ---- stage exit: encode the wire code ----
            z_out = _encode_boundary(x, stages, cfg, spec)
            # ---- collect finished microbatches on the last stage ----
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_out = ((stage == n_stages - 1) & (t >= n_stages - 1)
                      & (t - (n_stages - 1) < n_micro))
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_out, x, cur), out_idx, 0)
            # ---- stream to the next stage (no wraparound: stage0 gets 0) ----
            z_next = jax.lax.ppermute(
                z_out, "model", [(i, i + 1) for i in range(n_stages - 1)])
            return (z_next, outputs), None

        T = n_micro + n_stages - 1
        (z, outputs), _ = jax.lax.scan(tick, (z0, out0),
                                       jnp.arange(T, dtype=jnp.int32))
        # only the last stage holds real outputs; psum replicates them
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            "model")
        return outputs

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    return _shard_map(
        body, mesh,
        (P(None, batch_axes, None, None), stage_specs),
        P(None, batch_axes, None, None),
    )(x_micro, params["stages"])


# ---------------------------------------------------------------------------
# End-to-end pipelined train/loss step
# ---------------------------------------------------------------------------


def pipeline_loss(params, batch, cfg: ModelConfig, spec: PipelineSpec, mesh,
                  batch_axes: tuple[str, ...] = ("data",), z_loss: float = 1e-4,
                  compute_dtype=jnp.bfloat16):
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_micro = spec.n_microbatches
    assert B % n_micro == 0, (B, n_micro)
    x = embed_fn(params["embeds"], tokens, cfg, None, compute_dtype)
    x = x.reshape(n_micro, B // n_micro, S, -1)
    y = pipeline_apply(params, x, cfg, spec, mesh, batch_axes)
    # loss head is MICROBATCHED (scan + remat): a full-batch fp32 logits
    # tensor would be (B, S, V/16) ≈ 34 GB/device (§Perf cell C iteration 4:
    # 145 GiB/device -> fits, and the logits all-gather drops with it)
    labels_m = labels.reshape(n_micro, B // n_micro, S)

    def head(y_mb, lab_mb):
        h = rmsnorm(y_mb, params["final_norm"], cfg.norm_eps)
        lgts = logits_fn(params["embeds"], h, cfg, None)
        return next_token_loss(lgts, lab_mb, z_loss)

    head = jax.checkpoint(head, policy=jax.checkpoint_policies.nothing_saveable)

    def body(acc, xs):
        y_mb, lab_mb = xs
        return acc + head(y_mb, lab_mb), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (y, labels_m))
    return total / n_micro


def wire_bytes_per_hop(cfg: ModelConfig, spec: PipelineSpec,
                       global_batch: int, seq: int,
                       data_shards: int = 1) -> int:
    """On-wire bytes for one full microbatch sweep across one boundary.

    For the int8 codec this accounts the fp32 scales honestly: one per
    quantization block of the per-device per-microbatch code tensor — the
    block the runtime codec actually quantizes (``data_shards`` matters:
    a sharded microbatch can fall back to per-row scales)."""
    width = spec.wire_width(cfg)
    n = global_batch * seq * width
    if spec.wire_codec == "int8":
        micro_elems = (max(global_batch // spec.n_microbatches // data_shards,
                           1) * seq * width)
        block = qs.wire_block(micro_elems, width)
        return n + (n // block) * 4
    return n * jnp.dtype(spec.wire_dtype).itemsize


def schedule_stats(cfg: ModelConfig, spec: PipelineSpec, global_batch: int,
                   seq: int, data_shards: int = 1) -> dict:
    """Static schedule accounting, derived from the real carry structures:

    * ``bubble_fraction``   — idle fraction of the tick/slot loop
    * ``stash_bytes``       — per-device activation stash: GPipe saves the
      checkpointed tick carry's wire code once per tick (T codes); 1F1B
      allocates a min(n_stages, n_micro)-slot ring of codes in the carry
    * ``carry_code_bytes``  — one in-flight wire code (B_loc, S, d_wire)
    * ``wire_bytes_per_hop``— on-wire bytes per boundary per sweep
    """
    Pn, M = spec.n_stages, spec.n_microbatches
    width = spec.wire_width(cfg)
    B_loc = max(global_batch // M // data_shards, 1)
    code_bytes = (B_loc * seq * width
                  * jnp.dtype(spec.carry_dtype()).itemsize)
    ticks = M + Pn - 1
    if spec.schedule == "1f1b":
        loop_len = 2 * ticks
        stash_codes = min(Pn, M)
    else:
        loop_len = ticks
        stash_codes = ticks
    return {
        "schedule": spec.schedule,
        "n_stages": Pn,
        "n_microbatches": M,
        "loop_length": loop_len,
        "bubble_fraction": (Pn - 1) / ticks,
        "carry_code_bytes": int(code_bytes),
        "stash_codes": int(stash_codes),
        "stash_bytes": int(stash_codes * code_bytes),
        "wire_bytes_per_hop": int(
            wire_bytes_per_hop(cfg, spec, global_batch, seq,
                               data_shards=data_shards)),
    }


# ---------------------------------------------------------------------------
# Fused pipeline: embed on stage 0, loss on the last stage (paper §2.2:
# 'Miners in the first layer also handle data ingestion and tokenization,
# while those in the final layer compute the training loss.')
# ---------------------------------------------------------------------------


def pipeline_loss_fused(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                        mesh, batch_axes: tuple[str, ...] = ("data",),
                        z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """One shard_map for the whole step: tokens (tiny) replicate to stages

    instead of embedded activations; the loss is computed on the last stage
    and psum'd as a scalar.  §Perf cell C iteration 5: removes the
    537 MB x 2 x ticks GSPMD resharding permutes and the 4.5 GB output
    all-reduce of the v1 layout — inter-stage traffic is then just the
    (compressed) wire codes, i.e. the paper's §4 claim made visible on-mesh.
    """
    kind = blk.period_kinds(cfg)[0]
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_stages, n_micro = spec.n_stages, spec.n_microbatches
    assert B % n_micro == 0
    d_wire = spec.wire_width(cfg)
    Bm = B // n_micro
    tokens_m = tokens.reshape(n_micro, Bm, S)
    labels_m = labels.reshape(n_micro, Bm, S)
    positions = jnp.arange(S, dtype=jnp.int32)[None]

    def body(toks, labs, embed_tbl, unembed_tbl, final_gamma, stages):
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = toks.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        last = n_stages - 1

        z0 = jnp.zeros((B_loc, S, d_wire), spec.carry_dtype())
        out0 = jnp.zeros((n_micro, B_loc, S, cfg.d_model), compute_dtype)

        # §Perf cell C iteration 7 (winner of 6/7/8 — see EXPERIMENTS.md):
        # the tick body is checkpointed, so the backward pipeline re-derives
        # each tick from its carry, whose activation part is the COMPRESSED
        # wire code z — the paper's 64x compression also shrinks the GPipe
        # activation stash.  The in-carry output collector is donated/
        # aliased in place by XLA (the ys-collection variants measured
        # strictly worse).
        def tick(carry, t):
            z, outputs = carry
            t_in = jax.lax.dynamic_index_in_dim(
                toks, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            # stage 0 ingests tokens (paper: first-layer miners tokenize);
            # the embedding gather is tiny next to a full-width activation
            x_in = jnp.take(embed_tbl, t_in, axis=0).astype(compute_dtype)
            r = _decode_boundary(z, stages, spec, compute_dtype)
            x = jnp.where(stage == 0, x_in, r)
            x = _stage_forward(stages["blocks"], x, cfg, kind, pos, True)
            z_out = _encode_boundary(x, stages, cfg, spec)
            out_idx = jnp.clip(t - last, 0, n_micro - 1)
            is_out = (stage == last) & (t >= last) & (t - last < n_micro)
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_out, x, cur), out_idx, 0)
            z_next = jax.lax.ppermute(
                z_out, "model", [(i, i + 1) for i in range(n_stages - 1)])
            return (z_next, outputs), None

        tick = jax.checkpoint(tick,
                              policy=jax.checkpoint_policies.nothing_saveable)
        T = n_micro + n_stages - 1
        (_, outputs), _ = jax.lax.scan(tick, (z0, out0),
                                       jnp.arange(T, dtype=jnp.int32))

        # ---- loss head on the last stage, microbatched + remat ----
        pad_mask = (jnp.arange(unembed_tbl.shape[0]) >= cfg.vocab_size
                    ) * (-1e9)

        def head(y_mb, lab_mb):
            h = rmsnorm(y_mb, final_gamma, cfg.norm_eps)
            lgts = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                              unembed_tbl.astype(jnp.float32)) + pad_mask
            return next_token_loss(lgts, lab_mb, z_loss)

        head = jax.checkpoint(head,
                              policy=jax.checkpoint_policies.nothing_saveable)

        def loss_body(acc, xs):
            y_mb, lab_mb = xs
            return acc + head(y_mb, lab_mb), None

        local_loss, _ = jax.lax.scan(loss_body, _traced_zero(outputs),
                                     (outputs, labs))
        loss = jax.lax.psum(
            jnp.where(stage == last, local_loss, 0.0), "model") / n_micro
        return jax.lax.pmean(loss, batch_axes)

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    unembed = params["embeds"].get("unembed", params["embeds"]["embed"])
    return _shard_map(
        body, mesh,
        (P(None, batch_axes, None), P(None, batch_axes, None),
         P(None, None), P(None, None), P(None), stage_specs),
        P(),
    )(tokens_m, labels_m, params["embeds"]["embed"], unembed,
      params["final_norm"], params["stages"])


# ---------------------------------------------------------------------------
# 1F1B: explicit-backward slot loop (loss AND grads in one shard_map)
# ---------------------------------------------------------------------------


def pipeline_1f1b_grads(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                        mesh, batch_axes: tuple[str, ...] = ("data",),
                        z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """One shard_map computing ``(loss, grads)`` under the 1F1B timetable

    (module docstring).  Each slot dispatches on its timetable role via
    ``lax.switch`` — idle, forward, or backward — so a stage only pays for
    the work its slot actually does: forward slots run the primal blocks
    alone (no loss head, no pullback), backward slots re-run the stage's
    forward from the stashed *wire code* under ``jax.vjp`` (decode ->
    blocks -> encode + loss head), seed the cotangent from the incoming
    backward wire code (or 1.0 for the last stage's loss), and accumulate
    param grads.  ``lax.switch`` on the per-device role is legal under
    shard_map here because the branches contain no collectives — the two
    ``ppermute`` hand-offs stay outside, executed by every device each
    slot.  (The previous revision ran the full vjp + vocab head in *every*
    slot, masked; on CPU that lockstep compute made 1F1B ~26% slower per
    step than GPipe.  The retrace sanitizer in repro.analysis confirmed
    steady-state slots never retrace — the cost was real compute, not
    recompilation.)  The activation stash is a min(n_stages, n_micro)-slot
    ring of codes — the 1F1B memory claim, vs GPipe's one code per tick.

    Returns grads matching ``jax.grad(pipeline_loss_fused)``: per-stage
    params stay per-stage, shared params (embeddings, final norm) are
    psum'd over stages and pmean'd over the batch axes.
    """
    kind = blk.period_kinds(cfg)[0]
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    Pn, M = spec.n_stages, spec.n_microbatches
    assert B % M == 0
    d_wire = spec.wire_width(cfg)
    Bm = B // M
    tokens_m = tokens.reshape(M, Bm, S)
    labels_m = labels.reshape(M, Bm, S)
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    R = min(Pn, M)                       # stash ring slots (in-flight cap)
    K = 2 * (M + Pn - 1)                 # total schedule slots

    def body(toks, labs, embed_tbl, unembed_tbl, final_gamma, stages):
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = toks.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        last = Pn - 1
        pad_mask = (jnp.arange(unembed_tbl.shape[0]) >= cfg.vocab_size
                    ) * (-1e9)

        def stage_fn(stage_p, z_in, emb, unemb, fgamma, toks_t, labs_t):
            """This stage's forward from its received wire code (or tokens
            on stage 0), through its blocks, to its exit code AND the loss
            head — one function so one vjp yields every cotangent; the
            where() gates route grads to the right owners (embed on stage
            0, head params on the last stage) automatically."""
            x_e = jnp.take(emb, toks_t, axis=0).astype(compute_dtype)
            r = _decode_boundary(z_in, stage_p, spec, compute_dtype)
            x = jnp.where(stage == 0, x_e, r)
            x = _stage_forward(stage_p["blocks"], x, cfg, kind, pos, False)
            z_out = _encode_boundary(x, stage_p, cfg, spec, codec=False)
            h = rmsnorm(x, fgamma, cfg.norm_eps)
            lgts = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                              unemb.astype(jnp.float32)) + pad_mask
            loss_t = next_token_loss(lgts, labs_t, z_loss)
            return z_out, loss_t

        def fwd_sched(t, s):
            """(valid, micro) the stage-s forward timetable assigns slot t:
            f(s,m) = s + m while m < P - s (warmup), else 2m + s (steady,
            throttled so in-flight microbatches stay capped at P - s)."""
            w_cap = jnp.minimum(Pn - s, M)
            warm_m = t - s
            warm_ok = (warm_m >= 0) & (warm_m < w_cap)
            steady_m = (t - s) // 2
            steady_ok = (((t - s) % 2 == 0) & (steady_m >= Pn - s)
                         & (steady_m < M))
            m = jnp.clip(jnp.where(warm_ok, warm_m, steady_m), 0, M - 1)
            return warm_ok | steady_ok, m

        def slot(carry, t):
            z_wire, g_wire, stash, grads, loss_acc = carry
            # ---- arrival: a code sent by stage-1 last slot enters the ring
            # (at the warmup->steady seam a code arrives up to P - s slots
            # before its forward slot, so it must be stashed on arrival —
            # the single-slot z_wire register would lose it)
            a_ok, ma = fwd_sched(t - 1, stage - 1)
            a_ok = a_ok & (stage > 0)
            a_idx = ma % R
            cur = jax.lax.dynamic_index_in_dim(stash, a_idx, 0,
                                               keepdims=False)
            stash = jax.lax.dynamic_update_index_in_dim(
                stash, jnp.where(a_ok, z_wire, cur), a_idx, 0)
            # ---- timetable: which (if any) micro this stage works on ----
            f_ok, mf = fwd_sched(t, stage)
            bn = t - (2 * Pn - 1 - stage)
            mb = jnp.clip(bn // 2, 0, M - 1)
            b_ok = (bn >= 0) & (bn % 2 == 0) & (bn // 2 < M)
            # F and B slots are disjoint by parity; both read the stash
            # ring — the forward its just-arrived code, the backward the
            # code stashed at its forward slot (entries live from arrival
            # to b(s,m); ring reuse starts strictly later)
            m_idx = jnp.where(f_ok, mf, mb)
            z_src = jax.lax.dynamic_index_in_dim(stash, m_idx % R, 0,
                                                 keepdims=False)
            toks_t = jax.lax.dynamic_index_in_dim(toks, m_idx, 0,
                                                  keepdims=False)
            labs_t = jax.lax.dynamic_index_in_dim(labs, m_idx, 0,
                                                  keepdims=False)

            # ---- role dispatch: pay only for what this slot does --------
            # (branches close over loop-invariant tracers; no collectives
            # inside, so per-device switch is shard_map-legal)
            def idle(z_src, toks_t, labs_t, g_in, grads, loss_acc):
                zeros = jnp.zeros((B_loc, S, d_wire), spec.carry_dtype())
                return zeros, zeros, grads, loss_acc

            def fwd_slot(z_src, toks_t, labs_t, g_in, grads, loss_acc):
                # primal blocks only: no loss head, no pullback
                x_e = jnp.take(embed_tbl, toks_t,
                               axis=0).astype(compute_dtype)
                r = _decode_boundary(z_src, stages, spec, compute_dtype)
                x = jnp.where(stage == 0, x_e, r)
                x = _stage_forward(stages["blocks"], x, cfg, kind, pos,
                                   False)
                z_send = _encode_boundary(x, stages, cfg, spec,
                                          codec=False)
                if spec.wire_codec == "int8":
                    z_send = ops.int8_wire_roundtrip(z_send)
                return (z_send, jnp.zeros_like(z_send), grads, loss_acc)

            def bwd_slot(z_src, toks_t, labs_t, g_in, grads, loss_acc):
                (z_out, loss_t), vjp = jax.vjp(
                    lambda sp, z, e, u, f: stage_fn(sp, z, e, u, f,
                                                    toks_t, labs_t),
                    stages, z_src, embed_tbl, unembed_tbl, final_gamma)
                ct_z = jnp.where(stage == last, jnp.zeros_like(z_out),
                                 g_in.astype(z_out.dtype))
                ct_loss = jnp.where(stage == last, jnp.ones_like(loss_t),
                                    jnp.zeros_like(loss_t))
                g_stages, g_z, g_emb, g_unemb, g_fg = vjp((ct_z, ct_loss))
                grads = jax.tree.map(
                    lambda acc, g: acc + g.astype(jnp.float32),
                    grads, (g_stages, g_emb, g_unemb, g_fg))
                g_send = g_z.astype(spec.carry_dtype())
                if spec.wire_codec == "int8":
                    g_send = ops.int8_wire_roundtrip(g_send)
                g_send = jnp.where(stage > 0, g_send,
                                   jnp.zeros_like(g_send))
                loss_acc = loss_acc + jnp.where(stage == last, loss_t,
                                                jnp.zeros_like(loss_t))
                return (jnp.zeros_like(g_send), g_send, grads, loss_acc)

            role = jnp.where(b_ok, 2, f_ok.astype(jnp.int32))
            z_send, g_send, grads, loss_acc = jax.lax.switch(
                role, [idle, fwd_slot, bwd_slot],
                z_src, toks_t, labs_t, g_wire, grads, loss_acc)
            # ---- hand-offs: consumed exactly one slot later --------------
            z_wire = jax.lax.ppermute(
                z_send, "model", [(i, i + 1) for i in range(Pn - 1)])
            g_wire = jax.lax.ppermute(
                g_send, "model", [(i + 1, i) for i in range(Pn - 1)])
            return (z_wire, g_wire, stash, grads, loss_acc), None

        z0 = jnp.zeros((B_loc, S, d_wire), spec.carry_dtype())
        stash0 = jnp.zeros((R, B_loc, S, d_wire), spec.carry_dtype())
        grads0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                              (stages, embed_tbl, unembed_tbl, final_gamma))
        carry0 = (z0, jnp.zeros_like(z0), stash0, grads0, _traced_zero(toks))
        (_, _, _, grads, loss_acc), _ = jax.lax.scan(
            slot, carry0, jnp.arange(K, dtype=jnp.int32))

        g_stages, g_emb, g_unemb, g_fg = grads
        scale = 1.0 / M
        loss = jax.lax.pmean(
            jax.lax.psum(jnp.where(stage == last, loss_acc, 0.0 * loss_acc),
                         "model") * scale, batch_axes)
        # stage params: per-stage owner; shared params: sum over stages
        g_stages = jax.tree.map(
            lambda a: jax.lax.pmean(a * scale, batch_axes)[None], g_stages)
        shared = jax.tree.map(
            lambda a: jax.lax.pmean(jax.lax.psum(a * scale, "model"),
                                    batch_axes),
            (g_emb, g_unemb, g_fg))
        return loss, g_stages, *shared

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    tied = "unembed" not in params["embeds"]
    unembed = params["embeds"].get("unembed", params["embeds"]["embed"])
    loss, g_stages, g_emb, g_unemb, g_fg = _shard_map(
        body, mesh,
        (P(None, batch_axes, None), P(None, batch_axes, None),
         P(None, None), P(None, None), P(None), stage_specs),
        (P(), stage_specs, P(), P(), P()),
    )(tokens_m, labels_m, params["embeds"]["embed"], unembed,
      params["final_norm"], params["stages"])

    embeds_g = {"embed": g_emb + g_unemb if tied else g_emb}
    if not tied:
        embeds_g["unembed"] = g_unemb
    grads = {"embeds": embeds_g, "final_norm": g_fg, "stages": g_stages}
    return loss, grads


def pipeline_loss_1f1b(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                       mesh, batch_axes: tuple[str, ...] = ("data",),
                       z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """`jax.grad`-compatible 1F1B loss: the explicit schedule computes the

    gradients in its own forward pass, so the custom_vjp backward just hands
    them to autodiff (scaled by the incoming cotangent)."""

    @jax.custom_vjp
    def run(p):
        loss, _ = pipeline_1f1b_grads(p, batch, cfg, spec, mesh, batch_axes,
                                      z_loss, compute_dtype)
        return loss

    def fwd(p):
        loss, grads = pipeline_1f1b_grads(p, batch, cfg, spec, mesh,
                                          batch_axes, z_loss, compute_dtype)
        return loss, (grads, p)

    def bwd(res, g):
        grads, p = res
        return (jax.tree.map(
            lambda gr, pp: (g * gr.astype(jnp.float32)).astype(pp.dtype),
            grads, p),)

    run.defvjp(fwd, bwd)
    return run(params)


def pipeline_loss_and_grads(params, batch, cfg: ModelConfig,
                            spec: PipelineSpec, mesh,
                            batch_axes: tuple[str, ...] = ("data",),
                            z_loss: float = 1e-4,
                            compute_dtype=jnp.bfloat16):
    """Schedule dispatcher for the training hot path: GPipe differentiates
    the tick scan; 1F1B computes grads explicitly in one pass."""
    if spec.schedule == "1f1b":
        return pipeline_1f1b_grads(params, batch, cfg, spec, mesh,
                                   batch_axes, z_loss, compute_dtype)
    return jax.value_and_grad(
        lambda p: pipeline_loss_fused(p, batch, cfg, spec, mesh, batch_axes,
                                      z_loss, compute_dtype))(params)
