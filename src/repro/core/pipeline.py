"""Pipeline-parallel engine (paper C1 + C3 on-mesh): a schedule *compiler*

plus one generalized slot executor, in ``shard_map`` with the ``model`` mesh
axis as the stage axis, streaming microbatch activations stage-to-stage via
``ppermute`` — and, when ``compress=True``, streaming the paper's
*bottleneck codes* (width d_b) instead of full-width activations, cutting
inter-stage bytes by d_model/d_b (64x for the paper's 2048->32).
``wire_codec="int8"`` ships quantized codes on the wire (per-block symmetric
int8, one fp32 scale per block), doubling 64x to the paper's headline 128x.

Faithfulness map:
  miners on one layer-slice   -> devices in one model-axis row
  S3 activation hand-off      -> ppermute along ``model``
  bottleneck block at miner Tx-> encode at stage exit (stage owns W_down)
  post-bottleneck at miner Rx -> decode at stage entry (stage owns W_up of
                                 the previous boundary)
  DP across pipeline replicas -> ``data`` (x ``pod``) axes

Schedules (``PipelineSpec.schedule``; registry ``SCHEDULES``) are all
compiled by ``compile_timetable`` into one ``Timetable``: per-stage,
per-slot role tables over {idle, F, B, W} plus a ring-stash plan (which
ring slot every arriving wire code is written to, and which ring slot every
unit reads).  The timetable is the single source of truth for execution
order, stash lifetime, wire hops, and bubble accounting:

  * ``"gpipe"``  — the golden reference: T = n_micro + n_stages - 1 forward
    ticks; autodiff through the tick scan gives the backward pipeline
    automatically (transpose of ppermute = reverse-direction ppermute), so
    gradients of the wire codes are compressed exactly like activations —
    the paper's symmetrical 128x.  The tick loop's ingest/collect index
    tables are derived from the compiled timetable.  Bubble
    (P-1)/(M+P-1); stash ~ one wire code per tick (checkpointed carry).
  * ``"1f1b"``   — one-forward-one-backward, run by the slot executor.
    Slot maps (equal F/B cost, slot granularity; stage s of P, micro m):
        f(s, m) = s + m              for m <  P - s   (warmup)
        f(s, m) = 2m + s             for m >= P - s   (steady)
        b(s, m) = 2P - 1 - s + 2m
    Same bubble as GPipe but the activation stash shrinks to a
    min(P, M)-slot ring of wire codes.
  * ``"interleaved"`` — Megatron-style virtual stages: each device hosts
    V > 1 *chunks* (chunk c on device c % P, local index c // P), walked
    in groups of P microbatches with a depth-staggered warmup, shrinking
    the bubble to (P-1)/(V*M+P-1).  Needs M % P == 0.  Chunk boundaries
    all carry the wire codec, so interleaved (P, V) is the *same model* as
    gpipe at P*V stages — the loss-parity oracle used by the tests.
  * ``"zerobubble"`` — ZB-H1-style split of backward slots into
    activation-grad ``B`` (sends the upstream cotangent as early as 1F1B
    does) and weight-grad ``W`` (fills former idle slots).  Bubble drops
    to ~1 - 3M/K ≈ 0.11 at P=4/M=8; the W slots re-run the stage forward
    from the stashed code (recompute-from-wire design), and the cotangent
    ring keeps each B's seed alive until its W consumes it.

Boundary codecs: the stage-exit encode (RMSNorm -> W_down -> wire cast) and
stage-entry decode (alpha * (z @ W_up)) run as fused Pallas kernels
(``kernels/bottleneck_fused.py``): one HBM read of the full-width x, one
write of the 64x-smaller code.  Dispatch follows the ``kernels/ops.py``
policy — compiled Pallas on TPU, the identical-math ref.py oracle on other
backends, the kernel bodies under interpret=True when
``REPRO_FORCE_PALLAS_INTERPRET=1`` (how the CPU equivalence suite pins
kernel == oracle).  Under ``wire_codec="int8"`` the slot executor ships and
*stashes* the physical (int8 codes, fp32 scales) pair — the ring holds the
compressed form and dequantizes at consumption (bit-identical to the old
dequantize-then-stash, since q * scale is exact in f32), so the int8 stash
is ~2x smaller than bf16 instead of 2x larger.  The GPipe autodiff carry
must stay a float tensor (an int8 carry would sever the straight-through
gradient channel across the scan transpose), so only the explicit-schedule
rings get the compressed stash.

Used by ``--strategy pipeline`` in launch/train.py + launch/dryrun.py and by
benchmarks/bench_pipeline.py (BENCH_pipeline.json).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops, quant_stream as qs
from repro.models import blocks as blk
from repro.models.layers import (
    dense_init,
    init_embeddings,
    next_token_loss,
    norm_init,
    rmsnorm,
)
from repro.models.layers import embed as embed_fn
from repro.models.layers import logits as logits_fn

from repro.common import shard_map_unchecked as _shard_map


SCHEDULES = ("gpipe", "1f1b", "interleaved", "zerobubble", "decode")
WIRE_CODECS = ("none", "int8")

# Timetable roles: every (stage, slot) cell does exactly one of these.
ROLE_IDLE, ROLE_F, ROLE_B, ROLE_W = 0, 1, 2, 3
ROLE_NAMES = ("idle", "F", "B", "W")

_NEVER = 1 << 30


class ScheduleError(ValueError):
    """A (schedule, P, M, V) combination the compiler rejects."""


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    n_stages: int
    n_microbatches: int
    compress: bool = True            # stream bottleneck codes, not residuals
    bottleneck_dim: int = 32
    wire_dtype: Any = jnp.bfloat16
    schedule: str = "gpipe"          # one of SCHEDULES (compiler registry)
    wire_codec: str = "none"         # "none" | "int8" (quantized codes)
    fuse_boundary: bool = True       # fused Pallas boundary encode/decode
    virtual_stages: int = 1          # chunks per device (interleaved only)

    def __post_init__(self):
        assert self.wire_codec in WIRE_CODECS, self.wire_codec
        assert self.wire_codec == "none" or self.compress, \
            "int8 wire codec quantizes bottleneck codes; needs compress=True"
        # one compile validates schedule name, V, and M % P constraints
        # (lru-cached, so every later timetable() call is free)
        compile_timetable(self.schedule, self.n_stages, self.n_microbatches,
                          self.virtual_stages)

    @property
    def n_chunks(self) -> int:
        """Model chunks = codec boundaries + 1: P * V."""
        return self.n_stages * self.virtual_stages

    def timetable(self) -> "Timetable":
        return compile_timetable(self.schedule, self.n_stages,
                                 self.n_microbatches, self.virtual_stages)

    def wire_width(self, cfg: ModelConfig) -> int:
        return self.bottleneck_dim if self.compress else cfg.d_model

    def carry_dtype(self):
        """On-device dtype of a *decoded* wire code.  int8 codes dequantize
        to exact f32 products (q * scale), so decoded carries hold f32; the
        explicit-schedule rings stash the (int8, scales) pair instead
        (``schedule_stats``/``wire_bytes_per_hop`` account both honestly)."""
        return jnp.float32 if self.wire_codec == "int8" else self.wire_dtype


# ---------------------------------------------------------------------------
# Schedule compiler: (schedule, P, M, V) -> Timetable
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class Timetable:
    """Compiled slot program for one pipeline schedule.

    All per-slot tables are (P, K) int32, indexed [stage, slot].  ``role``
    says what the stage does that slot; ``micro``/``vstage`` which
    (microbatch, local chunk) the unit works on (0 when idle).  The ring
    plan: ``z_arrive[d, t]`` is the forward-ring slot an arriving wire code
    is written to at slot t (-1: no arrival); ``z_src[d, t]`` the ring slot
    this slot's unit reads its input code from.  ``g_arrive``/``g_src``
    are the same for the backward (cotangent) ring — for ``zerobubble`` a
    cotangent stays live from its B until its W consumes it.

    ``f_slot``/``b_slot``/``w_slot`` are the raw (C, M) slot maps (w_slot
    is -1 outside zerobubble) kept for tests and accounting.
    """
    schedule: str
    n_stages: int
    n_virtual: int
    n_micro: int
    n_slots: int
    role: np.ndarray
    micro: np.ndarray
    vstage: np.ndarray
    z_ring: int
    g_ring: int
    z_arrive: np.ndarray
    z_src: np.ndarray
    g_arrive: np.ndarray
    g_src: np.ndarray
    f_slot: np.ndarray
    b_slot: np.ndarray
    w_slot: np.ndarray

    @property
    def n_chunks(self) -> int:
        return self.n_stages * self.n_virtual

    def work_units(self) -> int:
        return int((self.role != ROLE_IDLE).sum())

    def bubble_fraction(self) -> float:
        """Measured idle fraction of the executed timetable (not a closed
        form): 1 - work cells / (P * K)."""
        return 1.0 - self.work_units() / (self.n_stages * self.n_slots)


def _interleaved_slots(Pn: int, M: int, V: int):
    """Megatron-order virtual-stage schedule: per device, M//P groups of P
    microbatches walk the V chunks (forward: shallow->deep, backward:
    deep->shallow) with a depth-staggered warmup of (V-1)*P + (P-d)
    forwards, then strict B/F alternation; units dispatch in list order as
    soon as their producer's hand-off (one-slot transit) has arrived.
    Hits the ideal K = 2(VM + P - 1), i.e. bubble (P-1)/(VM+P-1)."""
    C = Pn * V
    orders = []
    for d in range(Pn):
        fseq = [("F", v * Pn + d, g * Pn + i)
                for g in range(M // Pn) for v in range(V) for i in range(Pn)]
        bseq = [("B", v * Pn + d, g * Pn + i)
                for g in range(M // Pn) for v in reversed(range(V))
                for i in range(Pn)]
        warm = min((V - 1) * Pn + (Pn - d), len(fseq))
        order = list(fseq[:warm])
        fi, bi = warm, 0
        while fi < len(fseq) or bi < len(bseq):
            if bi < len(bseq):
                order.append(bseq[bi])
                bi += 1
            if fi < len(fseq):
                order.append(fseq[fi])
                fi += 1
        orders.append(order)

    f: dict = {}
    b: dict = {}
    ptr = [0] * Pn
    t = 0
    while any(ptr[d] < len(orders[d]) for d in range(Pn)):
        for d in range(Pn):
            if ptr[d] >= len(orders[d]):
                continue
            kind, c, m = orders[d][ptr[d]]
            if kind == "F":
                ready = c == 0 or f.get((c - 1, m), _NEVER) + 1 <= t
            elif c == C - 1:
                ready = f.get((c, m), _NEVER) + 1 <= t
            else:
                ready = ((c, m) in f
                         and b.get((c + 1, m), _NEVER) + 1 <= t)
            if ready:
                (f if kind == "F" else b)[(c, m)] = t
                ptr[d] += 1
        t += 1
        if t > 4 * (V * M + Pn) + 8:
            raise ScheduleError(
                f"interleaved dispatch deadlocked at P={Pn} M={M} V={V}")
    return f, b, max(b.values()) + 1


def _slot_maps(schedule: str, Pn: int, M: int, V: int):
    """(f, b, w) slot dicts keyed (chunk, micro) plus loop length K."""
    f: dict = {}
    b: dict = {}
    w: dict = {}
    if schedule == "gpipe":
        Kf = M + Pn - 1
        for s in range(Pn):
            for m in range(M):
                f[(s, m)] = s + m
                b[(s, m)] = Kf + (Pn - 1 - s) + m
        K = 2 * Kf
    elif schedule == "decode":
        # Forward-only token round: micro-batch slots are request lanes,
        # each lane advances one token per round.  Lane m enters stage s
        # at slot s + m; there is no backward/weight pass, so the round
        # closes after the last lane drains the last stage.
        for s in range(Pn):
            for m in range(M):
                f[(s, m)] = s + m
        K = M + Pn - 1
    elif schedule in ("1f1b", "zerobubble"):
        for s in range(Pn):
            for m in range(M):
                f[(s, m)] = s + m if m < Pn - s else 2 * m + s
                b[(s, m)] = 2 * Pn - 1 - s + 2 * m
        K = 2 * (M + Pn - 1)
        if schedule == "zerobubble":
            # W(s, m) fills the first idle slot after its own B(s, m) —
            # in-order per stage, so the cotangent ring frees FIFO
            for s in range(Pn):
                used = ({f[(s, m)] for m in range(M)}
                        | {b[(s, m)] for m in range(M)})
                t = 0
                for m in range(M):
                    t = max(t, b[(s, m)] + 1)
                    while t in used:
                        t += 1
                    w[(s, m)] = t
                    used.add(t)
            K = max(K, max(w.values()) + 1)
    else:
        f, b, K = _interleaved_slots(Pn, M, V)
    return f, b, w, K


def _greedy_ring(entries: dict):
    """First-free interval allocation: {key: (arrive, last_use)} ->
    ({key: ring_slot}, capacity).  A ring slot frees the slot after its
    entry's last consumer."""
    free_at: list = []
    assign: dict = {}
    for key, (arrive, last) in sorted(entries.items(),
                                      key=lambda kv: (kv[1][0], kv[0])):
        for i, fa in enumerate(free_at):
            if fa <= arrive:
                assign[key] = i
                free_at[i] = last + 1
                break
        else:
            assign[key] = len(free_at)
            free_at.append(last + 1)
    return assign, max(1, len(free_at))


def _check_timetable(tt: "Timetable"):
    """Self-check: one unit per cell, F < B < W per (chunk, micro) with
    one-slot transit between neighbours, every send matched by a receive,
    and ring lifetimes within the declared capacities."""
    Pn, V, M, K = tt.n_stages, tt.n_virtual, tt.n_micro, tt.n_slots
    C = Pn * V
    # forward-only timetables (the decode schedule) have no B/W cells:
    # skip the backward-ordering/transit checks and expect zero B/W roles
    fwd_only = bool((tt.b_slot < 0).all())
    for c in range(C):
        d = c % Pn
        for m in range(M):
            fs, bs = int(tt.f_slot[c, m]), int(tt.b_slot[c, m])
            if fwd_only:
                if not 0 <= fs < K:
                    raise ScheduleError(
                        f"F slot out of range: chunk {c} micro {m}")
            elif not 0 <= fs < bs < K:
                raise ScheduleError(f"F/B order broken: chunk {c} micro {m}")
            if c > 0 and fs < int(tt.f_slot[c - 1, m]) + 1:
                raise ScheduleError(f"F transit broken: chunk {c} micro {m}")
            if not fwd_only and c < C - 1 \
                    and bs < int(tt.b_slot[c + 1, m]) + 1:
                raise ScheduleError(f"B transit broken: chunk {c} micro {m}")
            ws = int(tt.w_slot[c, m])
            if ws >= 0 and not bs < ws < K:
                raise ScheduleError(f"W order broken: chunk {c} micro {m}")
            if c > 0:
                # the code sent at f(c-1, m) must be received into the ring
                # one slot later on this chunk's device
                if int(tt.z_arrive[d, int(tt.f_slot[c - 1, m]) + 1]) < 0:
                    raise ScheduleError(
                        f"unmatched F send: chunk {c - 1} micro {m}")
            if not fwd_only and c < C - 1:
                if int(tt.g_arrive[d, int(tt.b_slot[c + 1, m]) + 1]) < 0:
                    raise ScheduleError(
                        f"unmatched B send: chunk {c + 1} micro {m}")
    counts = [(tt.role == r).sum() for r in (ROLE_F, ROLE_B, ROLE_W)]
    expect_b = 0 if fwd_only else C * M
    expect_w = C * M if (tt.w_slot >= 0).any() else 0
    if counts[0] != C * M or counts[1] != expect_b or counts[2] != expect_w:
        raise ScheduleError(f"role counts off: {counts}")
    if (tt.z_arrive >= tt.z_ring).any() or (tt.z_src >= tt.z_ring).any():
        raise ScheduleError("z ring index out of capacity")
    if (tt.g_arrive >= tt.g_ring).any() or (tt.g_src >= tt.g_ring).any():
        raise ScheduleError("g ring index out of capacity")


@functools.lru_cache(maxsize=None)
def compile_timetable(schedule: str, n_stages: int, n_micro: int,
                      n_virtual: int = 1) -> Timetable:
    """Compile + validate the slot program for one schedule point."""
    if schedule not in SCHEDULES:
        raise ScheduleError(
            f"unknown schedule {schedule!r}; registry: {SCHEDULES}")
    Pn, M, V = int(n_stages), int(n_micro), int(n_virtual)
    if Pn < 1 or M < 1:
        raise ScheduleError(f"need n_stages, n_micro >= 1: {Pn}, {M}")
    if schedule == "interleaved":
        if V < 2:
            raise ScheduleError(
                "interleaved needs virtual_stages >= 2 (V=1 is exactly "
                "1f1b; use that)")
        if Pn < 2:
            raise ScheduleError("interleaved needs n_stages >= 2")
        if M % Pn != 0:
            raise ScheduleError(
                f"interleaved walks microbatches in groups of P: need "
                f"n_microbatches % n_stages == 0, got {M} % {Pn}")
    elif V != 1:
        raise ScheduleError(
            f"{schedule} runs one chunk per device (virtual_stages=1)")

    C = Pn * V
    f, b, w, K = _slot_maps(schedule, Pn, M, V)

    role = np.zeros((Pn, K), np.int32)
    micro = np.zeros((Pn, K), np.int32)
    vstage = np.zeros((Pn, K), np.int32)
    for tbl, r in ((f, ROLE_F), (b, ROLE_B), (w, ROLE_W)):
        for (c, m), t in tbl.items():
            d = c % Pn
            if role[d, t] != ROLE_IDLE:
                raise ScheduleError(
                    f"slot conflict: stage {d} slot {t} "
                    f"({ROLE_NAMES[role[d, t]]} vs {ROLE_NAMES[r]})")
            role[d, t] = r
            micro[d, t] = m
            vstage[d, t] = c // Pn

    # ring plans: a stashed input code lives arrival -> last recompute
    # (W if the schedule splits backward, else B); a cotangent lives
    # arrival -> its consumer (B, and W for zerobubble)
    def last_use(c, m):
        if w:
            return w[(c, m)]
        if b:
            return b[(c, m)]
        return f[(c, m)]       # forward-only: consumed at its own F slot

    z_assign: dict = {}
    g_assign: dict = {}
    z_cap = g_cap = 1
    for d in range(Pn):
        z_entries = {(c, m): (f[(c - 1, m)] + 1, last_use(c, m))
                     for c in range(C) for m in range(M)
                     if c % Pn == d and c > 0}
        g_entries = {(c, m): (b[(c + 1, m)] + 1, last_use(c, m))
                     for c in range(C) for m in range(M)
                     if c % Pn == d and c < C - 1} if b else {}
        za, zc = _greedy_ring(z_entries)
        ga, gc = _greedy_ring(g_entries)
        z_assign.update(za)
        g_assign.update(ga)
        z_cap, g_cap = max(z_cap, zc), max(g_cap, gc)

    z_arrive = np.full((Pn, K), -1, np.int32)
    z_src = np.zeros((Pn, K), np.int32)
    g_arrive = np.full((Pn, K), -1, np.int32)
    g_src = np.zeros((Pn, K), np.int32)
    for (c, m), ring_i in z_assign.items():
        d = c % Pn
        z_arrive[d, f[(c - 1, m)] + 1] = ring_i
        z_src[d, f[(c, m)]] = ring_i
        if b:
            z_src[d, b[(c, m)]] = ring_i
        if w:
            z_src[d, w[(c, m)]] = ring_i
    for (c, m), ring_i in g_assign.items():
        d = c % Pn
        g_arrive[d, b[(c + 1, m)] + 1] = ring_i
        g_src[d, b[(c, m)]] = ring_i
        if w:
            g_src[d, w[(c, m)]] = ring_i

    def slot_arr(tbl):
        out = np.full((C, M), -1, np.int32)
        for (c, m), t in tbl.items():
            out[c, m] = t
        return out

    tt = Timetable(
        schedule=schedule, n_stages=Pn, n_virtual=V, n_micro=M, n_slots=K,
        role=role, micro=micro, vstage=vstage,
        z_ring=z_cap, g_ring=g_cap,
        z_arrive=z_arrive, z_src=z_src, g_arrive=g_arrive, g_src=g_src,
        f_slot=slot_arr(f), b_slot=slot_arr(b), w_slot=slot_arr(w))
    _check_timetable(tt)
    return tt


def _gpipe_io_tables(n_stages: int, n_micro: int):
    """The GPipe tick loop's ingest/collect indices, re-derived from the
    compiled timetable (bit-identical to the old clip arithmetic): per
    forward tick t, (microbatch stage 0 ingests, collector index on the
    last stage, collector-write flag)."""
    tt = compile_timetable("gpipe", n_stages, n_micro)
    T = n_micro + n_stages - 1
    in_m = np.zeros(T, np.int32)
    out_m = np.zeros(T, np.int32)
    out_ok = np.zeros(T, bool)
    cur = 0
    for t in range(T):
        if tt.role[0, t] == ROLE_F:
            cur = int(tt.micro[0, t])
        in_m[t] = cur
    cur = 0
    for t in range(T):
        if tt.role[-1, t] == ROLE_F:
            cur = int(tt.micro[-1, t])
            out_ok[t] = True
        out_m[t] = cur
    return in_m, out_m, out_ok


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_pipeline_params(key, cfg: ModelConfig, spec: PipelineSpec) -> dict:
    """Stage-stacked layout: every leading axis ``n_stages`` shards over

    ``model``; with ``virtual_stages=V > 1`` a second axis V follows it
    (position [d, v] holds chunk c = v*P + d).  Chunk c owns: its block
    slice, W_down of boundary c (encode at exit; unused on the last chunk)
    and W_up of boundary c-1 (decode at entry; unused on chunk 0).  RNG
    folds by *global chunk index*, so interleaved (P, V) params equal
    gpipe params at P*V stages chunk-for-chunk — the loss-parity oracle."""
    kinds = blk.period_kinds(cfg)
    assert kinds in (["attn_dense"], ["attn_moe"]), (
        "pipeline strategy supports uniform decoder stacks; "
        f"{cfg.arch_id} period={kinds}")
    kind = kinds[0]
    Pn, V, C = spec.n_stages, spec.virtual_stages, spec.n_chunks
    assert cfg.n_layers % C == 0, (cfg.n_layers, C)
    l_per = cfg.n_layers // C

    ks = jax.random.split(key, 4)

    def chunk_blocks(c):
        layers = [blk.init_block(jax.random.fold_in(ks[0], c * 1000 + l),
                                 kind, cfg) for l in range(l_per)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

    def stack_chunks(make):
        """(P, ...) for V == 1 (seed-exact layout), else (P, V, ...)."""
        if V == 1:
            return jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[make(c) for c in range(C)])
        rows = [jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[make(v * Pn + d) for v in range(V)])
                for d in range(Pn)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)

    d, db = cfg.d_model, spec.bottleneck_dim
    params = {
        "embeds": init_embeddings(ks[1], cfg),
        "final_norm": norm_init(cfg.d_model),
        "stages": {"blocks": stack_chunks(chunk_blocks)},
    }
    if spec.compress:
        params["stages"]["enc_norm"] = stack_chunks(
            lambda c: jnp.ones((d,), jnp.float32))
        params["stages"]["w_down"] = stack_chunks(
            lambda c: dense_init(jax.random.fold_in(ks[2], c), d, db))
        params["stages"]["w_up_prev"] = stack_chunks(
            lambda c: dense_init(jax.random.fold_in(ks[3], c), db, d,
                                 scale=1.0 / np.sqrt(db)))
        params["stages"]["alpha_dec"] = stack_chunks(
            lambda c: jnp.asarray(0.5, jnp.float32))
    return params


# ---------------------------------------------------------------------------
# Boundary codecs (fused Pallas hot path, jnp fallback kept as oracle path)
# ---------------------------------------------------------------------------


def _encode_boundary(x, stages, cfg: ModelConfig, spec: PipelineSpec,
                     codec: bool = True):
    """Stage exit: RMSNorm -> W_down -> wire cast, one fused kernel (one HBM
    read of full-width x, one write of the d_model/d_b-smaller code); then
    the optional differentiable int8 wire roundtrip.  Kernel dispatch
    follows the ops.py policy: compiled Pallas on TPU, the identical-math
    oracle elsewhere (REPRO_FORCE_PALLAS_INTERPRET=1 forces the kernel
    bodies under interpret, as the equivalence suite does)."""
    if not spec.compress:
        z = x.astype(spec.wire_dtype)
    elif spec.fuse_boundary:
        z = ops.bottleneck_encode(x, stages["enc_norm"], stages["w_down"],
                                  eps=cfg.norm_eps,
                                  wire_dtype=spec.carry_dtype())
    else:
        xn = rmsnorm(x, stages["enc_norm"], cfg.norm_eps)
        z = (xn.astype(jnp.float32) @ stages["w_down"].astype(jnp.float32)
             ).astype(spec.carry_dtype())
    if codec and spec.wire_codec == "int8":
        z = ops.int8_wire_roundtrip(z)
    return z


def _decode_boundary(z, stages, spec: PipelineSpec, compute_dtype):
    """Stage entry: alpha * (z @ W_up) — fused gated decode (one full-width
    write instead of matmul write + scale pass)."""
    if not spec.compress:
        return z.astype(compute_dtype)
    if spec.fuse_boundary:
        return ops.bottleneck_decode_gated(z, stages["w_up_prev"],
                                           stages["alpha_dec"],
                                           out_dtype=compute_dtype)
    r = (z.astype(jnp.float32) @ stages["w_up_prev"].astype(jnp.float32)
         ).astype(compute_dtype)
    return stages["alpha_dec"].astype(compute_dtype) * r


def _traced_zero(x) -> jax.Array:
    """A scalar f32 zero derived from a traced array.  Rank-0 *constants*
    inside a shard_map body break its transpose on jax<=0.4.x (the const is
    promoted to a body output whose P() spec fails _check_names), so scan
    carries must originate from traced values."""
    return x.ravel()[0].astype(jnp.float32) * 0.0


# ---------------------------------------------------------------------------
# The pipelined forward (GPipe)
# ---------------------------------------------------------------------------


def _stage_forward(stage_params, x, cfg: ModelConfig, kind: str,
                   positions, remat: bool):
    """Apply this stage's block slice (inner scan over layers)."""
    ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=positions)

    def body(h, layer_params):
        h, _, _ = blk.apply_block(kind, layer_params, h, ctx, None)
        return h, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def pipeline_apply(params, x_micro, cfg: ModelConfig, spec: PipelineSpec,
                   mesh, batch_axes: tuple[str, ...] = ("data",),
                   remat: bool = True):
    """x_micro: (n_micro, B, S, d_model) embedded microbatches (B = global

    batch / n_micro).  Returns (n_micro, B, S, d_model) block-stack outputs.
    GPipe-structured forward sweep (virtual_stages == 1 layouts only).
    """
    assert spec.virtual_stages == 1, \
        "pipeline_apply is the V=1 forward; interleaved runs the executor"
    kind = blk.period_kinds(cfg)[0]
    n_stages, n_micro = spec.n_stages, spec.n_microbatches
    d_wire = spec.wire_width(cfg)
    S = x_micro.shape[2]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    in_m, out_m, out_ok = _gpipe_io_tables(n_stages, n_micro)
    in_tbl, out_tbl = jnp.asarray(in_m), jnp.asarray(out_m)
    ok_tbl = jnp.asarray(out_ok)

    def body(x_all, stages):
        # local views: x_all (n_micro, B_loc, S, D); stages leading dim == 1
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = x_all.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        compute_dtype = x_all.dtype

        z0 = jnp.zeros((B_loc, S, d_wire), spec.carry_dtype())
        out0 = jnp.zeros_like(x_all)

        def tick(carry, t):
            z, outputs = carry
            # ---- stage entry: ingest (stage 0) or decode the wire code ----
            x_in = jax.lax.dynamic_index_in_dim(
                x_all, in_tbl[t], 0, keepdims=False)
            r = _decode_boundary(z, stages, spec, compute_dtype)
            x = jnp.where(stage == 0, x_in, r)
            # ---- stage compute ----
            x = _stage_forward(stages["blocks"], x, cfg, kind, pos, remat)
            # ---- stage exit: encode the wire code ----
            z_out = _encode_boundary(x, stages, cfg, spec)
            # ---- collect finished microbatches on the last stage ----
            out_idx = out_tbl[t]
            is_out = (stage == n_stages - 1) & ok_tbl[t]
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_out, x, cur), out_idx, 0)
            # ---- stream to the next stage (no wraparound: stage0 gets 0) ----
            z_next = jax.lax.ppermute(
                z_out, "model", [(i, i + 1) for i in range(n_stages - 1)])
            return (z_next, outputs), None

        T = n_micro + n_stages - 1
        (z, outputs), _ = jax.lax.scan(tick, (z0, out0),
                                       jnp.arange(T, dtype=jnp.int32))
        # only the last stage holds real outputs; psum replicates them
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            "model")
        return outputs

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    return _shard_map(
        body, mesh,
        (P(None, batch_axes, None, None), stage_specs),
        P(None, batch_axes, None, None),
    )(x_micro, params["stages"])


# ---------------------------------------------------------------------------
# End-to-end pipelined train/loss step
# ---------------------------------------------------------------------------


def pipeline_loss(params, batch, cfg: ModelConfig, spec: PipelineSpec, mesh,
                  batch_axes: tuple[str, ...] = ("data",), z_loss: float = 1e-4,
                  compute_dtype=jnp.bfloat16):
    if spec.virtual_stages > 1:
        # interleaved layouts only exist for the slot executor
        loss, _ = pipeline_timetable_grads(params, batch, cfg, spec, mesh,
                                           batch_axes, z_loss, compute_dtype)
        return loss
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_micro = spec.n_microbatches
    assert B % n_micro == 0, (B, n_micro)
    x = embed_fn(params["embeds"], tokens, cfg, None, compute_dtype)
    x = x.reshape(n_micro, B // n_micro, S, -1)
    y = pipeline_apply(params, x, cfg, spec, mesh, batch_axes)
    # loss head is MICROBATCHED (scan + remat): a full-batch fp32 logits
    # tensor would be (B, S, V/16) ≈ 34 GB/device (§Perf cell C iteration 4:
    # 145 GiB/device -> fits, and the logits all-gather drops with it)
    labels_m = labels.reshape(n_micro, B // n_micro, S)

    def head(y_mb, lab_mb):
        h = rmsnorm(y_mb, params["final_norm"], cfg.norm_eps)
        lgts = logits_fn(params["embeds"], h, cfg, None)
        return next_token_loss(lgts, lab_mb, z_loss)

    head = jax.checkpoint(head, policy=jax.checkpoint_policies.nothing_saveable)

    def body(acc, xs):
        y_mb, lab_mb = xs
        return acc + head(y_mb, lab_mb), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (y, labels_m))
    return total / n_micro


def wire_bytes_per_hop(cfg: ModelConfig, spec: PipelineSpec,
                       global_batch: int, seq: int,
                       data_shards: int = 1) -> int:
    """On-wire bytes for one full microbatch sweep across one boundary.

    For the int8 codec this accounts the fp32 scales honestly: one per
    quantization block of the per-device per-microbatch code tensor — the
    block the runtime codec actually quantizes (``data_shards`` matters:
    a sharded microbatch can fall back to per-row scales)."""
    width = spec.wire_width(cfg)
    n = global_batch * seq * width
    if spec.wire_codec == "int8":
        micro_elems = (max(global_batch // spec.n_microbatches // data_shards,
                           1) * seq * width)
        block = qs.wire_block(micro_elems, width)
        return n + (n // block) * 4
    return n * jnp.dtype(spec.wire_dtype).itemsize


def schedule_stats(cfg: ModelConfig, spec: PipelineSpec, global_batch: int,
                   seq: int, data_shards: int = 1) -> dict:
    """Schedule accounting derived from the compiled timetable and the real
    carry structures:

    * ``bubble_fraction``   — idle fraction of the *executed timetable*
      (``Timetable.bubble_fraction``, not a closed form; equals
      (P-1)/(M+P-1) for gpipe/1f1b — the tests pin that identity)
    * ``stash_codes/bytes`` — per-device activation stash: GPipe saves the
      checkpointed tick carry's wire code once per tick (T float codes —
      an int8 carry would sever the straight-through gradient, so the
      autodiff path cannot stash pairs); explicit schedules allocate the
      compiler's z-ring, which under int8 stashes the physical
      (codes, scales) pair
    * ``grad_ring_codes``   — cotangent-ring slots (zerobubble keeps each
      B's seed alive until its W)
    * ``carry_code_bytes``  — one decoded in-flight code (B_loc, S, d_wire)
    * ``wire_bytes_per_hop``— on-wire bytes per boundary per sweep
    """
    Pn, M = spec.n_stages, spec.n_microbatches
    tt = spec.timetable()
    width = spec.wire_width(cfg)
    B_loc = max(global_batch // M // data_shards, 1)
    code_bytes = (B_loc * seq * width
                  * jnp.dtype(spec.carry_dtype()).itemsize)
    if spec.wire_codec == "int8":
        ring_code_bytes = qs.wire_nbytes((B_loc, seq, width))
    else:
        ring_code_bytes = code_bytes
    ticks = M + Pn - 1
    if spec.schedule == "gpipe":
        loop_len = ticks
        stash_codes = ticks
        stash_bytes = ticks * code_bytes
        grad_ring = 0
    else:
        loop_len = tt.n_slots
        stash_codes = tt.z_ring
        stash_bytes = tt.z_ring * ring_code_bytes
        grad_ring = tt.g_ring
    return {
        "schedule": spec.schedule,
        "n_stages": Pn,
        "n_microbatches": M,
        "virtual_stages": spec.virtual_stages,
        "loop_length": loop_len,
        "timetable_slots": tt.n_slots,
        "bubble_fraction": tt.bubble_fraction(),
        "carry_code_bytes": int(code_bytes),
        "ring_code_bytes": int(ring_code_bytes),
        "stash_codes": int(stash_codes),
        "stash_bytes": int(stash_bytes),
        "grad_ring_codes": int(grad_ring),
        "wire_bytes_per_hop": int(
            wire_bytes_per_hop(cfg, spec, global_batch, seq,
                               data_shards=data_shards)),
    }


# ---------------------------------------------------------------------------
# Fused pipeline: embed on stage 0, loss on the last stage (paper §2.2:
# 'Miners in the first layer also handle data ingestion and tokenization,
# while those in the final layer compute the training loss.')
# ---------------------------------------------------------------------------


def pipeline_loss_fused(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                        mesh, batch_axes: tuple[str, ...] = ("data",),
                        z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """One shard_map for the whole step: tokens (tiny) replicate to stages

    instead of embedded activations; the loss is computed on the last stage
    and psum'd as a scalar.  §Perf cell C iteration 5: removes the
    537 MB x 2 x ticks GSPMD resharding permutes and the 4.5 GB output
    all-reduce of the v1 layout — inter-stage traffic is then just the
    (compressed) wire codes, i.e. the paper's §4 claim made visible on-mesh.
    The tick loop's ingest/collect indices come from the compiled gpipe
    timetable (``_gpipe_io_tables``).
    """
    kind = blk.period_kinds(cfg)[0]
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_stages, n_micro = spec.n_stages, spec.n_microbatches
    assert B % n_micro == 0
    assert spec.virtual_stages == 1, \
        "the fused autodiff loop is the V=1 golden path"
    d_wire = spec.wire_width(cfg)
    Bm = B // n_micro
    tokens_m = tokens.reshape(n_micro, Bm, S)
    labels_m = labels.reshape(n_micro, Bm, S)
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    in_m, out_m, out_ok = _gpipe_io_tables(n_stages, n_micro)
    in_tbl, out_tbl = jnp.asarray(in_m), jnp.asarray(out_m)
    ok_tbl = jnp.asarray(out_ok)

    def body(toks, labs, embed_tbl, unembed_tbl, final_gamma, stages):
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = toks.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        last = n_stages - 1

        z0 = jnp.zeros((B_loc, S, d_wire), spec.carry_dtype())
        out0 = jnp.zeros((n_micro, B_loc, S, cfg.d_model), compute_dtype)

        # §Perf cell C iteration 7 (winner of 6/7/8 — see EXPERIMENTS.md):
        # the tick body is checkpointed, so the backward pipeline re-derives
        # each tick from its carry, whose activation part is the COMPRESSED
        # wire code z — the paper's 64x compression also shrinks the GPipe
        # activation stash.  The in-carry output collector is donated/
        # aliased in place by XLA (the ys-collection variants measured
        # strictly worse).
        def tick(carry, t):
            z, outputs = carry
            t_in = jax.lax.dynamic_index_in_dim(
                toks, in_tbl[t], 0, keepdims=False)
            # stage 0 ingests tokens (paper: first-layer miners tokenize);
            # the embedding gather is tiny next to a full-width activation
            x_in = jnp.take(embed_tbl, t_in, axis=0).astype(compute_dtype)
            r = _decode_boundary(z, stages, spec, compute_dtype)
            x = jnp.where(stage == 0, x_in, r)
            x = _stage_forward(stages["blocks"], x, cfg, kind, pos, True)
            z_out = _encode_boundary(x, stages, cfg, spec)
            out_idx = out_tbl[t]
            is_out = (stage == last) & ok_tbl[t]
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_out, x, cur), out_idx, 0)
            z_next = jax.lax.ppermute(
                z_out, "model", [(i, i + 1) for i in range(n_stages - 1)])
            return (z_next, outputs), None

        tick = jax.checkpoint(tick,
                              policy=jax.checkpoint_policies.nothing_saveable)
        T = n_micro + n_stages - 1
        (_, outputs), _ = jax.lax.scan(tick, (z0, out0),
                                       jnp.arange(T, dtype=jnp.int32))

        # ---- loss head on the last stage, microbatched + remat ----
        pad_mask = (jnp.arange(unembed_tbl.shape[0]) >= cfg.vocab_size
                    ) * (-1e9)

        def head(y_mb, lab_mb):
            h = rmsnorm(y_mb, final_gamma, cfg.norm_eps)
            lgts = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                              unembed_tbl.astype(jnp.float32)) + pad_mask
            return next_token_loss(lgts, lab_mb, z_loss)

        head = jax.checkpoint(head,
                              policy=jax.checkpoint_policies.nothing_saveable)

        def loss_body(acc, xs):
            y_mb, lab_mb = xs
            return acc + head(y_mb, lab_mb), None

        local_loss, _ = jax.lax.scan(loss_body, _traced_zero(outputs),
                                     (outputs, labs))
        loss = jax.lax.psum(
            jnp.where(stage == last, local_loss, 0.0), "model") / n_micro
        return jax.lax.pmean(loss, batch_axes)

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    unembed = params["embeds"].get("unembed", params["embeds"]["embed"])
    return _shard_map(
        body, mesh,
        (P(None, batch_axes, None), P(None, batch_axes, None),
         P(None, None), P(None, None), P(None), stage_specs),
        P(),
    )(tokens_m, labels_m, params["embeds"]["embed"], unembed,
      params["final_norm"], params["stages"])


# ---------------------------------------------------------------------------
# Generalized slot executor: runs any compiled explicit-backward timetable
# (1f1b / interleaved / zerobubble) — loss AND grads in one shard_map
# ---------------------------------------------------------------------------


def pipeline_timetable_grads(params, batch, cfg: ModelConfig,
                             spec: PipelineSpec, mesh,
                             batch_axes: tuple[str, ...] = ("data",),
                             z_loss: float = 1e-4,
                             compute_dtype=jnp.bfloat16):
    """One shard_map computing ``(loss, grads)`` by replaying the compiled

    ``Timetable``.  Each slot dispatches its table role via ``lax.switch``
    — idle, F, B, or (zerobubble) W — so a stage only pays for the work its
    slot actually does: F slots run the primal blocks alone (no loss head,
    no pullback); B slots re-run the chunk's forward from the stashed
    *wire code* under ``jax.vjp`` (decode -> blocks -> encode + loss head),
    seed the cotangent from the cotangent ring (or 1.0 for the final
    chunk's loss), and — for 1f1b/interleaved — accumulate param grads in
    the same pullback; zerobubble's B pulls back to the activation only
    (the upstream hand-off leaves as early as 1F1B's) while its W re-runs
    the same vjp restricted to params in a former idle slot, consuming the
    cotangent the ring kept alive.  ``lax.switch`` on the per-device role
    is legal under shard_map here because the branches contain no
    collectives — the two ``ppermute`` hand-offs stay outside, executed by
    every device each slot.  Ring writes/reads use the compiler's
    ring-stash plan verbatim; under ``wire_codec="int8"`` the rings and
    hand-offs carry the physical (int8 codes, fp32 scales) pair and
    dequantize at consumption — bit-identical values to the old
    dequantize-then-stash (q * scale is exact in f32), at ~half the bf16
    ring bytes.

    Returns grads matching ``jax.grad(pipeline_loss_fused)``: per-stage
    params stay per-stage, shared params (embeddings, final norm) are
    psum'd over stages and pmean'd over the batch axes.
    """
    kind = blk.period_kinds(cfg)[0]
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    Pn, M, V = spec.n_stages, spec.n_microbatches, spec.virtual_stages
    assert B % M == 0
    d_wire = spec.wire_width(cfg)
    Bm = B // M
    tokens_m = tokens.reshape(M, Bm, S)
    labels_m = labels.reshape(M, Bm, S)
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    tt = spec.timetable()
    K = tt.n_slots
    zb = spec.schedule == "zerobubble"
    is_int8 = spec.wire_codec == "int8"

    # (P, K) tables baked as constants; [stage, t] gathers give each device
    # its compiled unit for the slot
    role_tbl = jnp.asarray(tt.role)
    micro_tbl = jnp.asarray(tt.micro)
    vst_tbl = jnp.asarray(tt.vstage)
    zarr_tbl = jnp.asarray(tt.z_arrive)
    zsrc_tbl = jnp.asarray(tt.z_src)
    garr_tbl = jnp.asarray(tt.g_arrive)
    gsrc_tbl = jnp.asarray(tt.g_src)

    def body(toks, labs, embed_tbl, unembed_tbl, final_gamma, stages):
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = toks.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        last = Pn - 1
        pad_mask = (jnp.arange(unembed_tbl.shape[0]) >= cfg.vocab_size
                    ) * (-1e9)

        code_shape = (B_loc, S, d_wire)
        if is_int8:
            n_code = B_loc * S * d_wire
            blk_w = qs.wire_block(n_code, d_wire)

            def wire_zero():
                return (jnp.zeros(code_shape, jnp.int8),
                        jnp.zeros((n_code // blk_w,), jnp.float32))

            def wire_pack(z_f):
                # f32 code -> the physically shipped/stashed (q, scales)
                return ops.wire_encode(z_f)

            def wire_unpack(wz):
                # exact dequantized f32 (== ops.int8_wire_roundtrip output)
                return ops.wire_decode(*wz)
        else:
            def wire_zero():
                return jnp.zeros(code_shape, spec.carry_dtype())

            def wire_pack(z_f):
                return z_f

            def wire_unpack(wz):
                return wz

        def ring_zero(n):
            return jax.tree.map(
                lambda a: jnp.zeros((n,) + a.shape, a.dtype), wire_zero())

        def ring_read(ring, i):
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False), ring)

        def ring_write(ring, val, i, ok):
            def upd(a, v):
                cur = jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    a, jnp.where(ok, v, cur), i, 0)
            return jax.tree.map(upd, ring, val)

        def chunk_params(v_idx):
            if V == 1:
                return stages
            return jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, v_idx, 0,
                                                       keepdims=False),
                stages)

        def acc_chunk_grads(g_acc, g_chunk, v_idx):
            if V == 1:
                return jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, g_chunk)

            def upd(a, g):
                cur = jax.lax.dynamic_index_in_dim(a, v_idx, 0,
                                                   keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    a, cur + g.astype(jnp.float32), v_idx, 0)
            return jax.tree.map(upd, g_acc, g_chunk)

        def stage_fn(chunk_p, z_in, emb, unemb, fgamma, toks_t, labs_t,
                     is_first):
            """One chunk's forward from its received wire code (or tokens
            on the first chunk), through its blocks, to its exit code AND
            the loss head — one function so one vjp yields every cotangent;
            the where() gates route grads to the right owners (embed on the
            first chunk, head params on the last) automatically."""
            x_e = jnp.take(emb, toks_t, axis=0).astype(compute_dtype)
            r = _decode_boundary(z_in, chunk_p, spec, compute_dtype)
            x = jnp.where(is_first, x_e, r)
            x = _stage_forward(chunk_p["blocks"], x, cfg, kind, pos, False)
            z_out = _encode_boundary(x, chunk_p, cfg, spec, codec=False)
            h = rmsnorm(x, fgamma, cfg.norm_eps)
            lgts = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                              unemb.astype(jnp.float32)) + pad_mask
            loss_t = next_token_loss(lgts, labs_t, z_loss)
            return z_out, loss_t

        def slot(carry, t):
            z_wire, g_wire, z_ring, g_ring, grads, loss_acc = carry
            # ---- arrivals: last slot's hand-offs enter their compiled
            # ring slots (at the warmup->steady seam a code arrives up to
            # P - s slots before its forward slot, so it must be stashed on
            # arrival — a single-slot register would lose it)
            za = zarr_tbl[stage, t]
            z_ring = ring_write(z_ring, z_wire, jnp.maximum(za, 0), za >= 0)
            ga = garr_tbl[stage, t]
            g_ring = ring_write(g_ring, g_wire, jnp.maximum(ga, 0), ga >= 0)
            # ---- this slot's compiled unit ----
            role_id = role_tbl[stage, t]
            m_idx = micro_tbl[stage, t]
            v_idx = vst_tbl[stage, t]
            z_src = ring_read(z_ring, zsrc_tbl[stage, t])
            ct_src = ring_read(g_ring, gsrc_tbl[stage, t])
            toks_t = jax.lax.dynamic_index_in_dim(toks, m_idx, 0,
                                                  keepdims=False)
            labs_t = jax.lax.dynamic_index_in_dim(labs, m_idx, 0,
                                                  keepdims=False)
            chunk_p = chunk_params(v_idx)
            is_first = (stage == 0) & (v_idx == 0)
            is_last = (stage == last) & (v_idx == V - 1)

            def seed_cts(z_out, loss_t):
                """Cotangent seeds: the final chunk seeds its loss with 1,
                everyone else the ring-held upstream activation grad."""
                ct_z = jnp.where(is_last, jnp.zeros_like(z_out),
                                 wire_unpack(ct_src).astype(z_out.dtype))
                ct_loss = jnp.where(is_last, jnp.ones_like(loss_t),
                                    jnp.zeros_like(loss_t))
                return ct_z, ct_loss

            def gate_g(g_send):
                # the first chunk has no upstream; with wraparound perms
                # (V > 1) its send would otherwise corrupt the last device
                return jax.tree.map(
                    lambda a: jnp.where(is_first, jnp.zeros_like(a), a),
                    g_send)

            # ---- role dispatch: pay only for what this slot does --------
            # (branches close over loop-invariant tracers; no collectives
            # inside, so per-device switch is shard_map-legal)
            def idle(grads, loss_acc):
                return wire_zero(), wire_zero(), grads, loss_acc

            def fwd_slot(grads, loss_acc):
                # primal blocks only: no loss head, no pullback
                x_e = jnp.take(embed_tbl, toks_t,
                               axis=0).astype(compute_dtype)
                r = _decode_boundary(wire_unpack(z_src), chunk_p, spec,
                                     compute_dtype)
                x = jnp.where(is_first, x_e, r)
                x = _stage_forward(chunk_p["blocks"], x, cfg, kind, pos,
                                   False)
                z_out = _encode_boundary(x, chunk_p, cfg, spec, codec=False)
                return wire_pack(z_out), wire_zero(), grads, loss_acc

            def bwd_full(grads, loss_acc):
                z_in = wire_unpack(z_src)
                (z_out, loss_t), vjp = jax.vjp(
                    lambda cp, z, e, u, fg: stage_fn(cp, z, e, u, fg,
                                                     toks_t, labs_t,
                                                     is_first),
                    chunk_p, z_in, embed_tbl, unembed_tbl, final_gamma)
                ct_z, ct_loss = seed_cts(z_out, loss_t)
                g_cp, g_z, g_emb, g_unemb, g_fg = vjp((ct_z, ct_loss))
                grads = (acc_chunk_grads(grads[0], g_cp, v_idx),
                         grads[1] + g_emb.astype(jnp.float32),
                         grads[2] + g_unemb.astype(jnp.float32),
                         grads[3] + g_fg.astype(jnp.float32))
                g_send = gate_g(wire_pack(g_z.astype(spec.carry_dtype())))
                loss_acc = loss_acc + jnp.where(is_last, loss_t,
                                                jnp.zeros_like(loss_t))
                return wire_zero(), g_send, grads, loss_acc

            def bwd_act(grads, loss_acc):
                # zerobubble B: activation grad only — the upstream
                # hand-off leaves as early as 1F1B's; params wait for W
                z_in = wire_unpack(z_src)
                (z_out, loss_t), vjp = jax.vjp(
                    lambda z: stage_fn(chunk_p, z, embed_tbl, unembed_tbl,
                                       final_gamma, toks_t, labs_t,
                                       is_first),
                    z_in)
                ct_z, ct_loss = seed_cts(z_out, loss_t)
                (g_z,) = vjp((ct_z, ct_loss))
                g_send = gate_g(wire_pack(g_z.astype(spec.carry_dtype())))
                loss_acc = loss_acc + jnp.where(is_last, loss_t,
                                                jnp.zeros_like(loss_t))
                return wire_zero(), g_send, grads, loss_acc

            def wgrad_slot(grads, loss_acc):
                # zerobubble W: the same vjp restricted to params, run in a
                # former idle slot; the cotangent ring kept the seed alive
                z_in = wire_unpack(z_src)
                (z_out, loss_t), vjp = jax.vjp(
                    lambda cp, e, u, fg: stage_fn(cp, z_in, e, u, fg,
                                                  toks_t, labs_t, is_first),
                    chunk_p, embed_tbl, unembed_tbl, final_gamma)
                ct_z, ct_loss = seed_cts(z_out, loss_t)
                g_cp, g_emb, g_unemb, g_fg = vjp((ct_z, ct_loss))
                grads = (acc_chunk_grads(grads[0], g_cp, v_idx),
                         grads[1] + g_emb.astype(jnp.float32),
                         grads[2] + g_unemb.astype(jnp.float32),
                         grads[3] + g_fg.astype(jnp.float32))
                return wire_zero(), wire_zero(), grads, loss_acc

            branches = ([idle, fwd_slot, bwd_act, wgrad_slot] if zb
                        else [idle, fwd_slot, bwd_full])
            z_send, g_send, grads, loss_acc = jax.lax.switch(
                role_id, branches, grads, loss_acc)
            # ---- hand-offs: consumed exactly one slot later; chunk
            # boundaries wrap devices only when V > 1 ----------------------
            if V == 1:
                fperm = [(i, i + 1) for i in range(Pn - 1)]
                bperm = [(i + 1, i) for i in range(Pn - 1)]
            else:
                fperm = [(i, (i + 1) % Pn) for i in range(Pn)]
                bperm = [((i + 1) % Pn, i) for i in range(Pn)]
            z_wire = jax.tree.map(
                lambda a: jax.lax.ppermute(a, "model", fperm), z_send)
            g_wire = jax.tree.map(
                lambda a: jax.lax.ppermute(a, "model", bperm), g_send)
            return (z_wire, g_wire, z_ring, g_ring, grads, loss_acc), None

        grads0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32),
                              (stages, embed_tbl, unembed_tbl, final_gamma))
        carry0 = (wire_zero(), wire_zero(), ring_zero(tt.z_ring),
                  ring_zero(tt.g_ring), grads0, _traced_zero(toks))
        (_, _, _, _, grads, loss_acc), _ = jax.lax.scan(
            slot, carry0, jnp.arange(K, dtype=jnp.int32))

        g_stages, g_emb, g_unemb, g_fg = grads
        scale = 1.0 / M
        loss = jax.lax.pmean(
            jax.lax.psum(jnp.where(stage == last, loss_acc, 0.0 * loss_acc),
                         "model") * scale, batch_axes)
        # stage params: per-stage owner; shared params: sum over stages
        g_stages = jax.tree.map(
            lambda a: jax.lax.pmean(a * scale, batch_axes)[None], g_stages)
        shared = jax.tree.map(
            lambda a: jax.lax.pmean(jax.lax.psum(a * scale, "model"),
                                    batch_axes),
            (g_emb, g_unemb, g_fg))
        return loss, g_stages, *shared

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    tied = "unembed" not in params["embeds"]
    unembed = params["embeds"].get("unembed", params["embeds"]["embed"])
    loss, g_stages, g_emb, g_unemb, g_fg = _shard_map(
        body, mesh,
        (P(None, batch_axes, None), P(None, batch_axes, None),
         P(None, None), P(None, None), P(None), stage_specs),
        (P(), stage_specs, P(), P(), P()),
    )(tokens_m, labels_m, params["embeds"]["embed"], unembed,
      params["final_norm"], params["stages"])

    embeds_g = {"embed": g_emb + g_unemb if tied else g_emb}
    if not tied:
        embeds_g["unembed"] = g_unemb
    grads = {"embeds": embeds_g, "final_norm": g_fg, "stages": g_stages}
    return loss, grads


def pipeline_1f1b_grads(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                        mesh, batch_axes: tuple[str, ...] = ("data",),
                        z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """Back-compat name for the generalized executor (PR 2/6 API)."""
    return pipeline_timetable_grads(params, batch, cfg, spec, mesh,
                                    batch_axes, z_loss, compute_dtype)


def pipeline_loss_1f1b(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                       mesh, batch_axes: tuple[str, ...] = ("data",),
                       z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """`jax.grad`-compatible explicit-schedule loss: the slot executor
    computes the gradients in its own forward pass, so the custom_vjp
    backward just hands them to autodiff (scaled by the incoming
    cotangent).  Works for any executor schedule (1f1b / interleaved /
    zerobubble)."""

    @jax.custom_vjp
    def run(p):
        loss, _ = pipeline_timetable_grads(p, batch, cfg, spec, mesh,
                                           batch_axes, z_loss, compute_dtype)
        return loss

    def fwd(p):
        loss, grads = pipeline_timetable_grads(p, batch, cfg, spec, mesh,
                                               batch_axes, z_loss,
                                               compute_dtype)
        return loss, (grads, p)

    def bwd(res, g):
        grads, p = res
        return (jax.tree.map(
            lambda gr, pp: (g * gr.astype(jnp.float32)).astype(pp.dtype),
            grads, p),)

    run.defvjp(fwd, bwd)
    return run(params)


def pipeline_loss_and_grads(params, batch, cfg: ModelConfig,
                            spec: PipelineSpec, mesh,
                            batch_axes: tuple[str, ...] = ("data",),
                            z_loss: float = 1e-4,
                            compute_dtype=jnp.bfloat16):
    """Schedule dispatcher for the training hot path: GPipe differentiates
    the tick scan; every other schedule replays its compiled timetable in
    the slot executor, computing grads explicitly in one pass."""
    if spec.schedule == "gpipe":
        return jax.value_and_grad(
            lambda p: pipeline_loss_fused(p, batch, cfg, spec, mesh,
                                          batch_axes, z_loss,
                                          compute_dtype))(params)
    return pipeline_timetable_grads(params, batch, cfg, spec, mesh,
                                    batch_axes, z_loss, compute_dtype)
