"""Pipeline-parallel engine (paper C1 + C3 on-mesh): GPipe-style schedule in

``shard_map`` with the ``model`` mesh axis as the stage axis, streaming
microbatch activations stage-to-stage via ``ppermute`` — and, when
``compress=True``, streaming the paper's *bottleneck codes* (width d_b)
instead of full-width activations, cutting inter-stage bytes by
d_model/d_b (64x for the paper's 2048->32).

Faithfulness map:
  miners on one layer-slice   -> devices in one model-axis row
  S3 activation hand-off      -> ppermute along ``model``
  bottleneck block at miner Tx-> encode at stage exit (stage owns W_down)
  post-bottleneck at miner Rx -> decode at stage entry (stage owns W_up of
                                 the previous boundary)
  DP across pipeline replicas -> ``data`` (x ``pod``) axes

The schedule is plain GPipe: T = n_micro + n_stages - 1 ticks; autodiff
through the tick scan gives the backward pipeline automatically (transpose
of ppermute = reverse-direction ppermute), so gradients of the wire codes
are compressed exactly like activations — the paper's symmetrical 128x.

Used by ``--strategy pipeline`` for dense-family archs and by the §Perf
paper-representative hillclimb cell.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ModelConfig
from repro.models import blocks as blk
from repro.models.layers import (
    dense_init,
    init_embeddings,
    next_token_loss,
    norm_init,
    rmsnorm,
)
from repro.models.layers import embed as embed_fn
from repro.models.layers import logits as logits_fn

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    n_stages: int
    n_microbatches: int
    compress: bool = True            # stream bottleneck codes, not residuals
    bottleneck_dim: int = 32
    wire_dtype: Any = jnp.bfloat16

    def wire_width(self, cfg: ModelConfig) -> int:
        return self.bottleneck_dim if self.compress else cfg.d_model


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_pipeline_params(key, cfg: ModelConfig, spec: PipelineSpec) -> dict:
    """Stage-stacked layout: every leading axis ``n_stages`` shards over

    ``model``.  Stage s owns: its block slice, W_down of boundary s (encode
    at exit; unused on the last stage) and W_up of boundary s-1 (decode at
    entry; unused on stage 0)."""
    kinds = blk.period_kinds(cfg)
    assert kinds in (["attn_dense"], ["attn_moe"]), (
        "pipeline strategy supports uniform decoder stacks; "
        f"{cfg.arch_id} period={kinds}")
    kind = kinds[0]
    assert cfg.n_layers % spec.n_stages == 0, (cfg.n_layers, spec.n_stages)
    l_per = cfg.n_layers // spec.n_stages

    ks = jax.random.split(key, 4)
    stages = []
    for s in range(spec.n_stages):
        layers = [blk.init_block(jax.random.fold_in(ks[0], s * 1000 + l),
                                 kind, cfg) for l in range(l_per)]
        stages.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers))
    stage_blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *stages)

    d, db = cfg.d_model, spec.bottleneck_dim
    params = {
        "embeds": init_embeddings(ks[1], cfg),
        "final_norm": norm_init(cfg.d_model),
        "stages": {"blocks": stage_blocks},
    }
    if spec.compress:
        params["stages"]["enc_norm"] = jnp.ones((spec.n_stages, d), jnp.float32)
        params["stages"]["w_down"] = jnp.stack([
            dense_init(jax.random.fold_in(ks[2], s), d, db)
            for s in range(spec.n_stages)])
        params["stages"]["w_up_prev"] = jnp.stack([
            dense_init(jax.random.fold_in(ks[3], s), db, d,
                       scale=1.0 / np.sqrt(db))
            for s in range(spec.n_stages)])
        params["stages"]["alpha_dec"] = jnp.full((spec.n_stages,),
                                                 0.5, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# The pipelined forward
# ---------------------------------------------------------------------------


def _stage_forward(stage_params, x, cfg: ModelConfig, kind: str,
                   positions, remat: bool):
    """Apply this stage's block slice (inner scan over layers)."""
    ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=positions)

    def body(h, layer_params):
        h, _, _ = blk.apply_block(kind, layer_params, h, ctx, None)
        return h, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def pipeline_apply(params, x_micro, cfg: ModelConfig, spec: PipelineSpec,
                   mesh, batch_axes: tuple[str, ...] = ("data",),
                   remat: bool = True):
    """x_micro: (n_micro, B, S, d_model) embedded microbatches (B = global

    batch / n_micro).  Returns (n_micro, B, S, d_model) block-stack outputs.
    """
    kind = blk.period_kinds(cfg)[0]
    n_stages, n_micro = spec.n_stages, spec.n_microbatches
    d_wire = spec.wire_width(cfg)
    S = x_micro.shape[2]
    positions = jnp.arange(S, dtype=jnp.int32)[None]

    def body(x_all, stages):
        # local views: x_all (n_micro, B_loc, S, D); stages leading dim == 1
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = x_all.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        compute_dtype = x_all.dtype

        z0 = jnp.zeros((B_loc, S, d_wire), spec.wire_dtype)
        out0 = jnp.zeros_like(x_all)

        def tick(carry, t):
            z, outputs = carry
            # ---- stage entry: ingest (stage 0) or decode the wire code ----
            x_in = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            if spec.compress:
                r = (z.astype(jnp.float32) @ stages["w_up_prev"].astype(jnp.float32)
                     ).astype(compute_dtype)
                r = stages["alpha_dec"].astype(compute_dtype) * r
            else:
                r = z.astype(compute_dtype)
            x = jnp.where(stage == 0, x_in, r)
            # ---- stage compute ----
            x = _stage_forward(stages["blocks"], x, cfg, kind, pos, remat)
            # ---- stage exit: encode the wire code ----
            if spec.compress:
                xn = rmsnorm(x, stages["enc_norm"], cfg.norm_eps)
                z_out = (xn.astype(jnp.float32) @ stages["w_down"].astype(jnp.float32)
                         ).astype(spec.wire_dtype)
            else:
                z_out = x.astype(spec.wire_dtype)
            # ---- collect finished microbatches on the last stage ----
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_out = ((stage == n_stages - 1) & (t >= n_stages - 1)
                      & (t - (n_stages - 1) < n_micro))
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_out, x, cur), out_idx, 0)
            # ---- stream to the next stage (no wraparound: stage0 gets 0) ----
            z_next = jax.lax.ppermute(
                z_out, "model", [(i, i + 1) for i in range(n_stages - 1)])
            return (z_next, outputs), None

        T = n_micro + n_stages - 1
        (z, outputs), _ = jax.lax.scan(tick, (z0, out0),
                                       jnp.arange(T, dtype=jnp.int32))
        # only the last stage holds real outputs; psum replicates them
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            "model")
        return outputs

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, batch_axes, None, None), stage_specs),
        out_specs=P(None, batch_axes, None, None),
        check_vma=False,
    )(x_micro, params["stages"])


# ---------------------------------------------------------------------------
# End-to-end pipelined train/loss step
# ---------------------------------------------------------------------------


def pipeline_loss(params, batch, cfg: ModelConfig, spec: PipelineSpec, mesh,
                  batch_axes: tuple[str, ...] = ("data",), z_loss: float = 1e-4,
                  compute_dtype=jnp.bfloat16):
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_micro = spec.n_microbatches
    assert B % n_micro == 0, (B, n_micro)
    x = embed_fn(params["embeds"], tokens, cfg, None, compute_dtype)
    x = x.reshape(n_micro, B // n_micro, S, -1)
    y = pipeline_apply(params, x, cfg, spec, mesh, batch_axes)
    # loss head is MICROBATCHED (scan + remat): a full-batch fp32 logits
    # tensor would be (B, S, V/16) ≈ 34 GB/device (§Perf cell C iteration 4:
    # 145 GiB/device -> fits, and the logits all-gather drops with it)
    labels_m = labels.reshape(n_micro, B // n_micro, S)

    def head(y_mb, lab_mb):
        h = rmsnorm(y_mb, params["final_norm"], cfg.norm_eps)
        lgts = logits_fn(params["embeds"], h, cfg, None)
        return next_token_loss(lgts, lab_mb, z_loss)

    head = jax.checkpoint(head, policy=jax.checkpoint_policies.nothing_saveable)

    def body(acc, xs):
        y_mb, lab_mb = xs
        return acc + head(y_mb, lab_mb), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (y, labels_m))
    return total / n_micro


def wire_bytes_per_hop(cfg: ModelConfig, spec: PipelineSpec,
                       global_batch: int, seq: int) -> int:
    """On-wire bytes for one full microbatch sweep across one boundary."""
    width = spec.wire_width(cfg)
    return global_batch * seq * width * jnp.dtype(spec.wire_dtype).itemsize


# ---------------------------------------------------------------------------
# Fused pipeline: embed on stage 0, loss on the last stage (paper §2.2:
# 'Miners in the first layer also handle data ingestion and tokenization,
# while those in the final layer compute the training loss.')
# ---------------------------------------------------------------------------


def pipeline_loss_fused(params, batch, cfg: ModelConfig, spec: PipelineSpec,
                        mesh, batch_axes: tuple[str, ...] = ("data",),
                        z_loss: float = 1e-4, compute_dtype=jnp.bfloat16):
    """One shard_map for the whole step: tokens (tiny) replicate to stages

    instead of embedded activations; the loss is computed on the last stage
    and psum'd as a scalar.  §Perf cell C iteration 5: removes the
    537 MB x 2 x ticks GSPMD resharding permutes and the 4.5 GB output
    all-reduce of the v1 layout — inter-stage traffic is then just the
    (compressed) wire codes, i.e. the paper's §4 claim made visible on-mesh.
    """
    kind = blk.period_kinds(cfg)[0]
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    n_stages, n_micro = spec.n_stages, spec.n_microbatches
    assert B % n_micro == 0
    d_wire = spec.wire_width(cfg)
    Bm = B // n_micro
    tokens_m = tokens.reshape(n_micro, Bm, S)
    labels_m = labels.reshape(n_micro, Bm, S)
    positions = jnp.arange(S, dtype=jnp.int32)[None]

    def body(toks, labs, embed_tbl, unembed_tbl, final_gamma, stages):
        stages = jax.tree.map(lambda a: a[0], stages)
        B_loc = toks.shape[1]
        stage = jax.lax.axis_index("model")
        pos = jnp.broadcast_to(positions, (B_loc, S))
        last = n_stages - 1

        z0 = jnp.zeros((B_loc, S, d_wire), spec.wire_dtype)
        out0 = jnp.zeros((n_micro, B_loc, S, cfg.d_model), compute_dtype)

        # §Perf cell C iteration 7 (winner of 6/7/8 — see EXPERIMENTS.md):
        # the tick body is checkpointed, so the backward pipeline re-derives
        # each tick from its carry, whose activation part is the COMPRESSED
        # wire code z — the paper's 64x compression also shrinks the GPipe
        # activation stash.  The in-carry output collector is donated/
        # aliased in place by XLA (the ys-collection variants measured
        # strictly worse).
        def tick(carry, t):
            z, outputs = carry
            t_in = jax.lax.dynamic_index_in_dim(
                toks, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            # stage 0 ingests tokens (paper: first-layer miners tokenize);
            # the embedding gather is tiny next to a full-width activation
            x_in = jnp.take(embed_tbl, t_in, axis=0).astype(compute_dtype)
            if spec.compress:
                r = (z.astype(jnp.float32)
                     @ stages["w_up_prev"].astype(jnp.float32)
                     ).astype(compute_dtype)
                r = stages["alpha_dec"].astype(compute_dtype) * r
            else:
                r = z.astype(compute_dtype)
            x = jnp.where(stage == 0, x_in, r)
            x = _stage_forward(stages["blocks"], x, cfg, kind, pos, True)
            if spec.compress:
                xn = rmsnorm(x, stages["enc_norm"], cfg.norm_eps)
                z_out = (xn.astype(jnp.float32)
                         @ stages["w_down"].astype(jnp.float32)
                         ).astype(spec.wire_dtype)
            else:
                z_out = x.astype(spec.wire_dtype)
            out_idx = jnp.clip(t - last, 0, n_micro - 1)
            is_out = (stage == last) & (t >= last) & (t - last < n_micro)
            cur = jax.lax.dynamic_index_in_dim(outputs, out_idx, 0,
                                               keepdims=False)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(is_out, x, cur), out_idx, 0)
            z_next = jax.lax.ppermute(
                z_out, "model", [(i, i + 1) for i in range(n_stages - 1)])
            return (z_next, outputs), None

        tick = jax.checkpoint(tick,
                              policy=jax.checkpoint_policies.nothing_saveable)
        T = n_micro + n_stages - 1
        (_, outputs), _ = jax.lax.scan(tick, (z0, out0),
                                       jnp.arange(T, dtype=jnp.int32))

        # ---- loss head on the last stage, microbatched + remat ----
        pad_mask = (jnp.arange(unembed_tbl.shape[0]) >= cfg.vocab_size
                    ) * (-1e9)

        def head(y_mb, lab_mb):
            h = rmsnorm(y_mb, final_gamma, cfg.norm_eps)
            lgts = jnp.einsum("bsd,vd->bsv", h.astype(jnp.float32),
                              unembed_tbl.astype(jnp.float32)) + pad_mask
            return next_token_loss(lgts, lab_mb, z_loss)

        head = jax.checkpoint(head,
                              policy=jax.checkpoint_policies.nothing_saveable)

        def loss_body(acc, xs):
            y_mb, lab_mb = xs
            return acc + head(y_mb, lab_mb), None

        local_loss, _ = jax.lax.scan(loss_body, jnp.zeros((), jnp.float32),
                                     (outputs, labs))
        loss = jax.lax.psum(
            jnp.where(stage == last, local_loss, 0.0), "model") / n_micro
        return jax.lax.pmean(loss, batch_axes)

    stage_specs = jax.tree.map(lambda _: P("model"), params["stages"])
    unembed = params["embeds"].get("unembed", params["embeds"]["embed"])
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, batch_axes, None), P(None, batch_axes, None),
                  P(None, None), P(None, None), P(None), stage_specs),
        out_specs=P(),
        check_vma=False,
    )(tokens_m, labels_m, params["embeds"]["embed"], unembed,
      params["final_norm"], params["stages"])
