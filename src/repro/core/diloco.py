"""DiLoCo composition (paper §2.1): inner AdamW steps + outer Nesterov merge.

The paper couples its B_min/B_eff straggler policy with DiLoCo [6]: each
miner runs local optimizer steps independently; at a merge event qualifying
miners' *parameter deltas* are aggregated (here: via Butterfly All-Reduce)
and applied through an outer Nesterov-momentum step on the shared anchor.

Two consumers:
  * the decentralized runtime sim (host-side, numpy vectors via butterfly)
  * the on-mesh path: ``outer_merge_step`` syncs the ``pod`` axis every H
    inner steps — the paper's "full synchronization" mapped onto multi-pod
    DCN, compiled separately from the inner train_step in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.common import tree_axpy, tree_scale, tree_sub
from repro.core.butterfly import butterfly_all_reduce_mesh


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OuterState:
    anchor: Any            # params at last sync (the shared model)
    momentum: Any          # outer Nesterov momentum buffer
    outer_step: jax.Array


def outer_init(params) -> OuterState:
    return OuterState(
        anchor=jax.tree.map(jnp.asarray, params),
        momentum=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        outer_step=jnp.zeros((), jnp.int32),
    )


def outer_update(state: OuterState, avg_params, *, outer_lr: float = 0.7,
                 outer_momentum: float = 0.9, nesterov: bool = True
                 ) -> OuterState:
    """Nesterov outer step on the averaged worker parameters.

    outer_grad = anchor - avg(workers); anchor <- anchor - lr * step(grad).
    """
    delta = tree_sub(state.anchor, avg_params)           # outer "gradient"

    def upd(m, d, a):
        d = d.astype(jnp.float32)
        m_new = outer_momentum * m + d
        step = d + outer_momentum * m_new if nesterov else m_new
        return m_new, (a.astype(jnp.float32) - outer_lr * step).astype(a.dtype)

    flat = jax.tree.map(upd, state.momentum, delta, state.anchor)
    new_m = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_a = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return OuterState(new_a, new_m, state.outer_step + 1)


# ---------------------------------------------------------------------------
# On-mesh outer merge (pod axis)
# ---------------------------------------------------------------------------


def outer_merge_step(params, outer: OuterState, mesh, axis: str = "pod",
                     outer_lr: float = 0.7, outer_momentum: float = 0.9,
                     param_specs=None):
    """Butterfly-average the per-pod parameter replicas over ``axis``, then

    apply the Nesterov outer step, and return (synced params, new outer
    state, agreement).  Lowered+compiled separately in the dry-run: its
    collective bytes are the DCN cost of the paper's full-sync stage.

    ``param_specs`` (a PartitionSpec tree) keeps sharded leaves sharded
    inside the merge: each device butterfly-reduces only its LOCAL shard
    over ``axis`` — without it GSPMD all-gathers every leaf to every device
    first (measured 14.8 TB/device for kimi-k2's 1T params vs 58 GB with
    specs; EXPERIMENTS.md §Dry-run).
    """
    agrees = []
    from jax.sharding import PartitionSpec as P

    def merge_leaf(p, spec):
        merged, agree = butterfly_all_reduce_mesh(
            p.astype(jnp.float32), axis, mesh, in_spec=spec)
        agrees.append(agree)
        return merged

    if param_specs is None:
        param_specs = jax.tree.map(lambda _: P(), params)
    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_s = treedef.flatten_up_to(param_specs)
    avg = jax.tree_util.tree_unflatten(
        treedef, [merge_leaf(p, s) for p, s in zip(leaves_p, leaves_s)])
    new_outer = outer_update(outer, avg, outer_lr=outer_lr,
                             outer_momentum=outer_momentum)
    agreement = jnp.mean(jnp.stack(agrees)) if agrees else jnp.ones(())
    synced = jax.tree.map(lambda a, p: a.astype(p.dtype),
                          new_outer.anchor, params)
    return synced, new_outer, agreement


# ---------------------------------------------------------------------------
# Host-side helpers for the runtime simulation
# ---------------------------------------------------------------------------


def should_merge(batches_done: dict[int, int], b_min: int,
                 quorum_frac: float = 0.5) -> bool:
    """Paper §2.1: merge once >= quorum of miners completed B_min batches."""
    if not batches_done:
        return False
    qualifying = sum(1 for b in batches_done.values() if b >= b_min)
    return qualifying >= max(1, int(len(batches_done) * quorum_frac))


def effective_batch(batches_done: dict[int, int], b_min: int) -> int:
    """B_eff = sum of B_m over miners with B_m >= B_min (paper §2.1)."""
    return sum(b for b in batches_done.values() if b >= b_min)
