"""CLASP — Contribution Loss Assessment via Sampling of Pathways (paper §6,

App. B).  Samples are routed through one miner per layer along
orchestrator-chosen random pathways; the orchestrator records
D = {(pathway_k, loss_k)}.  Per-miner attribution is the Shapley-style
conditional mean  l̄_i = mean{loss_k : i in pathway_k};  outliers (malicious
or broken miners) are flagged by robust z-score.

This module is pure statistics + the toy generative model of App. B; the
runtime sim feeds it *real* losses from tiny models with injected corruption
(tests/test_clasp_integration.py), reproducing Fig 8 on live training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PathwayRecord:
    pathway: tuple[int, ...]      # miner uid per layer (one per layer)
    loss: float


@dataclasses.dataclass
class ClaspReport:
    mean_loss: np.ndarray         # (n_miners,) l̄_i  (nan if never sampled)
    counts: np.ndarray            # (n_miners,) |S_i|
    z_scores: np.ndarray          # robust z of l̄_i within each layer
    flagged: np.ndarray           # bool (n_miners,)
    layer_of: np.ndarray          # (n_miners,) layer index


def attribute(records: Sequence[PathwayRecord], n_miners: int,
              layer_of: Sequence[int], z_thresh: float = 6.0) -> ClaspReport:
    # NOTE: the default threshold is higher than the regression variant's:
    # with adversaries present, honest miners' conditional means inherit
    # co-occurrence noise (z up to ~4-5), while true adversaries land at
    # z > 20; attribute_regression controls for co-occurrence and keeps 3.0.
    """App. B: per-miner conditional mean loss + per-layer robust z-scores.

    z-scores are computed within each layer (miners in a layer see the same
    sample distribution), using median/MAD so that the malicious miners
    themselves do not drag the baseline (the paper's 'normalizing by the
    number of occurrences ... z-scores or similar').
    """
    layer_of = np.asarray(layer_of)
    sums = np.zeros(n_miners)
    counts = np.zeros(n_miners)
    for rec in records:
        for m in rec.pathway:
            sums[m] += rec.loss
            counts[m] += 1
    with np.errstate(invalid="ignore", divide="ignore"):
        mean = np.where(counts > 0, sums / np.maximum(counts, 1), np.nan)

    z = _layerwise_robust_z(mean, layer_of)
    flagged = z > z_thresh
    return ClaspReport(mean, counts, z, flagged, layer_of)


def _layerwise_robust_z(values: np.ndarray, layer_of: np.ndarray) -> np.ndarray:
    """Per-layer median-centred deviations with a scale POOLED across all

    miners: per-layer MAD over 5 miners is far too noisy (false flags), so
    the deviation scale is the global MAD of layer-centred residuals."""
    resid = np.zeros_like(values, dtype=float)
    for layer in np.unique(layer_of):
        idx = np.where(layer_of == layer)[0]
        vals = values[idx]
        ok = ~np.isnan(vals)
        if ok.sum() < 2:
            continue
        resid[idx] = np.where(ok, vals - np.median(vals[ok]), 0.0)
    ok_all = ~np.isnan(values)
    mad = np.median(np.abs(resid[ok_all])) * 1.4826
    scale = mad if mad > 1e-12 else (np.std(resid[ok_all]) + 1e-12)
    return np.where(ok_all, resid / scale, 0.0)


def attribute_regression(records: Sequence[PathwayRecord], n_miners: int,
                         layer_of: Sequence[int], z_thresh: float = 3.0,
                         ridge: float = 1e-3) -> ClaspReport:
    """Paper §6: 'treating each miner as if it were a feature in a dataset'.

    Least-squares regression loss_k ~ mu + sum_i beta_i * 1[i in pi_k]
    isolates each miner's *marginal* loss contribution, controlling for
    co-occurring bad actors — sharper than the conditional mean when
    multiple adversaries (or few samples) make pathway composition
    correlated.  beta_i replaces l̄_i in the report; z-scores as before.
    """
    layer_of = np.asarray(layer_of)
    T = len(records)
    X = np.zeros((T, n_miners + 1), np.float64)
    y = np.empty(T, np.float64)
    for k, rec in enumerate(records):
        X[k, 0] = 1.0
        for m in rec.pathway:
            X[k, 1 + m] = 1.0
        y[k] = rec.loss
    counts = X[:, 1:].sum(axis=0)
    reg = ridge * np.eye(n_miners + 1)
    beta = np.linalg.solve(X.T @ X + reg, X.T @ y)
    contrib = np.where(counts > 0, beta[1:], np.nan)

    z = _layerwise_robust_z(contrib, layer_of)
    return ClaspReport(contrib, counts, z, z > z_thresh, layer_of)


# ---------------------------------------------------------------------------
# Pathway sampling (orchestrator side)
# ---------------------------------------------------------------------------


def sample_pathways(rng: np.random.RandomState, miners_per_layer: Sequence[Sequence[int]],
                    n_samples: int) -> list[tuple[int, ...]]:
    """Uniform random routes, one miner per layer (paper App. B item 2)."""
    out = []
    for _ in range(n_samples):
        out.append(tuple(int(rng.choice(layer)) for layer in miners_per_layer))
    return out


# ---------------------------------------------------------------------------
# Toy generative model (paper App. B / Fig 8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ToyConfig:
    n_layers: int = 5
    miners_per_layer: int = 5
    base_loss: float = 4.5
    base_std: float = 0.2
    malicious_inflation: float = 0.10   # +10% loss and std per bad miner hit
    n_samples: int = 5000
    seed: int = 0


def toy_simulation(cfg: ToyConfig, malicious: Sequence[int]
                   ) -> tuple[list[PathwayRecord], np.ndarray]:
    """Generate (records, layer_of) under the paper's toy model: loss ~

    N(4.5, 0.2); a malicious miner on the path inflates mean and std 10%."""
    rng = np.random.RandomState(cfg.seed)
    n_miners = cfg.n_layers * cfg.miners_per_layer
    layer_of = np.repeat(np.arange(cfg.n_layers), cfg.miners_per_layer)
    layers = [list(range(l * cfg.miners_per_layer, (l + 1) * cfg.miners_per_layer))
              for l in range(cfg.n_layers)]
    bad = set(malicious)
    records = []
    for path in sample_pathways(rng, layers, cfg.n_samples):
        n_bad = sum(1 for m in path if m in bad)
        mu = cfg.base_loss * (1 + cfg.malicious_inflation) ** n_bad
        sd = cfg.base_std * (1 + cfg.malicious_inflation) ** n_bad
        records.append(PathwayRecord(path, float(rng.normal(mu, sd))))
    return records, layer_of


def fair_miner_suppression(report: ClaspReport, malicious: Sequence[int]) -> float:
    """Fig 8b's 'intrinsic balancing': fair miners sharing a layer with bad

    actors show *reduced* contribution (they are sampled into fewer bad
    paths than the bad miner, so their conditional mean sits below the
    overall mean).  Returns mean(l̄ fair-in-bad-layer) - mean(l̄ fair-in-clean
    -layer); negative = suppression observed."""
    bad = set(malicious)
    bad_layers = {report.layer_of[m] for m in bad}
    fair = [m for m in range(len(report.mean_loss)) if m not in bad]
    in_bad = [report.mean_loss[m] for m in fair if report.layer_of[m] in bad_layers]
    in_clean = [report.mean_loss[m] for m in fair
                if report.layer_of[m] not in bad_layers]
    if not in_bad or not in_clean:
        return 0.0
    return float(np.nanmean(in_bad) - np.nanmean(in_clean))
