"""Incentivization (paper §3 + Appendix A).

Scores: a miner earns S_m^n = number of backward passes validated in epoch n.
Each score carries a step-function time decay w(t) = 1[t <= gamma]; the raw
incentive is I_m = sum_n S_m^n * w(t - t_n).  Emissions per interval are
distributed proportionally to I_m.

Appendix A: the number of live scores per miner is N_scores = gamma / T_s
(T_s = full-sync interval).  Incentive *stability* falls as N_scores shrinks
— ``stability_simulation`` reproduces Fig 9's (monitoring time x decay)
sweep by simulating score arrival/expiry and measuring the coefficient of
variation of each miner's emission share.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class ScoreEntry:
    miner: int
    epoch: int
    score: float            # S_m^n: validated backward passes
    t_assigned: float


class IncentiveLedger:
    """Append-only score ledger with step-function decay (paper §3)."""

    def __init__(self, gamma: float):
        self.gamma = float(gamma)
        self.entries: list[ScoreEntry] = []

    def record(self, miner: int, epoch: int, score: float, t: float) -> None:
        assert score >= 0
        self.entries.append(ScoreEntry(miner, epoch, float(score), float(t)))

    def weight(self, entry: ScoreEntry, t_now: float) -> float:
        """w(t): 1 while the score is younger than gamma, else 0."""
        return 1.0 if (t_now - entry.t_assigned) <= self.gamma else 0.0

    def raw_incentive(self, miner: int, t_now: float) -> float:
        return sum(e.score * self.weight(e, t_now)
                   for e in self.entries if e.miner == miner)

    def emissions(self, t_now: float, total_emission: float = 1.0,
                  miners: Optional[list[int]] = None) -> dict[int, float]:
        miners = miners if miners is not None else sorted(
            {e.miner for e in self.entries})
        raw = np.array([self.raw_incentive(m, t_now) for m in miners])
        total = raw.sum()
        if total <= 0:
            share = np.full(len(miners), 1.0 / max(len(miners), 1))
        else:
            share = raw / total
        return {m: float(s * total_emission) for m, s in zip(miners, share)}

    def prune(self, t_now: float) -> None:
        self.entries = [e for e in self.entries
                        if (t_now - e.t_assigned) <= self.gamma]


def expected_live_scores(gamma: float, sync_interval: float) -> float:
    """Appendix A: N_scores = gamma / T_s."""
    return gamma / sync_interval


# ---------------------------------------------------------------------------
# Fig 9: incentive stability vs (monitoring time, decay period)
# ---------------------------------------------------------------------------


def stability_simulation(
    sync_interval_hours: float,
    gamma_hours: float,
    n_miners: int = 32,
    horizon_hours: float = 100.0,
    score_cv: float = 0.3,
    validated_fraction: float = 1.0,
    seed: int = 0,
) -> dict:
    """Simulate epochs of score assignment + expiry; return the mean

    coefficient-of-variation of per-miner emission share over time (low CV
    = stable incentives).  Scores per epoch are noisy (hardware heterogeneity)
    and each miner is only validated with probability ``validated_fraction``
    per epoch (validator coverage)."""
    rng = np.random.RandomState(seed)
    ledger = IncentiveLedger(gamma_hours)
    n_epochs = int(horizon_hours / sync_interval_hours)
    base_rate = rng.lognormal(0.0, 0.25, n_miners)      # heterogeneous hw
    shares = []
    for ep in range(n_epochs):
        t = ep * sync_interval_hours
        for m in range(n_miners):
            if rng.rand() > validated_fraction:
                continue                                 # not monitored
            score = max(rng.normal(base_rate[m], score_cv * base_rate[m]), 0.0)
            ledger.record(m, ep, score, t)
        ledger.prune(t)
        em = ledger.emissions(t, miners=list(range(n_miners)))
        shares.append([em[m] for m in range(n_miners)])
    shares = np.asarray(shares[max(1, int(gamma_hours / sync_interval_hours)):])
    if shares.size == 0:
        return {"cv": np.inf, "n_scores": expected_live_scores(
            gamma_hours, sync_interval_hours)}
    mean = shares.mean(axis=0)
    std = shares.std(axis=0)
    cv = float(np.mean(std / np.maximum(mean, 1e-12)))
    return {
        "cv": cv,
        "n_scores": expected_live_scores(gamma_hours, sync_interval_hours),
        "mean_share": mean.tolist(),
    }
