"""Butterfly All-Reduce (paper §5): pair-indexed shards, 2x redundancy,

O(1) per-miner bandwidth, agreement-matrix verification, graceful failures.

Construction (§5, eqs. 1-2): for N miners on one layer, enumerate all
P = {(i,j) : i<j} pairs (|P| = N(N-1)/2), apply a seeded random bijection
f : P -> {0..|P|-1}; shard s of the flattened parameter space is *assigned*
to the two miners of pair f^-1(s).  Each assignee downloads shard s from all
N miners, averages, re-uploads.  Every shard therefore has exactly two
independent reducers:

* agreement: the two copies are compared (cosine similarity) — a deceptive
  reducer is exposed by every partner it shares a shard with (Fig 7a);
* fault tolerance: a shard is lost only if BOTH assignees fail, so
  |P_valid| = C(N,2) - C(k,2) with k faulty miners (Fig 7b);
* bandwidth: per miner = upload W + download 2W + upload 2W/N + download W
  = 4W + 2W/N — O(1) in N (§5.3), vs N*W for a central merger.

Three implementations share the math:
  * ``ButterflyPlan`` + ``reduce_shards`` — the reduce run centrally over
    in-memory vectors: the *golden oracle* the store-and-forward path must
    reproduce to float equality.
  * ``ButterflyExecutor`` — the reduce as per-miner store-and-forward
    actions over a ``Transport``: every shard upload, reduce download and
    reduced-copy re-upload crosses the wire under the acting miner's link,
    so ``SimulatedNetworkTransport`` byte accounting reproduces the §5.3
    closed form 4W + 2W/N, and validators can audit the reduce from store
    artifacts alone (``store_agreement``).  Needs KeySchema v2.
  * ``butterfly_all_reduce_mesh`` — the on-mesh equivalent for TPU pods:
    redundancy-2 reduce-scatter (+shifted copy) + agreement compare +
    all-gather, expressed in shard_map collectives.  Used by the DiLoCo
    outer merge on the ``pod``/``data`` axis.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import cdiv, shard_map_unchecked
from repro.core import compression
from repro.kernels import ops

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


# ---------------------------------------------------------------------------
# Plan construction (paper eqs. 1-2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ButterflyPlan:
    n_miners: int
    pairs: tuple[tuple[int, int], ...]      # shard s -> (miner_i, miner_j)
    vector_len: int
    # shard boundaries snap to multiples of ``align`` (except the vector
    # end).  Sharded sync sets align to the wire codec's quantization block
    # so per-shard int8 codes are bit-identical to slices of the full
    # vector's codes — the dense-vs-sharded parity contract.
    align: int = 1

    @property
    def n_shards(self) -> int:
        return len(self.pairs)

    def shard_bounds(self, s: int) -> tuple[int, int]:
        """Near-equal contiguous slices of the flattened parameter vector;
        with ``align > 1``, near-equal in whole blocks (trailing shards may
        be empty when the vector has fewer blocks than shards)."""
        if self.align == 1:
            base = self.vector_len // self.n_shards
            extra = self.vector_len % self.n_shards
            lo = s * base + min(s, extra)
            hi = lo + base + (1 if s < extra else 0)
            return lo, hi
        blocks = cdiv(self.vector_len, self.align)
        base = blocks // self.n_shards
        extra = blocks % self.n_shards
        blo = s * base + min(s, extra)
        bhi = blo + base + (1 if s < extra else 0)
        return (min(blo * self.align, self.vector_len),
                min(bhi * self.align, self.vector_len))

    def shards_of(self, miner: int) -> list[int]:
        """Shard indices assigned to ``miner`` (one per partner: N-1 shards)."""
        return [s for s, (i, j) in enumerate(self.pairs) if miner in (i, j)]


def make_plan(n_miners: int, vector_len: int, seed: int = 0,
              align: int = 1) -> ButterflyPlan:
    assert n_miners >= 2
    pairs = list(itertools.combinations(range(n_miners), 2))
    rng = np.random.RandomState(seed)
    rng.shuffle(pairs)                       # the random bijection f
    return ButterflyPlan(n_miners, tuple(tuple(p) for p in pairs),
                         vector_len, align)


# ---------------------------------------------------------------------------
# Fault / bandwidth math (paper §5.2-5.3)
# ---------------------------------------------------------------------------


def valid_shard_fraction(n: int, k: int) -> float:
    """p_valid = 1 - k(k-1) / (N(N-1)) — fraction of shards still reduced

    correctly with k faulty miners (Fig 7b)."""
    if n < 2:
        return 0.0
    return 1.0 - (k * (k - 1)) / (n * (n - 1))


def transfer_volume(n_miners: int, w_bytes: float) -> dict:
    """Per-miner and total traffic; the paper's 4W + 2W/N vs central N*W."""
    per_miner = 4 * w_bytes + 2 * w_bytes / n_miners
    return {
        "per_miner_bytes": per_miner,
        "total_bytes": per_miner * n_miners,
        "central_merger_bytes": n_miners * w_bytes + 3,   # paper's comparison
        "n_miners": n_miners,
    }


# ---------------------------------------------------------------------------
# Exact simulation (runtime path)
# ---------------------------------------------------------------------------


def reduce_shards(
    plan: ButterflyPlan,
    uploads: dict[int, np.ndarray],          # miner -> full flattened vector
    reducer_ok: Optional[Sequence[bool]] = None,   # reducer miner alive?
    tamper: Optional[dict[int, float]] = None,     # miner -> additive noise
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Run the full butterfly reduce.

    Returns (merged vector, shard_valid (n_shards,), agreement (n_shards, 2)
    reducer ids with per-shard copy agreement encoded via ``shard_agree``).
    Uses ``kernels.ops.shard_merge`` (masked mean) for each shard reduction.
    """
    n = plan.n_miners
    reducer_ok = list(reducer_ok) if reducer_ok is not None else [True] * n
    tamper = tamper or {}
    present = sorted(uploads.keys())
    merged = np.zeros(plan.vector_len, np.float32)
    shard_valid = np.zeros(plan.n_shards, bool)
    shard_agree = np.ones(plan.n_shards, bool)

    # stack uploads once; missing miners -> masked out
    stacked = np.stack([
        np.asarray(uploads[m], np.float32) if m in uploads
        else np.zeros(plan.vector_len, np.float32)
        for m in range(n)])
    valid_mask = np.array([m in uploads for m in range(n)])

    for s, (i, j) in enumerate(plan.pairs):
        lo, hi = plan.shard_bounds(s)
        if hi == lo:
            shard_valid[s] = True
            continue
        copies = []
        for reducer in (i, j):
            if not reducer_ok[reducer]:
                continue
            block = jnp.asarray(stacked[:, lo:hi])
            mean = np.asarray(ops.shard_merge(block, jnp.asarray(valid_mask)))
            if reducer in tamper:
                mean = mean + tamper[reducer]
            copies.append((reducer, mean))
        if not copies:
            shard_valid[s] = False          # both assignees down: shard lost
            continue
        shard_valid[s] = True
        if len(copies) == 2:
            a, b = copies[0][1], copies[1][1]
            shard_agree[s] = bool(np.allclose(a, b, rtol=1e-4, atol=1e-5))
        merged[lo:hi] = copies[0][1]        # first surviving copy wins
    return merged, shard_valid, shard_agree


def agreement_matrix(
    plan: ButterflyPlan,
    reduced_copies: dict[tuple[int, int], np.ndarray],   # (shard, reducer) -> copy
) -> np.ndarray:
    """(N, N) matrix: fraction of shared shards on which each miner pair's

    reduced copies agree (Fig 7a; off-consensus rows expose deceivers)."""
    n = plan.n_miners
    agree = np.full((n, n), np.nan)
    for s, (i, j) in enumerate(plan.pairs):
        a = reduced_copies.get((s, i))
        b = reduced_copies.get((s, j))
        if a is None or b is None:
            continue
        ok = float(np.allclose(a, b, rtol=1e-4, atol=1e-5))
        agree[i, j] = agree[j, i] = ok
    np.fill_diagonal(agree, 1.0)
    return agree


def reduce_with_copies(
    plan: ButterflyPlan,
    uploads: dict[int, np.ndarray],
    tamper: Optional[dict[int, float]] = None,
) -> dict[tuple[int, int], np.ndarray]:
    """Each reducer's copy of each assigned shard (input to agreement_matrix)."""
    n = plan.n_miners
    tamper = tamper or {}
    stacked = np.stack([
        np.asarray(uploads[m], np.float32) if m in uploads
        else np.zeros(plan.vector_len, np.float32) for m in range(n)])
    valid_mask = jnp.asarray(np.array([m in uploads for m in range(n)]))
    out = {}
    for s, (i, j) in enumerate(plan.pairs):
        lo, hi = plan.shard_bounds(s)
        block = jnp.asarray(stacked[:, lo:hi])
        base = np.asarray(ops.shard_merge(block, valid_mask))
        for reducer in (i, j):
            copy = base + tamper.get(reducer, 0.0)
            out[(s, reducer)] = copy
    return out


# ---------------------------------------------------------------------------
# Store-and-forward execution over a Transport (KeySchema v2, §5.1-5.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    """One unit of reducer work: download every miner's copy of ``shard``,
    masked-merge, re-upload the reduced copy."""
    shard: int
    lo: int
    hi: int
    upload_keys: tuple[str, ...]     # plan order: one key per plan index
    reduced_key: str
    reducer_uid: int


class ButterflyExecutor:
    """Drives the butterfly reduce as store-and-forward actions over a
    ``Transport`` — nothing is merged centrally.

    Three steps, each charged to the acting peer's link so the §5.3
    closed form falls out of the byte accounting:

      1. ``upload_vector``   each miner splits its flat weight vector on
                             the plan's shard bounds and uploads every
                             shard (``W`` up per miner),
      2. ``reduce_one``      each reducer downloads all N copies of an
                             assigned shard (``2W`` down across its N-1
                             shards), masked-merges them with the
                             ``kernels.ops.shard_merge`` dispatch, and
                             re-uploads its reduced copy (``2W/N`` up),
      3. ``collect``         the anchor assembly reads the redundant
                             reduced copies back (first surviving copy per
                             shard wins, exactly like ``reduce_shards``).

    Shard uploads ride ``codec`` (the sharing stage's wire codec, int8 by
    default).  Reduced copies always ride fp32: they are the consensus
    artifact the anchor is assembled from, quantizing them a second time
    would compound the codec error, and they are only ``2W/N`` of traffic.
    With ``plan.align`` set to the codec's quantization block, per-shard
    codes are bit-identical to slices of a whole-vector encode, so the
    assembled anchor equals the dense oracle's to float equality.

    The transport's schema must be KeySchema v2 (minting a shard key from
    a v1 schema raises).
    """

    def __init__(self, plan: ButterflyPlan, transport, *, epoch: int,
                 stage: int, uids: Sequence[int], codec: str = "none"):
        assert len(uids) == plan.n_miners, (len(uids), plan.n_miners)
        self.plan = plan
        self.transport = transport
        self.epoch = epoch
        self.stage = stage
        self.uids = tuple(uids)              # plan index -> real miner uid
        self.codec = codec
        # agreement matrix of the last collect() (plan-index-indexed) —
        # collect computes it for consensus weighting; callers reuse it
        # instead of re-comparing every copy
        self.last_agreement: Optional[np.ndarray] = None

    # -- key minting (the only schema touchpoints) -----------------------

    def upload_key(self, idx: int, shard: int) -> str:
        return self.transport.schema.shard_upload(
            self.epoch, self.stage, self.uids[idx], shard)

    def reduced_key(self, shard: int, idx: int) -> str:
        return self.transport.schema.shard_reduced(
            self.epoch, self.stage, shard, self.uids[idx])

    # -- step 1: sharded upload (actor = the uploading miner) ------------

    def upload_vector(self, idx: int, vector: np.ndarray,
                      actor: str) -> list[str]:
        """Publish miner ``idx``'s flat weight vector as per-shard payloads
        (empty shards are skipped); returns the minted keys."""
        from repro.api.messages import ShardUploadMsg
        vec = jnp.asarray(vector, jnp.float32)
        assert vec.shape[0] == self.plan.vector_len, \
            (vec.shape, self.plan.vector_len)
        keys = []
        for s in range(self.plan.n_shards):
            lo, hi = self.plan.shard_bounds(s)
            if hi == lo:
                continue
            msg = ShardUploadMsg(self.epoch, self.stage, self.uids[idx], s,
                                 codec=self.codec)
            payload = compression.encode(vec[lo:hi], self.codec)
            self.transport.publish(msg, payload, actor=actor)
            keys.append(msg.key(self.transport.schema))
        return keys

    # -- step 2: reduce (actor = the assigned reducer) -------------------

    def assignments_for(self, idx: int) -> list[ShardAssignment]:
        """The N-1 shard reductions the plan assigns to miner ``idx``."""
        out = []
        for s in self.plan.shards_of(idx):
            lo, hi = self.plan.shard_bounds(s)
            if hi == lo:
                continue
            out.append(ShardAssignment(
                s, lo, hi,
                tuple(self.upload_key(i, s)
                      for i in range(self.plan.n_miners)),
                self.reduced_key(s, idx),
                self.uids[idx]))
        return out

    def reduce_one(self, assignment: ShardAssignment, actor: str,
                   tamper: float = 0.0) -> np.ndarray:
        """Download every miner's copy of one shard, masked-merge, upload
        the reduced copy.  ``tamper`` is the fault-injection hook: a
        deceptive reducer adds a constant offset after the merge (same
        semantics as ``reduce_with_copies``)."""
        from repro.api.messages import ShardReducedMsg
        n = self.plan.n_miners
        width = assignment.hi - assignment.lo
        blocks = np.zeros((n, width), np.float32)
        valid = np.zeros((n,), bool)
        for i, key in enumerate(assignment.upload_keys):
            if not self.transport.exists(key):
                continue                     # miner never uploaded: mask out
            payload = self.transport.get(key, actor=actor)
            blocks[i] = np.asarray(compression.decode(payload, width))
            valid[i] = True
        mean = np.asarray(ops.shard_merge(jnp.asarray(blocks),
                                          jnp.asarray(valid)))
        if tamper:
            mean = mean + np.float32(tamper)
        msg = ShardReducedMsg(self.epoch, self.stage, assignment.shard,
                              assignment.reducer_uid)
        self.transport.publish(msg, compression.encode(mean, "none"),
                               actor=actor)
        return mean

    def run_reducer(self, idx: int, actor: str,
                    tamper: float = 0.0) -> list[ShardAssignment]:
        """All of miner ``idx``'s reduce work; returns what was done (the
        runtime miner logs it for validator replay)."""
        done = []
        for a in self.assignments_for(idx):
            self.reduce_one(a, actor=actor, tamper=tamper)
            done.append(a)
        return done

    # -- step 3: anchor assembly from the redundant copies ---------------

    def collect(self, actor: str = "orchestrator") -> tuple[
            np.ndarray, np.ndarray, dict[tuple[int, int], np.ndarray]]:
        """Assemble the merged vector from the store's reduced copies.

        Returns (merged, shard_valid, copies) with ``copies`` keyed by
        (shard, plan index) — the same structure ``reduce_with_copies``
        returns, so ``agreement_matrix`` applies unchanged.  A shard is
        lost only when *neither* assignee uploaded a copy (Fig 7b).

        Copy selection is consensus-weighted: honest reducers of a shard
        produce bit-identical copies (same store inputs, same merge), so
        when the two copies *disagree* the assembly prefers the copy from
        the reducer with the higher mean agreement across all its shards —
        a single tamperer (out of consensus with every partner, Fig 7a)
        cannot poison the anchor as long as its partner is honest.  Only a
        shard whose *both* assignees are dishonest, or whose only
        surviving copy is tampered, degrades."""
        copies: dict[tuple[int, int], np.ndarray] = {}
        for s, (i, j) in enumerate(self.plan.pairs):
            lo, hi = self.plan.shard_bounds(s)
            if hi == lo:
                continue
            for r in (i, j):
                key = self.reduced_key(s, r)
                if not self.transport.exists(key):
                    continue
                payload = self.transport.get(key, actor=actor)
                copies[(s, r)] = np.asarray(
                    compression.decode(payload, hi - lo))
        # per-reducer consensus: mean agreement over pairs with both copies
        agree = agreement_matrix(self.plan, copies)
        self.last_agreement = agree
        n = self.plan.n_miners
        consensus = np.array([
            np.nanmean(agree[m][np.arange(n) != m])
            if np.any(~np.isnan(agree[m][np.arange(n) != m])) else 1.0
            for m in range(n)])
        merged = np.zeros(self.plan.vector_len, np.float32)
        shard_valid = np.zeros(self.plan.n_shards, bool)
        for s, (i, j) in enumerate(self.plan.pairs):
            lo, hi = self.plan.shard_bounds(s)
            if hi == lo:
                shard_valid[s] = True
                continue
            present = [r for r in (i, j) if (s, r) in copies]
            if not present:
                continue                     # both assignees down: lost
            best = max(present, key=lambda r: (consensus[r], -r))
            merged[lo:hi] = copies[(s, best)]
            shard_valid[s] = True
        return merged, shard_valid, copies


def store_agreement(transport, epoch: int, stage: int,
                    actor: str = "?") -> tuple[list[int], np.ndarray]:
    """Rebuild the Fig 7a agreement evidence purely from wire artifacts.

    Walks the store's ``weights/ep{E}/s{S}`` prefix for ``shard_reduced``
    keys, pairs up each shard's two redundant copies and compares them —
    no plan, miner state or uploader cooperation needed: shard identity and
    the reducer uids are in the keys themselves.  Returns (uids, matrix)
    with the matrix indexed by position in the sorted uid list; a tampering
    reducer shows a ~0 row against every partner."""
    schema = transport.schema
    by_shard: dict[int, list[tuple[int, str]]] = {}
    for key in transport.keys(schema.stage_weights_prefix(epoch, stage)):
        try:
            parsed = schema.parse(key)
        except ValueError:
            continue                         # foreign key kinds: not ours
        if parsed.kind != "shard_reduced":
            continue
        # the walk is a plain string-prefix match, so stage 1's prefix
        # also catches stage 12/13/... keys — filter on the parsed fields
        if (parsed.fields["epoch"] != epoch
                or parsed.fields["stage"] != stage):
            continue
        by_shard.setdefault(parsed.fields["shard"], []).append(
            (parsed.fields["reducer"], key))
    uids = sorted({uid for entries in by_shard.values()
                   for uid, _ in entries})
    pos = {u: i for i, u in enumerate(uids)}
    agree = np.full((len(uids), len(uids)), np.nan)
    for entries in by_shard.values():
        if len(entries) != 2:
            continue                         # copy lost: nothing to compare
        (ua, ka), (ub, kb) = sorted(entries)
        a = np.asarray(compression.decode(transport.get(ka, actor=actor)))
        b = np.asarray(compression.decode(transport.get(kb, actor=actor)))
        ok = float(np.allclose(a, b, rtol=1e-4, atol=1e-5))
        agree[pos[ua], pos[ub]] = agree[pos[ub], pos[ua]] = ok
    if len(uids):
        np.fill_diagonal(agree, 1.0)
    return uids, agree


# ---------------------------------------------------------------------------
# On-mesh butterfly (TPU pods): redundancy-2 reduce-scatter + all-gather
# ---------------------------------------------------------------------------


def butterfly_all_reduce_mesh(x: jax.Array, axis: str, mesh,
                              in_spec=None, redundancy: int = 2):
    """Mean-all-reduce of ``x`` along mesh axis ``axis`` with butterfly-style

    redundancy: two independent reduce-scatters over shifted shard
    assignments produce two copies of every shard on different devices; the
    copies are cross-checked (ppermute + compare) before the all-gather.
    Returns (reduced x, agreement fraction scalar).

    Bandwidth per device: 2 * (W/N reduce-scatter) + W all-gather + W/N
    permute ≈ the paper's 4W + 2W/N counted one-sided on uploads+downloads.
    """
    n = mesh.shape[axis]
    in_spec = in_spec if in_spec is not None else jax.sharding.PartitionSpec()
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def body(v):
        size = v.size
        flat = v.reshape(-1)
        pad = (-size) % n
        flat = jnp.pad(flat, (0, pad))
        shard_len = flat.shape[0] // n
        # copy A: canonical assignment (device d reduces shard d)
        copy_a = jax.lax.psum_scatter(flat, axis, scatter_dimension=0,
                                      tiled=True) / n
        # copy B: shifted assignment (device d reduces shard d+1 mod n)
        copy_b = jax.lax.psum_scatter(jnp.roll(flat, -shard_len), axis,
                                      scatter_dimension=0, tiled=True) / n
        # align copy B onto shard d's canonical reducer and cross-check:
        # device d-1 holds shard d in copy_b -> send i -> i+1
        perm = [(i, (i + 1) % n) for i in range(n)]
        copy_b_aligned = jax.lax.ppermute(copy_b, axis, perm)
        agree = jnp.mean((jnp.abs(copy_a - copy_b_aligned)
                          <= 1e-3 * (jnp.abs(copy_a) + 1e-6)).astype(jnp.float32))
        agree = jax.lax.pmean(agree, axis)
        merged = jax.lax.all_gather(copy_a, axis, axis=0, tiled=True)
        merged = merged[:size].reshape(v.shape)
        return merged, agree

    return shard_map_unchecked(
        body, mesh, (in_spec,),
        (in_spec, jax.sharding.PartitionSpec()),
    )(x)
