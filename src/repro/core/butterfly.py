"""Butterfly All-Reduce (paper §5): pair-indexed shards, 2x redundancy,

O(1) per-miner bandwidth, agreement-matrix verification, graceful failures.

Construction (§5, eqs. 1-2): for N miners on one layer, enumerate all
P = {(i,j) : i<j} pairs (|P| = N(N-1)/2), apply a seeded random bijection
f : P -> {0..|P|-1}; shard s of the flattened parameter space is *assigned*
to the two miners of pair f^-1(s).  Each assignee downloads shard s from all
N miners, averages, re-uploads.  Every shard therefore has exactly two
independent reducers:

* agreement: the two copies are compared (cosine similarity) — a deceptive
  reducer is exposed by every partner it shares a shard with (Fig 7a);
* fault tolerance: a shard is lost only if BOTH assignees fail, so
  |P_valid| = C(N,2) - C(k,2) with k faulty miners (Fig 7b);
* bandwidth: per miner = upload W + download 2W + upload 2W/N + download W
  = 4W + 2W/N — O(1) in N (§5.3), vs N*W for a central merger.

Two implementations share the math:
  * ``ButterflyPlan`` + ``simulate_reduce`` — the exact store-and-forward
    algorithm over a state-store, used by the decentralized runtime sim.
  * ``butterfly_all_reduce_mesh`` — the on-mesh equivalent for TPU pods:
    redundancy-2 reduce-scatter (+shifted copy) + agreement compare +
    all-gather, expressed in shard_map collectives.  Used by the DiLoCo
    outer merge on the ``pod``/``data`` axis.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import cdiv, shard_map_unchecked
from repro.kernels import ops

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


# ---------------------------------------------------------------------------
# Plan construction (paper eqs. 1-2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ButterflyPlan:
    n_miners: int
    pairs: tuple[tuple[int, int], ...]      # shard s -> (miner_i, miner_j)
    vector_len: int

    @property
    def n_shards(self) -> int:
        return len(self.pairs)

    def shard_bounds(self, s: int) -> tuple[int, int]:
        """Near-equal contiguous slices of the flattened parameter vector."""
        base = self.vector_len // self.n_shards
        extra = self.vector_len % self.n_shards
        lo = s * base + min(s, extra)
        hi = lo + base + (1 if s < extra else 0)
        return lo, hi

    def shards_of(self, miner: int) -> list[int]:
        """Shard indices assigned to ``miner`` (one per partner: N-1 shards)."""
        return [s for s, (i, j) in enumerate(self.pairs) if miner in (i, j)]


def make_plan(n_miners: int, vector_len: int, seed: int = 0) -> ButterflyPlan:
    assert n_miners >= 2
    pairs = list(itertools.combinations(range(n_miners), 2))
    rng = np.random.RandomState(seed)
    rng.shuffle(pairs)                       # the random bijection f
    return ButterflyPlan(n_miners, tuple(tuple(p) for p in pairs), vector_len)


# ---------------------------------------------------------------------------
# Fault / bandwidth math (paper §5.2-5.3)
# ---------------------------------------------------------------------------


def valid_shard_fraction(n: int, k: int) -> float:
    """p_valid = 1 - k(k-1) / (N(N-1)) — fraction of shards still reduced

    correctly with k faulty miners (Fig 7b)."""
    if n < 2:
        return 0.0
    return 1.0 - (k * (k - 1)) / (n * (n - 1))


def transfer_volume(n_miners: int, w_bytes: float) -> dict:
    """Per-miner and total traffic; the paper's 4W + 2W/N vs central N*W."""
    per_miner = 4 * w_bytes + 2 * w_bytes / n_miners
    return {
        "per_miner_bytes": per_miner,
        "total_bytes": per_miner * n_miners,
        "central_merger_bytes": n_miners * w_bytes + 3,   # paper's comparison
        "n_miners": n_miners,
    }


# ---------------------------------------------------------------------------
# Exact simulation (runtime path)
# ---------------------------------------------------------------------------


def reduce_shards(
    plan: ButterflyPlan,
    uploads: dict[int, np.ndarray],          # miner -> full flattened vector
    reducer_ok: Optional[Sequence[bool]] = None,   # reducer miner alive?
    tamper: Optional[dict[int, float]] = None,     # miner -> additive noise
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Run the full butterfly reduce.

    Returns (merged vector, shard_valid (n_shards,), agreement (n_shards, 2)
    reducer ids with per-shard copy agreement encoded via ``shard_agree``).
    Uses ``kernels.ops.shard_merge`` (masked mean) for each shard reduction.
    """
    n = plan.n_miners
    reducer_ok = list(reducer_ok) if reducer_ok is not None else [True] * n
    tamper = tamper or {}
    present = sorted(uploads.keys())
    merged = np.zeros(plan.vector_len, np.float32)
    shard_valid = np.zeros(plan.n_shards, bool)
    shard_agree = np.ones(plan.n_shards, bool)

    # stack uploads once; missing miners -> masked out
    stacked = np.stack([
        np.asarray(uploads[m], np.float32) if m in uploads
        else np.zeros(plan.vector_len, np.float32)
        for m in range(n)])
    valid_mask = np.array([m in uploads for m in range(n)])

    for s, (i, j) in enumerate(plan.pairs):
        lo, hi = plan.shard_bounds(s)
        if hi == lo:
            shard_valid[s] = True
            continue
        copies = []
        for reducer in (i, j):
            if not reducer_ok[reducer]:
                continue
            block = jnp.asarray(stacked[:, lo:hi])
            mean = np.asarray(ops.shard_merge(block, jnp.asarray(valid_mask)))
            if reducer in tamper:
                mean = mean + tamper[reducer]
            copies.append((reducer, mean))
        if not copies:
            shard_valid[s] = False          # both assignees down: shard lost
            continue
        shard_valid[s] = True
        if len(copies) == 2:
            a, b = copies[0][1], copies[1][1]
            shard_agree[s] = bool(np.allclose(a, b, rtol=1e-4, atol=1e-5))
        merged[lo:hi] = copies[0][1]        # first surviving copy wins
    return merged, shard_valid, shard_agree


def agreement_matrix(
    plan: ButterflyPlan,
    reduced_copies: dict[tuple[int, int], np.ndarray],   # (shard, reducer) -> copy
) -> np.ndarray:
    """(N, N) matrix: fraction of shared shards on which each miner pair's

    reduced copies agree (Fig 7a; off-consensus rows expose deceivers)."""
    n = plan.n_miners
    agree = np.full((n, n), np.nan)
    for s, (i, j) in enumerate(plan.pairs):
        a = reduced_copies.get((s, i))
        b = reduced_copies.get((s, j))
        if a is None or b is None:
            continue
        ok = float(np.allclose(a, b, rtol=1e-4, atol=1e-5))
        agree[i, j] = agree[j, i] = ok
    np.fill_diagonal(agree, 1.0)
    return agree


def reduce_with_copies(
    plan: ButterflyPlan,
    uploads: dict[int, np.ndarray],
    tamper: Optional[dict[int, float]] = None,
) -> dict[tuple[int, int], np.ndarray]:
    """Each reducer's copy of each assigned shard (input to agreement_matrix)."""
    n = plan.n_miners
    tamper = tamper or {}
    stacked = np.stack([
        np.asarray(uploads[m], np.float32) if m in uploads
        else np.zeros(plan.vector_len, np.float32) for m in range(n)])
    valid_mask = jnp.asarray(np.array([m in uploads for m in range(n)]))
    out = {}
    for s, (i, j) in enumerate(plan.pairs):
        lo, hi = plan.shard_bounds(s)
        block = jnp.asarray(stacked[:, lo:hi])
        base = np.asarray(ops.shard_merge(block, valid_mask))
        for reducer in (i, j):
            copy = base + tamper.get(reducer, 0.0)
            out[(s, reducer)] = copy
    return out


# ---------------------------------------------------------------------------
# On-mesh butterfly (TPU pods): redundancy-2 reduce-scatter + all-gather
# ---------------------------------------------------------------------------


def butterfly_all_reduce_mesh(x: jax.Array, axis: str, mesh,
                              in_spec=None, redundancy: int = 2):
    """Mean-all-reduce of ``x`` along mesh axis ``axis`` with butterfly-style

    redundancy: two independent reduce-scatters over shifted shard
    assignments produce two copies of every shard on different devices; the
    copies are cross-checked (ppermute + compare) before the all-gather.
    Returns (reduced x, agreement fraction scalar).

    Bandwidth per device: 2 * (W/N reduce-scatter) + W all-gather + W/N
    permute ≈ the paper's 4W + 2W/N counted one-sided on uploads+downloads.
    """
    n = mesh.shape[axis]
    in_spec = in_spec if in_spec is not None else jax.sharding.PartitionSpec()
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def body(v):
        size = v.size
        flat = v.reshape(-1)
        pad = (-size) % n
        flat = jnp.pad(flat, (0, pad))
        shard_len = flat.shape[0] // n
        # copy A: canonical assignment (device d reduces shard d)
        copy_a = jax.lax.psum_scatter(flat, axis, scatter_dimension=0,
                                      tiled=True) / n
        # copy B: shifted assignment (device d reduces shard d+1 mod n)
        copy_b = jax.lax.psum_scatter(jnp.roll(flat, -shard_len), axis,
                                      scatter_dimension=0, tiled=True) / n
        # align copy B onto shard d's canonical reducer and cross-check:
        # device d-1 holds shard d in copy_b -> send i -> i+1
        perm = [(i, (i + 1) % n) for i in range(n)]
        copy_b_aligned = jax.lax.ppermute(copy_b, axis, perm)
        agree = jnp.mean((jnp.abs(copy_a - copy_b_aligned)
                          <= 1e-3 * (jnp.abs(copy_a) + 1e-6)).astype(jnp.float32))
        agree = jax.lax.pmean(agree, axis)
        merged = jax.lax.all_gather(copy_a, axis, axis=0, tiled=True)
        merged = merged[:size].reshape(v.shape)
        return merged, agree

    return shard_map_unchecked(
        body, mesh, (in_spec,),
        (in_spec, jax.sharding.PartitionSpec()),
    )(x)
