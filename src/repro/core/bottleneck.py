"""Paper §4: bottleneck transformer blocks with uninterrupted residual flow.

Fig 4 defines three block types.  Our faithful formulation (the paper gives
the figure, not equations — the interpretation below preserves every stated
property: residual pathway crosses the boundary *only* through the
compressed code, partial residuals are mixed into attention-layer outputs on
both sides, activations AND their gradients are compressed symmetrically):

  vanilla block        a = x + attn(norm(x));  y = a + mlp(norm(a))
  bottleneck block     a = α_enc·x + attn(norm(x));  h = a + mlp(norm(a))
                       z = cast_bf16( norm(h) @ W_down )          # wire code
  post-bottleneck blk  r = z @ W_up                                # carrier
                       a = α_dec·r + attn(norm(r));  y = a + mlp(norm(a))

z has width ``bottleneck_dim`` (32 on a 2048-d model ⇒ 64× dim reduction;
bf16-on-wire ⇒ the paper's 128× vs fp32).  Because z is produced by a *block
output* (post-attention/post-MLP hidden with the partial residual already
folded in), gradient flow back through the boundary passes through W_down/W_up
but never through a zero-residual gap — the property the paper credits for
preserved convergence.

The encode/decode matmuls are the compression hot-spot; on TPU they run as
the fused Pallas kernel (``kernels/bottleneck_fused.py``): RMSNorm + matmul +
cast in one VMEM pass instead of three HBM round-trips of the full-width
activation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BottleneckConfig, ModelConfig
from repro.kernels import ops
from repro.models.layers import dense_init, norm_init


def init_boundary(key, cfg: ModelConfig) -> dict:
    """Params for one bottleneck boundary (encoder + decoder sides)."""
    d, db = cfg.d_model, cfg.bottleneck.bottleneck_dim
    ks = jax.random.split(key, 2)
    return {
        "enc_norm": norm_init(d),
        "w_down": dense_init(ks[0], d, db),
        "w_up": dense_init(ks[1], db, d, scale=1.0 / np.sqrt(db)),
        "alpha_enc": jnp.asarray(1.0, jnp.float32),
        "alpha_dec": jnp.asarray(cfg.bottleneck.residual_alpha, jnp.float32),
    }


def encode(params: dict, h: jax.Array, cfg: ModelConfig,
           wire_dtype=jnp.bfloat16) -> jax.Array:
    """Block-output hidden (…, d_model) -> wire code (…, bottleneck_dim)."""
    return ops.bottleneck_encode(h, params["enc_norm"], params["w_down"],
                                 eps=cfg.norm_eps, wire_dtype=wire_dtype)


def decode(params: dict, z: jax.Array, cfg: ModelConfig,
           out_dtype=jnp.bfloat16) -> jax.Array:
    """Wire code -> full-width residual carrier r = z @ W_up."""
    zero_res = jnp.zeros(z.shape[:-1] + (cfg.d_model,), out_dtype)
    return ops.bottleneck_decode(z, params["w_up"], zero_res,
                                 jnp.asarray(0.0, jnp.float32),
                                 out_dtype=out_dtype)


def boundary_positions(n_layers: int, n_bottlenecks: int) -> list[int]:
    """Equally spaced boundary positions (index of the *bottleneck* block).

    A boundary at position p means: block p is a bottleneck block, block p+1
    is the post-bottleneck block.  With n_b boundaries the stack is split
    into n_b+1 pipeline stages.
    """
    if n_bottlenecks == 0:
        return []
    assert n_layers >= 2 * n_bottlenecks, (
        f"{n_layers} layers cannot host {n_bottlenecks} bottleneck/post pairs")
    # n_layers = regular blocks + 2 per boundary; spread the regular blocks
    # across the n_b+1 segments as evenly as possible (same scheme as
    # models.transformer.plan_layout, so docs/tests/layout agree)
    scanned = n_layers - 2 * n_bottlenecks
    base, extra = divmod(scanned, n_bottlenecks + 1)
    segs = [base + (1 if i < extra else 0) for i in range(n_bottlenecks + 1)]
    pos, cursor = [], 0
    for i in range(n_bottlenecks):
        cursor += segs[i]
        pos.append(cursor)          # the bottleneck block itself
        cursor += 2                 # bn + post-bn pair
    assert pos[-1] <= n_layers - 2
    return pos


def wire_bytes_per_token(cfg: ModelConfig, wire_dtype=jnp.bfloat16) -> int:
    """Bytes per token per boundary hop — the number the paper's 128x targets."""
    itemsize = jnp.dtype(wire_dtype).itemsize
    if cfg.bottleneck.enabled:
        return cfg.bottleneck.bottleneck_dim * itemsize
    return cfg.d_model * itemsize


def compression_report(cfg: ModelConfig) -> dict:
    """Ratios against the paper's fp32 full-width basis and the bf16 basis."""
    b = cfg.bottleneck
    full_fp32 = cfg.d_model * 4
    full_bf16 = cfg.d_model * 2
    wire = wire_bytes_per_token(cfg)
    return {
        "bottlenecks": b.n_bottlenecks,
        "bottleneck_dim": b.bottleneck_dim,
        "wire_bytes_per_token": wire,
        "ratio_vs_fp32": full_fp32 / wire,     # paper's headline number
        "ratio_vs_bf16": full_bf16 / wire,     # on-wire vs native bf16
    }
