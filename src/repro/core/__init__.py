"""IOTA core: the paper's five contributions as composable JAX modules.

C1 pipeline.py + diloco.py   — SWARM data+pipeline parallelism, B_min/B_eff
C2 incentives.py             — granular continuous incentives + stability
C3 bottleneck.py             — 128x activation compression, residual-preserving
C4 butterfly.py              — O(1) redundant all-reduce + agreement matrix
C5 clasp.py                  — pathway-sampling contribution attribution
"""
from repro.core import (  # noqa: F401
    bottleneck,
    butterfly,
    clasp,
    compression,
    diloco,
    incentives,
    pipeline,
)
