"""Inner optimizers: AdamW, SGD-M, Adafactor.

Functional optax-style API without the optax dependency:

    opt = adamw(schedule, ...)
    opt_state = opt.init(params)
    new_params, new_opt_state = opt.update(grads, opt_state, params, step)

Notes for the giant assigned archs (kimi-k2 1T, jamba 52B, llava 34B):
* ``opt_state_dtype`` lets moment buffers live in bf16 — halves optimizer HBM
  (quality note: production runs pair this with stochastic rounding; the
  dry-run only needs the honest memory footprint).
* ``adafactor`` keeps a factored second moment (row+col vectors instead of a
  full tensor) and no first moment — the classic memory-reduced choice; it is
  what makes kimi-k2 train_4k fit 16 GB/chip on the single-pod mesh.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    name: str
    # state_specs(param_specs_tree, param_shapes_tree) -> opt-state spec tree
    # (PartitionSpecs mirroring what ``init`` builds; used by the dry-run to
    # shard optimizer state like its parameters)
    state_specs: Callable[[Any, Any], Any] = None


def _is_pspec(x):
    from jax.sharding import PartitionSpec
    return isinstance(x, PartitionSpec)


def _to_dtype(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(schedule, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)
        return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros)}

    def update(grads, state, params, step):
        step = step + 1
        lr = schedule(step)
        b1c = 1 - beta1 ** step.astype(jnp.float32)
        b2c = 1 - beta2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu_f = beta1 * mu.astype(jnp.float32) + (1 - beta1) * g
            nu_f = beta2 * nu.astype(jnp.float32) + (1 - beta2) * jnp.square(g)
            step_dir = (mu_f / b1c) / (jnp.sqrt(nu_f / b2c) + eps)
            new_p = p - lr * (step_dir + weight_decay * p.astype(jnp.float32)
                              ).astype(p.dtype)
            return new_p.astype(p.dtype), mu_f.astype(state_dtype), nu_f.astype(state_dtype)

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}

    def state_specs(param_specs, param_shapes):
        del param_shapes
        import jax as _jax
        copy = lambda: _jax.tree.map(lambda s: s, param_specs,
                                     is_leaf=_is_pspec)
        return {"mu": copy(), "nu": copy()}

    return Optimizer(init, update, "adamw", state_specs)


# ---------------------------------------------------------------------------
# SGD with momentum (used as the DiLoCo *outer* optimizer: Nesterov)
# ---------------------------------------------------------------------------


def sgdm(schedule, momentum=0.9, nesterov=True, weight_decay=0.0,
         state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(grads, state, params, step):
        lr = schedule(step + 1)

        def upd(g, m, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m_f = momentum * m.astype(jnp.float32) + g
            d = g + momentum * m_f if nesterov else m_f
            return (p - lr * d.astype(p.dtype)).astype(p.dtype), m_f.astype(state_dtype)

        flat = jax.tree.map(upd, grads, state["mom"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mom = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mom": new_mom}

    def state_specs(param_specs, param_shapes):
        del param_shapes
        import jax as _jax
        return {"mom": _jax.tree.map(lambda s: s, param_specs,
                                     is_leaf=_is_pspec)}

    return Optimizer(init, update, "sgdm", state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ---------------------------------------------------------------------------


def adafactor(schedule, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, min_dim_size_to_factor=128) -> Optimizer:
    """Shazeer & Stern 2018, the memory-reduced variant used for giant archs."""

    def _factored(shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(leaf, params)}

    def update(grads, state, params, step):
        step_f = (step + 1).astype(jnp.float32)
        lr = schedule(step + 1)
        beta2 = 1.0 - step_f ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in v:
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                         ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(denom + eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(vv + eps)
                new_v = {"v": vv}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p - (lr * u + lr * weight_decay * p.astype(jnp.float32)
                         ).astype(p.dtype)
            return new_p.astype(p.dtype), new_v

        # pair each grad leaf with its factored-state sub-dict by flattening
        # the state tree "up to" the grads structure
        leaves_g, treedef = jax.tree_util.tree_flatten(grads)
        leaves_v = treedef.flatten_up_to(state["v"])
        leaves_p = jax.tree_util.tree_leaves(params)
        out_p, out_v = [], []
        for g, v, p in zip(leaves_g, leaves_v, leaves_p):
            np_, nv = upd(g, v, p)
            out_p.append(np_)
            out_v.append(nv)
        new_params = jax.tree_util.tree_unflatten(treedef, out_p)
        new_v = jax.tree_util.tree_unflatten(treedef, out_v)
        return new_params, {"v": new_v}

    def state_specs(param_specs, param_shapes):
        from jax.sharding import PartitionSpec as P

        def leaf(spec, shape):
            dims = list(spec) + [None] * (len(shape.shape) - len(spec))
            if _factored(shape.shape):
                return {"vr": P(*dims[:-1]),
                        "vc": P(*dims[:-2], dims[-1])}
            return {"v": P(*dims)}

        return {"v": jax.tree.map(leaf, param_specs, param_shapes,
                                  is_leaf=_is_pspec)}

    return Optimizer(init, update, "adafactor", state_specs)


def make_optimizer(parallel_cfg, train_cfg, total_steps: int | None = None) -> Optimizer:
    from repro.optim.schedules import cosine_warmup
    sched = cosine_warmup(train_cfg.lr, train_cfg.warmup_steps,
                          total_steps or train_cfg.total_steps)
    dtype = jnp.dtype(parallel_cfg.opt_state_dtype)
    if parallel_cfg.optimizer == "adamw":
        return adamw(sched, train_cfg.beta1, train_cfg.beta2, train_cfg.eps,
                     train_cfg.weight_decay, state_dtype=dtype)
    if parallel_cfg.optimizer == "adafactor":
        return adafactor(sched, weight_decay=train_cfg.weight_decay)
    if parallel_cfg.optimizer == "sgdm":
        return sgdm(sched, weight_decay=train_cfg.weight_decay)
    raise ValueError(parallel_cfg.optimizer)
