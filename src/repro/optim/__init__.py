from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    make_optimizer,
    sgdm,
)
from repro.optim.schedules import cosine_warmup  # noqa: F401
