"""xLSTM blocks (Beck et al., arXiv:2405.04517): stabilised mLSTM + sLSTM.

The xlstm-125m assigned arch alternates mLSTM (even) / sLSTM (odd) blocks.
Both cells use exponential gating with the log-space max-stabiliser m_t, so
training is NaN-free even with exp input gates.  Decode state is O(1) in
sequence length — this arch runs the ``long_500k`` shape.

d_ff == 0 in the assigned config: the blocks carry their own up/down
projection (proj_factor) instead of a separate FFN, per the paper.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, norm_init, rmsnorm


class MLSTMState(NamedTuple):
    C: jax.Array      # (B, H, Dh, Dh) matrix memory
    n: jax.Array      # (B, H, Dh) normaliser
    m: jax.Array      # (B, H) stabiliser


class SLSTMState(NamedTuple):
    c: jax.Array      # (B, d) scalar cell
    n: jax.Array      # (B, d) normaliser
    m: jax.Array      # (B, d) stabiliser
    h: jax.Array      # (B, d) previous hidden (recurrent input)


def _d_up(cfg: ModelConfig) -> int:
    return int(cfg.xlstm_proj_factor * cfg.d_model)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": norm_init(d),
        "wq": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, d),
        "wv": dense_init(ks[2], d, d),
        "wgi": dense_init(ks[3], d, H, scale=0.02),
        "wgf": dense_init(ks[4], d, H, scale=0.02),
        "bf": jnp.ones((H,), jnp.float32) * 3.0,   # forget-gate bias: remember
        "bi": jnp.zeros((H,), jnp.float32),
        "up_proj": dense_init(ks[5], d, 2 * _d_up(cfg)),
        "down_proj": dense_init(ks[6], _d_up(cfg), d,
                                scale=1.0 / np.sqrt(_d_up(cfg) * 2 * cfg.n_layers)),
        "out_norm": norm_init(d),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    H = cfg.n_heads
    Dh = cfg.d_model // H
    return MLSTMState(
        C=jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        n=jnp.zeros((batch, H, Dh), jnp.float32),
        m=jnp.full((batch, H), -1e9, jnp.float32),
    )


def mlstm_block(params: dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[MLSTMState] = None
                ) -> tuple[jax.Array, Optional[MLSTMState]]:
    B, S, d = x.shape
    H = cfg.n_heads
    Dh = d // H
    dtype = x.dtype
    xn = rmsnorm(x, params["norm"], cfg.norm_eps)

    q = (xn @ params["wq"].astype(dtype)).reshape(B, S, H, Dh).astype(jnp.float32)
    k = (xn @ params["wk"].astype(dtype)).reshape(B, S, H, Dh).astype(jnp.float32)
    v = (xn @ params["wv"].astype(dtype)).reshape(B, S, H, Dh).astype(jnp.float32)
    k = k / np.sqrt(Dh)
    i_pre = (xn.astype(jnp.float32) @ params["wgi"].astype(jnp.float32)) + params["bi"]
    f_pre = (xn.astype(jnp.float32) @ params["wgf"].astype(jnp.float32)) + params["bf"]

    st = state if state is not None else init_mlstm_state(cfg, B)

    def step(carry, inputs):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inputs                       # (B,H,...)
        log_f = -jax.nn.softplus(-f_t)                         # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, i_t)
        i_g = jnp.exp(i_t - m_new)                             # (B,H)
        f_g = jnp.exp(log_f + m - m_new)
        C = f_g[..., None, None] * C + i_g[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :])             # (B,H,Dh,Dh)
        n = f_g[..., None] * n + i_g[..., None] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), 1.0)
        h_t = num / den[..., None]
        return (C, n, m_new), h_t

    from repro.models.scan_utils import chunked_scan, pick_chunk
    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3),
          i_pre.transpose(1, 0, 2), f_pre.transpose(1, 0, 2))
    (C, n, m), hs = chunked_scan(step, (st.C, st.n, st.m), xs,
                                 chunk=pick_chunk(S))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(dtype)

    h = rmsnorm(h, params["out_norm"], cfg.norm_eps)
    u, g = jnp.split(h @ params["up_proj"].astype(dtype), 2, axis=-1)
    out = (u * jax.nn.silu(g)) @ params["down_proj"].astype(dtype)
    new_state = MLSTMState(C, n, m) if state is not None else None
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 10)
    # recurrent matrices are block-diagonal per head: (H, dh, dh)
    def rec(k):
        return (jax.random.normal(k, (H, dh, dh)) / np.sqrt(dh)).astype(jnp.float32)
    return {
        "norm": norm_init(d),
        "wz": dense_init(ks[0], d, d), "wi": dense_init(ks[1], d, d, scale=0.02),
        "wf": dense_init(ks[2], d, d, scale=0.02), "wo": dense_init(ks[3], d, d),
        "rz": rec(ks[4]), "ri": rec(ks[5]), "rf": rec(ks[6]), "ro": rec(ks[7]),
        "bf": jnp.ones((d,), jnp.float32) * 3.0,
        "bi": jnp.zeros((d,), jnp.float32),
        "up_proj": dense_init(ks[8], d, 2 * _d_up(cfg)),
        "down_proj": dense_init(ks[9], _d_up(cfg), d,
                                scale=1.0 / np.sqrt(_d_up(cfg) * 2 * cfg.n_layers)),
        "out_norm": norm_init(d),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, d), -1e9, jnp.float32), h=z)


def _blockdiag(h: jax.Array, r: jax.Array) -> jax.Array:
    """h (B, d) x blockdiag r (H, dh, dh) -> (B, d)."""
    B, d = h.shape
    H, dh, _ = r.shape
    return jnp.einsum("bhi,hij->bhj", h.reshape(B, H, dh), r).reshape(B, d)


def slstm_block(params: dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[SLSTMState] = None
                ) -> tuple[jax.Array, Optional[SLSTMState]]:
    B, S, d = x.shape
    dtype = x.dtype
    xn = rmsnorm(x, params["norm"], cfg.norm_eps).astype(jnp.float32)

    pre = {g: xn @ params["w" + g].astype(jnp.float32) for g in "zifo"}
    st = state if state is not None else init_slstm_state(cfg, B)

    def step(carry, inputs):
        c, n, m, h = carry
        z_t, i_t, f_t, o_t = inputs
        z_t = z_t + _blockdiag(h, params["rz"])
        i_t = i_t + _blockdiag(h, params["ri"]) + params["bi"]
        f_t = f_t + _blockdiag(h, params["rf"]) + params["bf"]
        o_t = o_t + _blockdiag(h, params["ro"])
        log_f = -jax.nn.softplus(-f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        i_g = jnp.exp(i_t - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c = f_g * c + i_g * jnp.tanh(z_t)
        n = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    from repro.models.scan_utils import chunked_scan, pick_chunk
    xs = tuple(pre[g].transpose(1, 0, 2) for g in "zifo")
    (c, n, m, h_last), hs = chunked_scan(step, (st.c, st.n, st.m, st.h), xs,
                                         chunk=pick_chunk(S))
    h = hs.transpose(1, 0, 2).astype(dtype)

    h = rmsnorm(h, params["out_norm"], cfg.norm_eps)
    u, g = jnp.split(h @ params["up_proj"].astype(dtype), 2, axis=-1)
    out = (u * jax.nn.silu(g)) @ params["down_proj"].astype(dtype)
    new_state = SLSTMState(c, n, m, h_last) if state is not None else None
    return out, new_state
