"""Core layers: RMSNorm, RoPE, GQA attention (+qk_norm), SwiGLU, embeddings.

Pure functions over param dicts.  ``ma`` (MeshAxes | None) threads sharding
constraints through without making the layers mesh-dependent: with ``ma=None``
everything runs unconstrained on one device.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.sharding.partition import MeshAxes, batch_spec, shard_constraint

Params = dict


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, scale: float | None = None,
               dtype=jnp.float32) -> jax.Array:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale
            ).astype(dtype)


def norm_init(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    return ref.rmsnorm(x, gamma, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings (split-half convention)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, d_head: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) int32 -> cos/sin (..., S, d_head//2) f32."""
    half = d_head // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (B, S, D/2) or (S, D/2).

    Angles are computed in fp32 (rope_angles) but applied in x.dtype —
    §Perf change, cell C iteration 2 (the fp32 rotation intermediates were
    a top-5 HBM-traffic contributor in the baseline HLO)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, KH, D)
    v: jax.Array          # (B, S_max, KH, D)
    length: jax.Array     # () int32 — valid prefix length


def init_attention(key, cfg: ModelConfig) -> Params:
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model,
                         scale=1.0 / np.sqrt(cfg.n_heads * hd * 2 * cfg.n_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def _attn_act_spec(ma: Optional[MeshAxes], heads_sharded: bool) -> Optional[P]:
    if ma is None:
        return None
    if ma.attn_batch_reshard:
        # heads don't divide the model axis: spread batch over (data, model)
        return P((*ma.batch, ma.model), None, None, None)
    return P(ma.batch, None, ma.model if heads_sharded else None, None)


def attention(
    params: Params,
    x: jax.Array,                       # (B, S, d_model) compute dtype
    cfg: ModelConfig,
    ma: Optional[MeshAxes],
    positions: jax.Array,               # (B, S) int32 absolute positions
    cache: Optional[KVCache] = None,    # decode: append + attend over prefix
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,  # enc-dec cross-attn
    causal: bool = True,                # False: bidirectional (encoder stacks)
) -> tuple[jax.Array, Optional[KVCache]]:
    B, S, _ = x.shape
    hd = cfg.head_dim
    dtype = x.dtype

    q = (x @ params["wq"].astype(dtype)).reshape(B, S, cfg.n_heads, hd)
    if cross_kv is None:
        k = (x @ params["wk"].astype(dtype)).reshape(B, S, cfg.n_kv_heads, hd)
        v = (x @ params["wv"].astype(dtype)).reshape(B, S, cfg.n_kv_heads, hd)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rmsnorm(k, params["k_norm"], cfg.norm_eps)

    if cross_kv is None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    q = shard_constraint(q, _attn_act_spec(ma, True))
    k = shard_constraint(k, _attn_act_spec(ma, ma.shard_kv_heads if ma else False))
    v = shard_constraint(v, _attn_act_spec(ma, ma.shard_kv_heads if ma else False))

    new_cache = None
    if cross_kv is not None:
        out = ops.flash_attention(q, k, v, causal=False)
    elif cache is None:
        out = ops.flash_attention(q, k, v, causal=causal)
    else:
        # Decode: write new kv at `length`, attend over the valid prefix + new.
        S_max = cache.k.shape[1]
        pos = jnp.minimum(cache.length, S_max - S)
        k_cache = jax.lax.dynamic_update_slice(cache.k, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache.v, v, (0, pos, 0, 0))
        kv_len = jnp.minimum(cache.length + S, S_max) * jnp.ones((B,), jnp.int32)
        out = ops.flash_attention(
            q, k_cache, v_cache, causal=True, q_offset=pos, kv_len=kv_len)
        new_cache = KVCache(k_cache, v_cache, cache.length + S)

    out = out.reshape(B, S, cfg.n_heads * hd)
    out = out @ params["wo"].astype(dtype)
    out = shard_constraint(out, batch_spec(ma, None, None))
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16, n_layers: Optional[int] = None) -> KVCache:
    """Stacked (layers-leading) KV cache for the scan-over-layers decoder."""
    n_layers = cfg.n_layers if n_layers is None else n_layers
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], cfg.d_model, d_ff),
        "w_up": dense_init(ks[1], cfg.d_model, d_ff),
        "w_out": dense_init(ks[2], d_ff, cfg.d_model,
                            scale=1.0 / np.sqrt(d_ff * 2 * cfg.n_layers)),
    }


def mlp(params: Params, x: jax.Array, ma: Optional[MeshAxes]) -> jax.Array:
    dtype = x.dtype
    h = jax.nn.silu(x @ params["w_gate"].astype(dtype)) * (x @ params["w_up"].astype(dtype))
    h = shard_constraint(h, batch_spec(ma, None, ma.model) if ma else None)
    out = h @ params["w_out"].astype(dtype)
    return shard_constraint(out, batch_spec(ma, None, None))


# ---------------------------------------------------------------------------
# Embedding / unembedding with Megatron-style vocab padding
# ---------------------------------------------------------------------------


def init_embeddings(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    p = {"embed": dense_init(ks[0], cfg.padded_vocab, cfg.d_model, scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], cfg.padded_vocab, cfg.d_model)
    return p


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig,
          ma: Optional[MeshAxes], dtype=jnp.bfloat16) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    return shard_constraint(x, batch_spec(ma, None, None))


def logits(params: Params, x: jax.Array, cfg: ModelConfig,
           ma: Optional[MeshAxes]) -> jax.Array:
    """(B, S, d_model) -> (B, S, padded_vocab) fp32, padded entries ~ -inf."""
    table = params.get("unembed", params["embed"])
    out = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                     table.astype(jnp.float32))
    pad_mask = (jnp.arange(cfg.padded_vocab) >= cfg.vocab_size) * (-1e9)
    out = out + pad_mask
    return shard_constraint(out, batch_spec(ma, None, ma.model) if ma else None)


def next_token_loss(lgts: jax.Array, labels: jax.Array,
                    z_loss: float = 0.0) -> jax.Array:
    """Mean next-token cross entropy; labels (B, S) already shifted."""
    lse = jax.nn.logsumexp(lgts, axis=-1)
    true_logit = jnp.take_along_axis(lgts, labels[..., None], axis=-1)[..., 0]
    nll = lse - true_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    return jnp.mean(nll)
