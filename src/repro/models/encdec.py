"""Encoder-decoder backbone for seamless-m4t-medium ([audio] family).

The modality frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, F, d_model) straight into the encoder.  The
decoder is a standard causal stack with per-layer cross-attention over the
encoder memory.  n_layers applies to BOTH stacks (12 enc + 12 dec).

Bottleneck boundaries use ``insert`` mode inside each stack; additionally the
encoder memory handed to the decoder can be bottleneck-compressed once
(``compress_memory``) — a beyond-paper extension of §4 to the cross-attention
wire, used when the enc/dec stacks live on different pipeline stages.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bottleneck as bn
from repro.models import blocks as blk
from repro.models.layers import embed, init_embeddings, logits, norm_init, rmsnorm
from repro.models.transformer import (
    StackLayout,
    _state_length,
    apply_stack,
    init_decoder_stack,
    init_stack_state,
    plan_layout,
)
from repro.sharding.partition import MeshAxes

WIRE_DTYPE = jnp.bfloat16


def enc_layout(cfg: ModelConfig) -> StackLayout:
    return plan_layout(cfg, decoder=False)


def dec_layout(cfg: ModelConfig) -> StackLayout:
    return plan_layout(cfg, decoder=True)


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "embeds": init_embeddings(ks[0], cfg),
        "enc": init_decoder_stack(ks[1], cfg, enc_layout(cfg)),
        "dec": init_decoder_stack(ks[2], cfg, dec_layout(cfg)),
        "enc_norm": norm_init(cfg.d_model),
        "final_norm": norm_init(cfg.d_model),
    }
    if cfg.bottleneck.enabled:
        p["memory_boundary"] = bn.init_boundary(ks[3], cfg)
    return p


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           ma: Optional[MeshAxes], remat: bool = True) -> jax.Array:
    """Frontend frame embeddings (B, F, d) -> encoder memory (B, F, d)."""
    B, F, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))
    ctx = blk.BlockCtx(cfg=cfg, ma=ma, positions=positions, causal=False)
    x, _, _ = apply_stack(params["enc"], frames, ctx, enc_layout(cfg),
                          None, remat)
    x = rmsnorm(x, params["enc_norm"], cfg.norm_eps)
    if cfg.bottleneck.enabled:
        # compress the cross-attention memory once for the enc->dec wire
        z = bn.encode(params["memory_boundary"], x, cfg, WIRE_DTYPE)
        x = bn.decode(params["memory_boundary"], z, cfg, x.dtype)
    return x


def forward(
    params: dict,
    tokens: jax.Array,                  # (B, S) decoder tokens
    cfg: ModelConfig,
    ma: Optional[MeshAxes] = None,
    *,
    frames: Optional[jax.Array] = None,  # (B, F, d_model) frontend embeddings
    memory: Optional[jax.Array] = None,  # precomputed encoder memory (decode)
    state: Optional[dict] = None,
    remat: bool = True,
    compute_dtype=jnp.bfloat16,
    capture_wire: Optional[list] = None,
):
    """Returns (logits, new_state, aux)."""
    assert (frames is None) != (memory is None), \
        "pass exactly one of frames / memory"
    if memory is None:
        memory = encode(params, frames.astype(compute_dtype), cfg, ma, remat)

    B, S = tokens.shape
    x = embed(params["embeds"], tokens, cfg, ma, compute_dtype)
    if state is not None:
        length = _state_length(state)
        positions = length + jnp.arange(S, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(positions, (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    # cross-attention K/V are produced per decoder layer from the shared
    # memory inside each attn_dense_cross block
    ctx = blk.BlockCtx(cfg=cfg, ma=ma, positions=positions,
                       cross_memory=memory, causal=True)
    x, new_state, aux = apply_stack(params["dec"], x, ctx, dec_layout(cfg),
                                    state, remat, capture_wire)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    lgts = logits(params["embeds"], x, cfg, ma)
    return lgts, new_state, aux


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    return init_stack_state(cfg, dec_layout(cfg), batch, max_len, dtype)


def decode_state_specs(cfg: ModelConfig, ma, batch: int):
    from repro.models.transformer import stack_state_specs
    return stack_state_specs(cfg, dec_layout(cfg), ma, batch)
