"""Modality frontend STUBS for the [vlm]/[audio] assigned archs.

Per the assignment, these entries specify the transformer BACKBONE only; the
frontend supplies precomputed patch/frame embeddings.  ``input_specs()`` in
launch/dryrun.py therefore feeds ``ShapeDtypeStruct`` embeddings directly; the
helpers here generate *synthetic but deterministic* embeddings for smoke
tests and examples, with the documented geometry:

* llava-next-34b: anyres tiling — a 672x672 image = 1 base 336px tile + 4
  crops, 576 patches each -> 2880 patch embeddings (width d_model).
* seamless-m4t-medium: 16kHz audio, 80-dim fbank at 10ms hop, conv
  subsampling x4 -> ``frames = seconds * 25`` frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LLAVA_ANYRES_TILES = 5
LLAVA_PATCHES_PER_TILE = 576
LLAVA_FRONTEND_TOKENS = LLAVA_ANYRES_TILES * LLAVA_PATCHES_PER_TILE  # 2880


def vision_patch_embeds(key, batch: int, n_patches: int, d_model: int,
                        dtype=jnp.bfloat16) -> jax.Array:
    """Stub ViT output: unit-RMS random patch embeddings (B, P, d)."""
    x = jax.random.normal(key, (batch, n_patches, d_model), jnp.float32)
    return (x / jnp.sqrt(jnp.mean(x ** 2, axis=-1, keepdims=True) + 1e-6)
            ).astype(dtype)


def audio_frame_embeds(key, batch: int, n_frames: int, d_model: int,
                       dtype=jnp.bfloat16) -> jax.Array:
    """Stub speech-encoder frontend output (B, F, d): smoothed noise so the

    encoder sees locally correlated 'speech-like' features."""
    x = jax.random.normal(key, (batch, n_frames + 8, d_model), jnp.float32)
    kernel = jnp.ones((9,), jnp.float32) / 9.0
    x = jax.vmap(jax.vmap(lambda row: jnp.convolve(row, kernel, mode="valid"),
                          in_axes=1, out_axes=1))(x)
    return x[:, :n_frames].astype(dtype)


def audio_frames_for_seq(seq_len: int) -> int:
    """Encoder memory length paired with a decoder length (doc'd in DESIGN.md):

    1/4 of the text length, capped at 4096 frames (~163s of audio)."""
    return min(max(seq_len // 4, 64), 4096)
