"""Sequence-scan utilities for the recurrent families (mamba / xlstm).

A naive ``lax.scan`` over 4k-500k timesteps stores the carry at every step
for the backward pass — for mLSTM's (B, H, Dh, Dh) matrix memory that is
terabytes.  ``chunked_scan`` nests the scan: an outer scan over chunks whose
body is ``jax.checkpoint``-ed, so only chunk-boundary carries persist and
each chunk's interior is recomputed during its own backward.  Memory drops
from O(S * |carry|) to O(S/c * |carry| + c * |carry|), minimised at
c ≈ sqrt(S) but fixed at the config's ``scan_chunk`` (256) for predictable
VMEM-friendly chunk sizes.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def chunked_scan(body: Callable, carry: Any, xs: Any, *, chunk: int = 256,
                 remat: bool = True) -> tuple[Any, Any]:
    """Drop-in replacement for ``jax.lax.scan(body, carry, xs)``.

    xs leaves are (S, ...); S must be divisible by ``chunk`` (callers pick
    ``chunk = S`` for short/smoke sequences).
    """
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if chunk >= S or S % chunk != 0:
        return jax.lax.scan(body, carry, xs)

    n_chunks = S // chunk
    xs_c = jax.tree.map(
        lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), xs)

    def chunk_body(c, x_chunk):
        return jax.lax.scan(body, c, x_chunk)

    if remat:
        chunk_body = jax.checkpoint(
            chunk_body, policy=jax.checkpoint_policies.nothing_saveable)

    carry, ys_c = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(
        lambda y: y.reshape((S,) + y.shape[2:]), ys_c)
    return carry, ys


def pick_chunk(seq_len: int, preferred: int = 256) -> int:
    if seq_len % preferred == 0:
        return preferred
    return seq_len
