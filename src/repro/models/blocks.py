"""Unified block abstraction over every assigned family.

A *block kind* is a string:
  "attn_dense"        pre-norm GQA attention + SwiGLU FFN          (dense LMs)
  "attn_moe"          attention + MoE FFN                          (kimi, olmoe)
  "attn_none"         attention only (xlstm-style d_ff == 0 never uses this;
                       kept for completeness)
  "attn_dense_cross"  attention + cross-attention + FFN            (enc-dec dec)
  "mamba_dense"/"mamba_moe"  Mamba mixer + (dense|MoE) FFN         (jamba)
  "mlstm" / "slstm"   xLSTM blocks (own up/down projection, no FFN)

Every kind shares one protocol:
  init_block(key, kind, cfg)                         -> params
  apply_block(kind, params, x, ctx, state, res_alpha) -> (y, new_state, aux)

``state`` is the per-block decode state (KVCache / MambaState / xLSTM states)
or None in training.  ``res_alpha`` is the partial-residual weight used by
bottleneck / post-bottleneck blocks (paper Fig 4); None = standard residual.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    KVCache,
    attention,
    init_attention,
    init_mlp,
    mlp,
    norm_init,
    rmsnorm,
)
from repro.sharding.partition import MeshAxes


@dataclasses.dataclass
class BlockCtx:
    cfg: ModelConfig
    ma: Optional[MeshAxes]
    positions: jax.Array                       # (B, S) absolute positions
    cross_memory: Optional[jax.Array] = None   # (B, F, d_model) encoder memory
    causal: bool = True                        # False inside encoder stacks


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, kind: str, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn_dense", "attn_moe", "attn_none", "attn_dense_cross"):
        p: dict = {"attn_norm": norm_init(d), "attn": init_attention(ks[0], cfg)}
        if kind == "attn_dense_cross":
            p["cross_norm"] = norm_init(d)
            p["cross"] = init_attention(ks[2], cfg)
        if kind.endswith("_moe"):
            p["ffn_norm"] = norm_init(d)
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        elif kind != "attn_none":
            p["ffn_norm"] = norm_init(d)
            p["mlp"] = init_mlp(ks[1], cfg)
        return p
    if kind.startswith("mamba"):
        p = {"mamba_norm": norm_init(d), "mamba": mamba_mod.init_mamba(ks[0], cfg)}
        if kind.endswith("_moe"):
            p["ffn_norm"] = norm_init(d)
            p["moe"] = moe_mod.init_moe(ks[1], cfg)
        elif kind.endswith("_dense"):
            p["ffn_norm"] = norm_init(d)
            p["mlp"] = init_mlp(ks[1], cfg)
        return p
    if kind == "mlstm":
        return {"mlstm": xlstm_mod.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"slstm": xlstm_mod.init_slstm(ks[0], cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def init_block_state(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Any:
    """Decode-time state for one block of this kind."""
    if kind.startswith("attn"):
        shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32))
    if kind.startswith("mamba"):
        return mamba_mod.init_mamba_state(cfg, batch)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def block_state_specs(kind: str, cfg: ModelConfig, ma, batch: int):
    """PartitionSpec tree mirroring ``init_block_state`` for the dry-run.

    Batch shards over the batch axes when divisible; otherwise (long_500k
    B=1) KV caches shard their *sequence* over ``data`` and recurrent states
    shard their feature dim over ``model``.
    """
    from jax.sharding import PartitionSpec as P
    if ma is None:
        b = None
    else:
        total = 1
        from numpy import prod
        total = int(prod([ma.mesh.shape[a] for a in ma.batch])) \
            if ma.mesh is not None else ma.data_axis_size
        b = ma.batch if batch % max(total, 1) == 0 else None
    mdl = ma.model if ma is not None else None
    kv_div = ma is not None and ma.shard_kv_heads
    kv = mdl if kv_div else None
    if kind.startswith("attn"):
        if ma is None:
            kvspec = P(None, None, None, None)
        elif b is not None:
            # kv heads over model when divisible, else the 32k+ sequence dim
            # — the cache must never be model-replicated (llama decode_32k:
            # 17 GiB/device replicated vs 1.1 GiB seq-sharded)
            kvspec = P(b, None, mdl, None) if kv_div else P(b, mdl, None, None)
        else:
            # tiny-batch decode (long_500k): shard the sequence dim
            seq_axes = ma.data if kv_div else (ma.data, ma.model)
            kvspec = P(None, seq_axes, kv, None)
        return KVCache(kvspec, kvspec, P())
    if kind.startswith("mamba"):
        return mamba_mod.MambaState(h=P(b, mdl, None), conv=P(b, None, mdl))
    if kind == "mlstm":
        return xlstm_mod.MLSTMState(C=P(b, None, mdl, None),
                                    n=P(b, None, mdl), m=P(b, None))
    if kind == "slstm":
        return xlstm_mod.SLSTMState(c=P(b, mdl), n=P(b, mdl), m=P(b, mdl),
                                    h=P(b, mdl))
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def apply_block(
    kind: str,
    params: dict,
    x: jax.Array,
    ctx: BlockCtx,
    state: Any = None,
    res_alpha: Optional[jax.Array] = None,
) -> tuple[jax.Array, Any, jax.Array]:
    cfg = ctx.cfg
    aux = jnp.zeros((), jnp.float32)
    dtype = x.dtype

    def resid(r, delta):
        if res_alpha is None:
            return r + delta
        return res_alpha.astype(jnp.float32).astype(dtype) * r + delta

    if kind.startswith("attn"):
        a, new_state = attention(
            params["attn"], rmsnorm(x, params["attn_norm"], cfg.norm_eps),
            cfg, ctx.ma, ctx.positions, cache=state, causal=ctx.causal)
        x = resid(x, a)
        if kind == "attn_dense_cross":
            mem = ctx.cross_memory
            B, F, _ = mem.shape
            hd = cfg.head_dim
            ck = (mem @ params["cross"]["wk"].astype(mem.dtype)
                  ).reshape(B, F, cfg.n_kv_heads, hd)
            cv = (mem @ params["cross"]["wv"].astype(mem.dtype)
                  ).reshape(B, F, cfg.n_kv_heads, hd)
            c, _ = attention(
                params["cross"], rmsnorm(x, params["cross_norm"], cfg.norm_eps),
                cfg, ctx.ma, ctx.positions, cross_kv=(ck, cv))
            x = x + c
        if "moe" in params:
            h, aux = moe_mod.moe_ffn(
                params["moe"], rmsnorm(x, params["ffn_norm"], cfg.norm_eps),
                cfg, ctx.ma)
            x = x + h
        elif "mlp" in params:
            x = x + mlp(params["mlp"],
                        rmsnorm(x, params["ffn_norm"], cfg.norm_eps), ctx.ma)
        return x, new_state, aux

    if kind.startswith("mamba"):
        m, new_state = mamba_mod.mamba_block(
            params["mamba"], rmsnorm(x, params["mamba_norm"], cfg.norm_eps),
            cfg, state)
        x = resid(x, m)
        if "moe" in params:
            h, aux = moe_mod.moe_ffn(
                params["moe"], rmsnorm(x, params["ffn_norm"], cfg.norm_eps),
                cfg, ctx.ma)
            x = x + h
        elif "mlp" in params:
            x = x + mlp(params["mlp"],
                        rmsnorm(x, params["ffn_norm"], cfg.norm_eps), ctx.ma)
        return x, new_state, aux

    if kind == "mlstm":
        y, new_state = xlstm_mod.mlstm_block(params["mlstm"], x, cfg, state)
        return resid(x, y), new_state, aux
    if kind == "slstm":
        y, new_state = xlstm_mod.slstm_block(params["slstm"], x, cfg, state)
        return resid(x, y), new_state, aux
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# per-arch period layout
# ---------------------------------------------------------------------------


def period_kinds(cfg: ModelConfig, decoder: bool = False) -> list[str]:
    """The repeating unit of block kinds for this arch.

    The full stack is ``n_layers / len(period)`` repetitions, scanned.
    """
    fam = cfg.family
    if fam == "ssm":
        return ["mlstm", "slstm"]
    if fam == "hybrid":
        period = []
        for i in range(cfg.hybrid_period):
            mixer = "attn" if i == cfg.hybrid_attn_index else "mamba"
            if cfg.moe is not None and cfg.moe.layer_pattern == "alternate":
                ffn = "moe" if i % 2 == 1 else "dense"
            else:
                ffn = "moe" if cfg.moe is not None else "dense"
            period.append(f"{mixer}_{ffn}")
        return period
    if cfg.is_encoder_decoder and decoder:
        return ["attn_dense_cross"]
    if cfg.moe is not None:
        if cfg.moe.layer_pattern == "alternate":
            return ["attn_dense", "attn_moe"]
        return ["attn_moe"]
    return ["attn_dense"]
