"""Selective state-space (Mamba) block for the Jamba hybrid architecture.

Faithful to Gu & Dao selective SSM: input-dependent (Δ, B, C), diagonal A,
causal depthwise conv front, SiLU gating, with a recurrent decode path whose
state is O(d_inner * d_state) — this is what makes ``long_500k`` runnable for
the hybrid arch (per-token decode cost independent of context length).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import cdiv
from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


class MambaState(NamedTuple):
    h: jax.Array          # (B, d_inner, d_state) SSM hidden
    conv: jax.Array       # (B, d_conv - 1, d_inner) causal conv tail


def _dims(cfg: ModelConfig) -> tuple[int, int]:
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = cdiv(cfg.d_model, 16)
    return d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig) -> dict:
    d, (d_in, dt_rank) = cfg.d_model, _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialisation of A
    A = jnp.tile(jnp.arange(1, cfg.mamba_d_state + 1, dtype=jnp.float32)[None],
                 (d_in, 1))
    dt_bias = jnp.log(jnp.exp(
        jnp.clip(jax.random.uniform(ks[4], (d_in,)) *
                 (np.log(0.1) - np.log(1e-3)) + np.log(1e-3), -10, 10).astype(jnp.float32)
    ))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, d_in)) /
                   np.sqrt(cfg.mamba_d_conv)).astype(jnp.float32),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * cfg.mamba_d_state),
        "dt_proj": dense_init(ks[3], dt_rank, d_in, scale=dt_rank ** -0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], d_in, d,
                               scale=1.0 / np.sqrt(d_in * 2 * cfg.n_layers)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq.  x (B,S,C), w (K,C).  Returns

    (y (B,S,C), new_tail (B,K-1,C))."""
    B, S, C = x.shape
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)   # (B, S+K-1, C)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :].astype(x.dtype)
            for i in range(K))
    y = y + b.astype(x.dtype)
    new_tail = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_tail


def mamba_block(params: dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[MambaState] = None
                ) -> tuple[jax.Array, Optional[MambaState]]:
    """x (B, S, d_model) -> (y, new_state).  state=None => training (h0 = 0,

    no state returned unless a state was passed in)."""
    B, S, d = x.shape
    d_in, dt_rank = _dims(cfg)
    d_state = cfg.mamba_d_state
    dtype = x.dtype

    xz = x @ params["in_proj"].astype(dtype)
    x_part, z = jnp.split(xz, 2, axis=-1)                     # (B,S,d_in) each

    tail_in = state.conv if state is not None else None
    x_conv, new_tail = _causal_conv(x_part, params["conv_w"], params["conv_b"], tail_in)
    x_conv = jax.nn.silu(x_conv)

    dbc = x_conv @ params["x_proj"].astype(dtype)
    dt, B_ssm, C_ssm = jnp.split(
        dbc.astype(jnp.float32), [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32)
                            + params["dt_bias"])              # (B,S,d_in)
    A = -jnp.exp(params["A_log"])                             # (d_in, d_state)

    h0 = (state.h.astype(jnp.float32) if state is not None
          else jnp.zeros((B, d_in, d_state), jnp.float32))

    if state is None:
        # training / prefill: the whole-sequence selective scan goes through
        # the Pallas kernel on TPU (h resident in VMEM — §Perf cell B); the
        # reference lax.scan elsewhere
        from repro.kernels import ops
        y = ops.mamba_scan(delta, x_conv.astype(jnp.float32), B_ssm, C_ssm, A)
        h_last = h0                  # not needed without a carried state
    else:
        # decode: explicit recurrence carrying the state
        # (discretisation happens INSIDE the step so the (B,S,d_in,d_state)
        # dA/dBx tensors are never materialised across the whole sequence)
        def step(h, inputs):
            delta_t, B_t, C_t, x_t = inputs                   # (B,d_in)/(B,ds)
            dA_t = jnp.exp(delta_t[..., None] * A[None])      # (B,d_in,ds)
            dBx_t = (delta_t * x_t)[..., None] * B_t[:, None, :]
            h = dA_t * h + dBx_t                              # (B,d_in,ds)
            y = jnp.einsum("bds,bs->bd", h, C_t)
            return h, y

        from repro.models.scan_utils import chunked_scan, pick_chunk
        xs = (delta.transpose(1, 0, 2), B_ssm.transpose(1, 0, 2),
              C_ssm.transpose(1, 0, 2),
              x_conv.astype(jnp.float32).transpose(1, 0, 2))
        h_last, ys = chunked_scan(step, h0, xs, chunk=pick_chunk(S))
        y = ys.transpose(1, 0, 2)                             # (B,S,d_in)
    y = y + x_conv.astype(jnp.float32) * params["D"]
    y = (y.astype(dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dtype)

    new_state = MambaState(h_last.astype(jnp.float32), new_tail) \
        if state is not None else None
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    d_in, _ = _dims(cfg)
    return MambaState(
        h=jnp.zeros((batch, d_in, cfg.mamba_d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), jnp.bfloat16),
    )
