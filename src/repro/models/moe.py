"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Two execution paths, one algorithm:

* **EP path** (``ma`` with a mesh): ``shard_map`` over the full mesh.  Each
  device owns ``E_local = E / model_axis`` experts and its data-shard of
  tokens; it routes *its* tokens, keeps only assignments that land on local
  experts, runs a sort + ``jax.lax.ragged_dot`` grouped matmul, and psums the
  weighted expert outputs over the ``model`` axis.  No all-to-all of tokens is
  required: each token's top-k experts live somewhere on the model axis, and
  the psum both combines expert outputs and replicates the result — the same
  bytes an all-to-all-based EP would move, with a simpler schedule.

* **Local path** (``ma is None``): identical routing + ragged_dot with all
  experts local (CPU smoke tests, single device).

Capacity: per-device expert buffers are padded to
``cap = ceil(N_local * k * E_local / E * capacity_factor)`` rows; overflow
tokens are dropped (Switch-style), underflow rows ride along with gate 0.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common import cdiv, round_up, shard_map_unchecked
from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.partition import MeshAxes

try:  # jax >= 0.4.35 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore


def init_moe(key, cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    E = cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(f * 2 * cfg.n_layers)
    return {
        "router": {"w": dense_init(ks[0], d, E, scale=0.02)},
        "experts": {
            "w_gate": (jax.random.truncated_normal(ks[1], -2, 2, (E, d, f)) * scale_in).astype(jnp.float32),
            "w_up": (jax.random.truncated_normal(ks[2], -2, 2, (E, d, f)) * scale_in).astype(jnp.float32),
            "w_out": (jax.random.truncated_normal(ks[3], -2, 2, (E, f, d)) * scale_out).astype(jnp.float32),
        },
    }


@functools.lru_cache(maxsize=None)
def _gathered_int8_fn(axis: str, gather_dim: int, scale_axis: int = -1):
    """FSDP all-gather of an expert-weight shard with int8 on the wire.

    §Perf cell A iteration 2 (beyond-paper, in the spirit of the paper's
    compressed-sharing stage): the per-microbatch expert-bank gathers
    dominate kimi-k2's collective term; quantizing the gather payload to
    int8 (per-row scales) halves the on-wire bytes vs bf16.  Backward is a
    straight-through estimator: the cotangent reduce-scatters back to the
    local shard at full precision (gradient fidelity preserved).
    """

    @jax.custom_vjp
    def f(w_local):
        return _fwd_impl(w_local)

    def _fwd_impl(w_local):
        wf = w_local.astype(jnp.float32)
        amax = jnp.max(jnp.abs(wf), axis=scale_axis, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        w_q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
        w_q_g = jax.lax.all_gather(w_q, axis, axis=gather_dim, tiled=True)
        scale_g = jax.lax.all_gather(scale, axis, axis=gather_dim, tiled=True)
        return (w_q_g.astype(jnp.float32) * scale_g).astype(jnp.bfloat16)

    def fwd(w_local):
        return _fwd_impl(w_local), None

    def bwd(_, g):
        # reduce-scatter in the cotangent's own dtype (bf16 for the giant
        # archs — matching what GSPMD's transpose of a bf16 gather does)
        g_local = jax.lax.psum_scatter(
            g, axis, scatter_dimension=gather_dim, tiled=True)
        return (g_local,)

    f.defvjp(fwd, bwd)
    return f


def _route(x2d: jax.Array, router_w: jax.Array, top_k: int):
    """Top-k routing in fp32. Returns (ids (N,k) int32, gates (N,k) f32,

    aux_loss scalar) with gates renormalised over the selected k."""
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    E = probs.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    aux = E * jnp.sum(me * ce)
    return ids, gates, aux


def _expert_ffn_local(
    x2d: jax.Array,             # (N, d) local tokens, compute dtype
    ids: jax.Array,             # (N, k)
    gates: jax.Array,           # (N, k) fp32
    w_gate: jax.Array,          # (E_local, d, f)
    w_up: jax.Array,
    w_out: jax.Array,           # (E_local, f, d)
    e_lo,                       # first local expert id (traced or 0)
    E_local: int,
    cap_per_expert: int,
) -> jax.Array:
    """Sort-by-expert + per-expert-capacity batched matmul over the local

    expert slice.  The (E_local, C, d) x (E_local, d, f) einsum lowers to a
    grouped/batched matmul on every backend with exactly E_local*C*d*f
    multiply-adds — unlike ragged_dot, whose CPU fallback loops over all
    groups (E_local x over-count, poisoning the dry-run roofline).
    Overflow beyond C tokens per expert is dropped Switch-style; empty slots
    ride along with gate 0.
    """
    N, k = ids.shape
    d = x2d.shape[1]
    C = cap_per_expert
    dtype = x2d.dtype
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    local = (flat_ids >= e_lo) & (flat_ids < e_lo + E_local)
    sort_key = jnp.where(local, flat_ids - e_lo, E_local)   # non-local last
    order = jnp.argsort(sort_key, stable=True)
    s_exp = sort_key[order]                                  # (N*k,) sorted
    s_tok = tok_idx[order]
    s_gate = jnp.where(local, flat_gates, 0.0)[order]

    # position of each row within its expert group
    counts = jnp.bincount(s_exp, length=E_local + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[s_exp].astype(jnp.int32)
    valid = (s_exp < E_local) & (pos < C)
    slot = jnp.where(valid, s_exp.astype(jnp.int32) * C + pos, E_local * C)

    # scatter token ids / gates into the (E_local*C,) slot grid
    tok_for_slot = jnp.zeros((E_local * C + 1,), jnp.int32).at[slot].set(
        s_tok, mode="drop")
    gate_for_slot = jnp.zeros((E_local * C + 1,), jnp.float32).at[slot].set(
        jnp.where(valid, s_gate, 0.0), mode="drop")
    tok_for_slot = tok_for_slot[:-1]
    gate_for_slot = gate_for_slot[:-1]

    xs = x2d[tok_for_slot].reshape(E_local, C, d)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(dtype)))
         * jnp.einsum("ecd,edf->ecf", xs, w_up.astype(dtype)))
    out = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dtype))
    out = out * gate_for_slot.reshape(E_local, C, 1).astype(dtype)

    y = jnp.zeros((N, d), dtype)
    y = y.at[tok_for_slot.reshape(-1)].add(out.reshape(E_local * C, d),
                                           mode="drop")
    return y


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig,
            ma: Optional[MeshAxes]) -> tuple[jax.Array, jax.Array]:
    """(B, S, d) -> (B, S, d); also returns the load-balancing aux loss."""
    assert cfg.moe is not None
    B, S, d = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    x2d = x.reshape(B * S, d)

    def cap_for(n_local: int) -> int:
        c = int(cdiv(n_local * k, E) * cfg.moe.capacity_factor) + 1
        return max(round_up(min(c, n_local * k), 4), 4)

    if ma is None or ma.mesh is None or ma.model_axis_size == 1:
        ids, gates, aux = _route(x2d, params["router"]["w"], k)
        y = _expert_ffn_local(
            x2d, ids, gates,
            params["experts"]["w_gate"], params["experts"]["w_up"],
            params["experts"]["w_out"], 0, E, cap_for(B * S))
        return y.reshape(B, S, d), aux

    # ---------------- EP path: shard_map over the whole mesh ----------------
    mesh = ma.mesh
    E_local = E // ma.model_axis_size
    n_batch_shards = int(np.prod([mesh.shape[a] for a in ma.batch]))
    shard_tokens = (B * S) % n_batch_shards == 0 and (B * S) >= n_batch_shards

    int8_gather = ma.fsdp and getattr(cfg.moe, "int8_fsdp_gather", False)

    if shard_tokens:
        # training/prefill: tokens sharded over batch axes, psum over model
        N_local = B * S // n_batch_shards
        cap = cap_for(N_local)

        def body(x_loc, router_w, w_gate, w_up, w_out):
            if int8_gather:
                # FSDP shards stay local; the gather rides int8 (§Perf A2)
                # per-f-row scales; the scale axis never coincides with
                # the gathered (FSDP) dim
                w_gate = _gathered_int8_fn(ma.data, 1, 2)(w_gate)
                w_up = _gathered_int8_fn(ma.data, 1, 2)(w_up)
                w_out = _gathered_int8_fn(ma.data, 2, 1)(w_out)
            ids, gates, aux = _route(x_loc, router_w, k)
            e_lo = jax.lax.axis_index(ma.model) * E_local
            y = _expert_ffn_local(x_loc, ids, gates, w_gate, w_up, w_out,
                                  e_lo, E_local, cap)
            y = jax.lax.psum(y, ma.model)
            aux = jax.lax.pmean(aux, ma.batch)
            return y, aux

        batch_sharded = P(ma.batch, None)
        if int8_gather:
            w_specs = (P(ma.model, ma.data, None), P(ma.model, ma.data, None),
                       P(ma.model, None, ma.data))
        else:
            w_specs = (P(ma.model, None, None), P(ma.model, None, None),
                       P(ma.model, None, None))
        y2d, aux = shard_map_unchecked(
            body, mesh,
            (batch_sharded, P(None, None)) + w_specs,
            (batch_sharded, P()),
        )(x2d, params["router"]["w"], params["experts"]["w_gate"],
          params["experts"]["w_up"], params["experts"]["w_out"])
        return y2d.reshape(B, S, d), aux

    # decode / tiny batches: tokens replicated, experts sharded; every
    # device computes its local experts' contribution for ALL tokens
    cap = cap_for(B * S)

    def body_rep(x_all, router_w, w_gate, w_up, w_out):
        ids, gates, aux = _route(x_all, router_w, k)
        e_lo = jax.lax.axis_index(ma.model) * E_local
        y = _expert_ffn_local(x_all, ids, gates, w_gate, w_up, w_out,
                              e_lo, E_local, cap)
        return jax.lax.psum(y, ma.model), aux

    y2d, aux = shard_map_unchecked(
        body_rep, mesh,
        (P(None, None), P(None, None), P(ma.model, None, None),
         P(ma.model, None, None), P(ma.model, None, None)),
        (P(None, None), P()),
    )(x2d, params["router"]["w"], params["experts"]["w_gate"],
      params["experts"]["w_up"], params["experts"]["w_out"])
    return y2d.reshape(B, S, d), aux
