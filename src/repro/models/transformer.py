"""Decoder-only transformer assembly: scan-over-periods + bottleneck boundaries.

Parameter layout (pytree):

  {"embeds": {...}, "final_norm": g,
   "seg0": {"period": {"b0": <block params, stacked (n_periods, ...)>,
                        "b1": ...}},
   "bnd0": {"boundary": <core.bottleneck params>,
            "bn_block": <block>, "post_block": <block>},     # replacement mode
   "seg1": {...}, ...}

Bottleneck boundaries (paper §4) come in two integration modes:

* ``replace`` (dense decoder stacks, the paper's own scheme): the block before
  the boundary is the *bottleneck block*, the one after is the
  *post-bottleneck block*; both live in the ``bndI`` subtree and are applied
  with partial-residual mixing (res_alpha).

* ``insert`` (ssm / hybrid / enc-dec): blocks are untouched; an
  encode→wire→decode pair is inserted between segments.  Noted in DESIGN.md
  §Arch-applicability — these families' recurrent/conv state never crosses a
  boundary, only the residual stream does.

Scanning is over *periods* (the repeating block-kind unit, see
``blocks.period_kinds``), so heterogeneous stacks (jamba 1:7, xlstm m/s
alternation) still lower to a single compiled period body.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bottleneck as bn
from repro.models import blocks as blk
from repro.models.layers import embed, init_embeddings, logits, norm_init, rmsnorm
from repro.sharding.partition import MeshAxes, batch_spec, shard_constraint

WIRE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Layout planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackLayout:
    period: tuple[str, ...]          # block kinds in one period
    seg_periods: tuple[int, ...]     # periods per segment (len = n_bnd + 1)
    mode: str                        # "replace" | "insert" | "none"

    @property
    def n_boundaries(self) -> int:
        return len(self.seg_periods) - 1

    def total_blocks(self) -> int:
        n = sum(self.seg_periods) * len(self.period)
        if self.mode == "replace":
            n += 2 * self.n_boundaries
        return n


def plan_layout(cfg: ModelConfig, decoder: bool = False) -> StackLayout:
    period = tuple(blk.period_kinds(cfg, decoder=decoder))
    plen = len(period)
    n_b = cfg.bottleneck.n_bottlenecks
    if n_b == 0:
        assert cfg.n_layers % plen == 0, (cfg.arch_id, cfg.n_layers, period)
        return StackLayout(period, (cfg.n_layers // plen,), "none")

    mode = "replace" if period == ("attn_dense",) or period == ("attn_moe",) \
        else "insert"
    if mode == "replace":
        # n_layers = scanned blocks + 2 per boundary (bn + post blocks)
        scanned = cfg.n_layers - 2 * n_b
        assert scanned >= 0, (cfg.n_layers, n_b)
        base, extra = divmod(scanned, n_b + 1)
        segs = tuple(base + (1 if i < extra else 0) for i in range(n_b + 1))
    else:
        n_periods = cfg.n_layers // plen
        assert n_periods >= n_b + 1, (cfg.arch_id, n_periods, n_b)
        base, extra = divmod(n_periods, n_b + 1)
        segs = tuple(base + (1 if i < extra else 0) for i in range(n_b + 1))
    return StackLayout(period, segs, mode)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_trees(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_segment(key, layout: StackLayout, n_periods: int, cfg: ModelConfig) -> dict:
    """Stacked params for one scanned segment of ``n_periods`` periods."""
    insts = []
    for p in range(n_periods):
        kp = jax.random.fold_in(key, p)
        inst = {f"b{i}": blk.init_block(jax.random.fold_in(kp, i), kind, cfg)
                for i, kind in enumerate(layout.period)}
        insts.append(inst)
    return {"period": _stack_trees(insts)}


def init_decoder_stack(key, cfg: ModelConfig, layout: StackLayout) -> dict:
    params: dict = {}
    for s, n_p in enumerate(layout.seg_periods):
        if n_p == 0:        # dense bottleneck packing leaves empty segments
            continue
        params[f"seg{s}"] = init_segment(
            jax.random.fold_in(key, 1000 + s), layout, n_p, cfg)
    for b in range(layout.n_boundaries):
        kb = jax.random.fold_in(key, 2000 + b)
        bnd: dict = {"boundary": bn.init_boundary(kb, cfg)}
        if layout.mode == "replace":
            kind = layout.period[0]
            bnd["bn_block"] = blk.init_block(jax.random.fold_in(kb, 1), kind, cfg)
            bnd["post_block"] = blk.init_block(jax.random.fold_in(kb, 2), kind, cfg)
        params[f"bnd{b}"] = bnd
    return params


def init_params(key, cfg: ModelConfig) -> dict:
    layout = plan_layout(cfg)
    k_e, k_s = jax.random.split(key)
    return {
        "embeds": init_embeddings(k_e, cfg),
        "final_norm": norm_init(cfg.d_model),
        **init_decoder_stack(k_s, cfg, layout),
    }


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------


def init_stack_state(cfg: ModelConfig, layout: StackLayout, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> dict:
    def period_state():
        return {f"b{i}": blk.init_block_state(kind, cfg, batch, max_len, dtype)
                for i, kind in enumerate(layout.period)}

    state: dict = {}
    for s, n_p in enumerate(layout.seg_periods):
        if n_p == 0:
            continue
        state[f"seg{s}"] = {"period": _stack_trees([period_state()
                                                    for _ in range(n_p)])}
    for b in range(layout.n_boundaries):
        if layout.mode == "replace":
            kind = layout.period[0]
            state[f"bnd{b}"] = {
                "bn_block": blk.init_block_state(kind, cfg, batch, max_len, dtype),
                "post_block": blk.init_block_state(kind, cfg, batch, max_len, dtype),
            }
        else:
            state[f"bnd{b}"] = {}
    return state


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    return init_stack_state(cfg, plan_layout(cfg), batch, max_len, dtype)


def stack_state_specs(cfg: ModelConfig, layout: StackLayout, ma, batch: int):
    """PartitionSpec tree mirroring ``init_stack_state`` (prepends the scan

    dim as replicated)."""
    from jax.sharding import PartitionSpec as P

    def period_spec():
        return {f"b{i}": blk.block_state_specs(kind, cfg, ma, batch)
                for i, kind in enumerate(layout.period)}

    def add_lead(tree):
        return jax.tree.map(lambda s: P(None, *s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    specs: dict = {}
    for s, n_p in enumerate(layout.seg_periods):
        if n_p == 0:
            continue
        specs[f"seg{s}"] = {"period": add_lead(period_spec())}
    for b in range(layout.n_boundaries):
        if layout.mode == "replace":
            kind = layout.period[0]
            specs[f"bnd{b}"] = {
                "bn_block": blk.block_state_specs(kind, cfg, ma, batch),
                "post_block": blk.block_state_specs(kind, cfg, ma, batch),
            }
        else:
            specs[f"bnd{b}"] = {}
    return specs


def decode_state_specs(cfg: ModelConfig, ma, batch: int):
    return stack_state_specs(cfg, plan_layout(cfg), ma, batch)


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _apply_segment(seg_params, x, ctx: blk.BlockCtx, layout: StackLayout,
                   seg_state, remat: bool):
    """Scan the stacked periods of one segment."""
    period = layout.period

    def period_fn(x, p_params, p_state):
        aux = jnp.zeros((), jnp.float32)
        new_state = {}
        for i, kind in enumerate(period):
            st = None if p_state is None else p_state[f"b{i}"]
            x, ns, a = blk.apply_block(kind, p_params[f"b{i}"], x, ctx, st)
            if p_state is not None:
                new_state[f"b{i}"] = ns
            aux = aux + a
        x = shard_constraint(x, batch_spec(ctx.ma, None, None))
        return x, new_state if p_state is not None else None, aux

    if remat:
        period_fn = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.nothing_saveable)

    has_state = seg_state is not None

    def scan_body(carry, xs):
        x, aux = carry
        p_params, p_state = xs
        x, ns, a = period_fn(x, p_params, p_state)
        return (x, aux + a), ns

    xs = (seg_params["period"], seg_state["period"] if has_state else None)
    if not has_state:
        # scan requires xs trees with a leading axis; params provide it.
        (x, aux), _ = jax.lax.scan(
            lambda c, p: scan_body(c, (p, None)),
            (x, jnp.zeros((), jnp.float32)), seg_params["period"])
        return x, None, aux
    (x, aux), new_state = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, {"period": new_state}, aux


def apply_stack(params, x, ctx: blk.BlockCtx, layout: StackLayout,
                state=None, remat: bool = True,
                capture_wire: Optional[list] = None):
    """Run segments + boundaries. ``capture_wire`` (a list) collects the wire

    codes z at each boundary — used by tests and the pipeline engine."""
    cfg = ctx.cfg
    aux_total = jnp.zeros((), jnp.float32)
    new_state: dict = {}
    n_seg = len(layout.seg_periods)
    for s in range(n_seg):
        if f"seg{s}" in params:       # zero-period segments are omitted
            seg_state = None if state is None else state[f"seg{s}"]
            x, ns, aux = _apply_segment(
                params[f"seg{s}"], x, ctx, layout, seg_state, remat)
            if state is not None:
                new_state[f"seg{s}"] = ns
            aux_total = aux_total + aux

        if s < n_seg - 1:
            bnd = params[f"bnd{s}"]
            bp = bnd["boundary"]
            bnd_state_new = {}
            if layout.mode == "replace":
                kind = layout.period[0]
                st = None if state is None else state[f"bnd{s}"]["bn_block"]
                x, ns1, a1 = blk.apply_block(
                    kind, bnd["bn_block"], x, ctx, st,
                    res_alpha=bp["alpha_enc"])
                z = bn.encode(bp, x, cfg, WIRE_DTYPE)            # ---- wire ----
                if capture_wire is not None:
                    capture_wire.append(z)
                r = bn.decode(bp, z, cfg, x.dtype)
                st = None if state is None else state[f"bnd{s}"]["post_block"]
                r2, ns2, a2 = blk.apply_block(
                    kind, bnd["post_block"], r, ctx, st,
                    res_alpha=bp["alpha_dec"])
                x = r2
                aux_total = aux_total + a1 + a2
                if state is not None:
                    bnd_state_new = {"bn_block": ns1, "post_block": ns2}
            else:  # insert
                z = bn.encode(bp, x, cfg, WIRE_DTYPE)            # ---- wire ----
                if capture_wire is not None:
                    capture_wire.append(z)
                x = bp["alpha_dec"].astype(x.dtype) * bn.decode(bp, z, cfg, x.dtype)
            if state is not None:
                new_state[f"bnd{s}"] = bnd_state_new
    return x, (new_state if state is not None else None), aux_total


def forward(
    params: dict,
    tokens: jax.Array,                  # (B, S) int32
    cfg: ModelConfig,
    ma: Optional[MeshAxes] = None,
    *,
    state: Optional[dict] = None,       # decode state (KV caches etc.)
    positions: Optional[jax.Array] = None,
    vision_embeds: Optional[jax.Array] = None,   # (B, P, d_model) VLM frontend
    remat: bool = True,
    compute_dtype=jnp.bfloat16,
    capture_wire: Optional[list] = None,
):
    """Returns (logits (B, S_text, padded_vocab) f32, new_state, aux_loss)."""
    layout = plan_layout(cfg)
    B, S = tokens.shape
    x = embed(params["embeds"], tokens, cfg, ma, compute_dtype)
    n_front = 0
    if vision_embeds is not None:
        n_front = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(compute_dtype), x], axis=1)

    if positions is None:
        if state is not None:
            length = _state_length(state)
            positions = length + jnp.arange(S + n_front, dtype=jnp.int32)[None]
            positions = jnp.broadcast_to(positions, (B, S + n_front))
        else:
            positions = jnp.broadcast_to(
                jnp.arange(S + n_front, dtype=jnp.int32)[None], (B, S + n_front))

    ctx = blk.BlockCtx(cfg=cfg, ma=ma, positions=positions)
    x, new_state, aux = apply_stack(params, x, ctx, layout, state, remat,
                                    capture_wire)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if n_front:
        x = x[:, n_front:, :]
    lgts = logits(params["embeds"], x, cfg, ma)
    return lgts, new_state, aux


def _state_length(state) -> jax.Array:
    """Fish the scalar cache length out of a decode-state pytree."""
    from repro.models.layers import KVCache
    found = []

    def visit(node):
        if isinstance(node, KVCache):
            found.append(node.length if node.length.ndim == 0 else node.length[0])
            return
        if isinstance(node, dict):
            for v in node.values():
                visit(v)

    visit(state)
    if not found:
        return jnp.zeros((), jnp.int32)
    return found[0]
