"""Unified Model: one object per assigned architecture.

Wraps the family-specific assemblies behind a single interface used by the
launcher, dry-run, runtime simulation, tests and benchmarks:

    model = build_model(arch_cfg)
    params       = model.init(key)
    state        = model.init_train_state(key)
    new_state, m = model.train_step(state, batch, ma)       # grad-accum inside
    logits, ...  = model.prefill_step(params, batch, ma)
    logits, st   = model.decode_step(params, dec_state, batch, ma)
    specs        = model.input_specs(shape)                  # ShapeDtypeStructs
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    AUDIO,
    VLM,
    ArchConfig,
    ModelConfig,
    ShapeConfig,
)
from repro.models import encdec, frontends, transformer
from repro.models.layers import next_token_loss
from repro.optim import make_optimizer
from repro.common import global_norm
from repro.sharding.partition import MeshAxes

AUX_LOSS_WEIGHT = 0.01


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.mcfg = cfg.model
        self.optimizer = make_optimizer(cfg.parallel, cfg.train)
        self._is_encdec = self.mcfg.is_encoder_decoder

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def init(self, key) -> dict:
        if self._is_encdec:
            params = encdec.init_params(key, self.mcfg)
        else:
            params = transformer.init_params(key, self.mcfg)
        pd = jnp.dtype(self.cfg.parallel.param_dtype)
        if pd != jnp.float32:
            params = jax.tree.map(lambda x: x.astype(pd), params)
        return params

    def init_train_state(self, key) -> TrainState:
        params = self.init(key)
        return TrainState(params=params,
                          opt_state=self.optimizer.init(params),
                          step=jnp.zeros((), jnp.int32))

    def abstract_train_state(self, key=None) -> TrainState:
        key = key if key is not None else jax.random.key(0)
        return jax.eval_shape(self.init_train_state, key)

    def init_decode_state(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self._is_encdec:
            return encdec.init_decode_state(self.mcfg, batch, max_len, dtype)
        return transformer.init_decode_state(self.mcfg, batch, max_len, dtype)

    # ------------------------------------------------------------------
    # forward / loss
    # ------------------------------------------------------------------

    def forward(self, params, batch: dict, ma: Optional[MeshAxes],
                state=None, capture_wire=None):
        m = self.mcfg
        remat = self.cfg.parallel.remat
        if self._is_encdec:
            return encdec.forward(
                params, batch["tokens"], m, ma,
                frames=batch.get("frames"), memory=batch.get("memory"),
                state=state, remat=remat, capture_wire=capture_wire)
        return transformer.forward(
            params, batch["tokens"], m, ma, state=state,
            vision_embeds=batch.get("vision_embeds"), remat=remat,
            capture_wire=capture_wire)

    def loss_fn(self, params, batch: dict, ma: Optional[MeshAxes]):
        lgts, _, aux = self.forward(params, batch, ma)
        loss = next_token_loss(lgts, batch["labels"], self.cfg.train.z_loss)
        total = loss + AUX_LOSS_WEIGHT * aux
        return total, {"loss": loss, "aux_loss": aux}

    # ------------------------------------------------------------------
    # train step (with microbatch gradient accumulation)
    # ------------------------------------------------------------------

    def train_step(self, state: TrainState, batch: dict,
                   ma: Optional[MeshAxes] = None,
                   sync_axes: Optional[tuple[str, ...]] = None):
        """One optimizer step.  ``sync_axes`` limits the gradient psum (DiLoCo

        inner steps pass ("data","model") so the ``pod`` axis stays local);
        None means full sync via jit's automatic reduction."""
        accum = self.cfg.parallel.grad_accum
        # each microbatch must still divide the batch shards, or GSPMD is
        # forced into full rematerialization of the activation constraints
        batch_size = batch["tokens"].shape[0]
        if ma is not None:
            accum = max(min(accum, batch_size // ma.batch_shard_total), 1)
        while batch_size % accum != 0:
            accum -= 1
        grad_fn = jax.value_and_grad(
            lambda p, b: self.loss_fn(p, b, ma), has_aux=True)

        if accum == 1:
            (_, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            zeros_m = {"loss": jnp.zeros((), jnp.float32),
                       "aux_loss": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_body, (zeros_g, zeros_m), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, metrics)

        if sync_axes:
            grads = jax.lax.pmean(grads, sync_axes)

        gnorm = global_norm(grads)
        clip = self.cfg.train.grad_clip
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-6)) if clip else 1.0
        grads = jax.tree.map(lambda g: g * scale, grads)

        new_params, new_opt = self.optimizer.update(
            grads, state.opt_state, state.params, state.step)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def prefill_step(self, params, batch: dict, ma: Optional[MeshAxes] = None):
        lgts, _, _ = self.forward(params, batch, ma)
        return lgts

    def decode_step(self, params, dec_state, batch: dict,
                    ma: Optional[MeshAxes] = None):
        """One new token against a populated cache; returns (logits, state)."""
        lgts, new_state, _ = self.forward(params, batch, ma, state=dec_state)
        return lgts, new_state

    # ------------------------------------------------------------------
    # input specs (ShapeDtypeStruct stand-ins — no allocation)
    # ------------------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> dict:
        """Abstract inputs for jit(...).lower() for this (arch x shape)."""
        m = self.mcfg
        B, S = shape.global_batch, shape.seq_len
        i32, bf16 = jnp.int32, jnp.bfloat16
        sds = jax.ShapeDtypeStruct

        if shape.kind == "train":
            if m.family == AUDIO:
                F = frontends.audio_frames_for_seq(S)
                return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32),
                        "frames": sds((B, F, m.d_model), bf16)}
            if m.family == VLM:
                S_text = S - m.frontend_tokens
                return {"tokens": sds((B, S_text), i32),
                        "labels": sds((B, S_text), i32),
                        "vision_embeds": sds((B, m.frontend_tokens, m.d_model), bf16)}
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}

        if shape.kind == "prefill":
            if m.family == AUDIO:
                F = frontends.audio_frames_for_seq(S)
                return {"tokens": sds((B, S), i32),
                        "frames": sds((B, F, m.d_model), bf16)}
            if m.family == VLM:
                return {"tokens": sds((B, S - m.frontend_tokens), i32),
                        "vision_embeds": sds((B, m.frontend_tokens, m.d_model), bf16)}
            return {"tokens": sds((B, S), i32)}

        # decode: one new token, cache of length S supplied separately
        batch = {"tokens": sds((B, 1), i32)}
        if m.family == AUDIO:
            F = frontends.audio_frames_for_seq(S)
            batch["memory"] = sds((B, F, m.d_model), bf16)
        return batch

    def decode_state_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16):
        assert shape.kind == "decode"
        return jax.eval_shape(
            partial(self.init_decode_state, shape.global_batch,
                    shape.seq_len, dtype))

    # ------------------------------------------------------------------

    def synth_batch(self, key, shape_or_bs, seq_len: Optional[int] = None) -> dict:
        """Concrete synthetic batch (smoke tests / examples)."""
        m = self.mcfg
        if isinstance(shape_or_bs, ShapeConfig):
            B, S = shape_or_bs.global_batch, shape_or_bs.seq_len
        else:
            B, S = shape_or_bs, seq_len
        ks = jax.random.split(key, 3)
        toks = jax.random.randint(ks[0], (B, S + 1), 0, m.vocab_size, jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if m.family == AUDIO:
            F = frontends.audio_frames_for_seq(S)
            batch["frames"] = frontends.audio_frame_embeds(ks[1], B, F, m.d_model)
        if m.family == VLM and m.frontend_tokens:
            batch["vision_embeds"] = frontends.vision_patch_embeds(
                ks[2], B, m.frontend_tokens, m.d_model)
        return batch


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
