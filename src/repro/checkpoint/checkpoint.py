"""Checkpointing: atomic, integrity-checked, async-capable, elastic.

Fault-tolerance contract (the large-scale runnability requirement):
  * atomic: write to ``<dir>.tmp`` then rename — a crash mid-write never
    corrupts the latest checkpoint;
  * integrity: every array file carries a blake2b digest in the manifest;
    restore verifies before handing state back;
  * async: ``CheckpointManager(async_save=True)`` snapshots device arrays to
    host then writes on a worker thread — the training loop never blocks on
    disk (the paper's miners upload weights to S3 mid-epoch the same way);
  * elastic: restore works with a *different* miner count / data shard count
    than save (the cursor is global-step based, and butterfly merge state is
    reconstructed from params alone — new miners "copy existing miners'
    state" per paper §2.2).

Format: one ``.npy`` per leaf + JSON manifest (paths, shapes, dtypes,
digests, user metadata).  No orbax dependency — keeps offline installs tiny.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.common import tree_paths


class SnapshotCorrupt(IOError):
    """A digest-verified restore found bytes that don't match the manifest.

    Typed (rather than a bare ``IOError``/assert) so crash-recovery layers —
    ``runtime.snapshot_cache.DiskSnapshotCache`` — can catch *exactly* this
    condition and fall back to the previous good snapshot, while genuine
    I/O errors (missing file, permission) still propagate.
    """

    def __init__(self, directory: str, leaf_path: str):
        super().__init__(
            f"checkpoint corruption detected at leaf '{leaf_path}' "
            f"in {directory}")
        self.directory = directory
        self.leaf_path = leaf_path


def _digest(arr: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def save_pytree(tree: Any, directory: str, metadata: Optional[dict] = None) -> None:
    """Atomic synchronous save."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = jax.tree_util.tree_leaves(tree)
    paths = tree_paths(tree)
    manifest = {"leaves": [], "metadata": metadata or {}}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        digest = _digest(arr)
        dtype_name = str(arr.dtype)
        # numpy can't serialise ml_dtypes (bfloat16 etc.) natively: store the
        # raw bits as a same-width uint view and reconstruct on restore
        if arr.dtype.kind == "V" or dtype_name not in np.sctypeDict:
            arr = arr.view({1: np.uint8, 2: np.uint16,
                            4: np.uint32, 8: np.uint64}[arr.dtype.itemsize])
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": dtype_name, "digest": digest,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_pytree(template: Any, directory: str,
                   verify: bool = True) -> tuple[Any, dict]:
    """Restore into the structure of ``template``; returns (tree, metadata).

    Leaf matching is by tree-path string, so a template whose *unrelated*
    parts changed (e.g. optimizer swapped) still restores the params that
    match — partial/elastic restore.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    leaves, treedef = jax.tree_util.tree_flatten(template)
    paths = tree_paths(template)
    out = []
    for path, leaf in zip(paths, leaves):
        entry = by_path.get(path)
        if entry is None:
            out.append(leaf)               # keep template value (new state)
            continue
        arr = np.load(os.path.join(directory, entry["file"]))
        if str(arr.dtype) != entry["dtype"]:
            # raw-bits view round trip for non-native dtypes (bfloat16 ...)
            import ml_dtypes  # noqa: F401 — registers the dtypes
            arr = arr.view(np.dtype(entry["dtype"]))
        if verify and _digest(arr) != entry["digest"]:
            raise SnapshotCorrupt(directory, path)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


@dataclasses.dataclass
class CheckpointManager:
    """Rolling step-indexed checkpoints with optional async writes."""
    root: str
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        os.makedirs(self.root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        meta = dict(metadata or {}, step=step)
        # snapshot to host NOW so the caller can mutate device state freely
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_pytree(host_tree, self._step_dir(step), meta)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, template: Any, step: Optional[int] = None
                ) -> tuple[Any, dict]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return restore_pytree(template, self._step_dir(step))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
