from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    SnapshotCorrupt,
    restore_pytree,
    save_pytree,
)
