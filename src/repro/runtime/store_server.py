"""``StoreServer``: the paper's globally accessible store as a real process.

Everything before this module simulated the hub — ``InProcessTransport``
is a dict lookup, ``SimulatedNetworkTransport`` only models links.  Here
the authoritative ``StateStore`` lives behind a length-prefixed TCP
socket, so miners/validators/orchestrator traffic genuinely crosses a
process (or host) boundary, exactly the §2 hub-and-spoke deployment:
the store is the only shared surface, and byte/digest accounting happens
*server-side*, where peers cannot fudge it.

Protocol: one ``serde`` frame per request/response (u64 length + tagged
binary body; see ``repro.api.serde`` — no pickle, peers never ship
bytecode).  Requests are dicts ``{"op": ..., ...}``; responses are
``{"ok": True, ...}`` or ``{"ok": False, "error": ..., ...}``.  A missing
key returns the full ``StoreKeyError`` context (key, actor, nearest
existing prefix) so the client can re-raise the *same* exception the
in-process transports raise — the failure surface is transport-invariant.

Ops: ``put`` (value + optional server-side codec; returns digest+nbytes),
``get`` (returns payload+nbytes+digest), ``exists``, ``delete_prefix``,
``keys``, ``traffic_report``, ``ping``, ``reset`` (fresh store — lets one
server host consecutive independent runs), ``shutdown``.

Run it three ways:

  * ``StoreServer().start()``      — daemon thread, same process (tests,
                                     benchmarks: real sockets, no spawn
                                     cost);
  * ``spawn_store_server()``       — separate OS process via the
                                     multiprocessing ``spawn`` context
                                     (examples/multiprocess_swarm.py);
  * ``python -m repro.runtime.store_server --port P`` — standalone
                                     (multi-host; bind a routable host).
"""
from __future__ import annotations

import argparse
import socket
import socketserver
import threading
from typing import Any, Optional

from repro.api import serde
from repro.runtime.state_store import StateStore, StoreKeyError


class _Handler(socketserver.BaseRequestHandler):
    """One peer connection: frames in, frames out, until EOF."""

    def setup(self) -> None:  # pragma: no cover - exercised via sockets
        # register so StoreServer.stop() can close this socket and join
        # this thread deterministically (daemon_threads=True means the
        # stdlib's own _Threads bookkeeping skips us)
        self.server.track_handler(threading.current_thread(), self.request)

    def finish(self) -> None:  # pragma: no cover - exercised via sockets
        self.server.untrack_handler(threading.current_thread())

    def handle(self) -> None:  # pragma: no cover - exercised via sockets
        self.request.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while True:
            try:
                body = serde.recv_frame(self.request)
            except OSError:
                # stop()/fail_primary() closed this socket under a blocked
                # recv — a normal shutdown path, not a request error
                return
            if body is None:
                return
            try:
                req = serde.loads(body)
                resp = self.server.dispatch(req)
            except StoreKeyError as e:
                resp = {"ok": False, "error": "StoreKeyError", "key": e.key,
                        "actor": e.actor, "nearest_prefix": e.nearest_prefix,
                        "nearest_count": e.nearest_count}
            except Exception as e:  # noqa: BLE001 - report, don't die
                resp = {"ok": False, "error": type(e).__name__,
                        "message": str(e)}
            try:
                frame = serde.dumps(resp)
            except Exception as e:  # noqa: BLE001 - e.g. a shared in-process
                # store holding a payload serde cannot encode: still reply
                frame = serde.dumps({
                    "ok": False, "error": type(e).__name__,
                    "message": f"response serialization failed: {e}"})
            serde.send_frame(self.request, frame)
            if req_is_shutdown(resp):
                # respond first, then stop the accept loop; shutdown() only
                # signals serve_forever, so calling it from a handler thread
                # cannot deadlock
                self.server.shutdown()
                return


def req_is_shutdown(resp: dict) -> bool:
    return bool(resp.get("ok")) and resp.get("op") == "shutdown"


class StoreServer(socketserver.ThreadingTCPServer):
    """Threaded TCP server owning the authoritative ``StateStore``.

    One lock serializes store access (the store is a plain dict + counters;
    requests are short).  ``address`` reports the actually-bound (host,
    port) — construct with ``port=0`` to let the OS pick."""

    daemon_threads = True
    allow_reuse_address = True

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 store: Optional[StateStore] = None):
        super().__init__((host, port), _Handler)
        self.store = store or StateStore()
        self._lock = threading.Lock()
        # blocking waits: handlers park here (lock released) until a put
        # lands, so pull-based actors cost zero CPU while idle
        self._cond = threading.Condition(self._lock)
        self._stopping = False
        self._thread: Optional[threading.Thread] = None
        self._handlers: dict = {}          # thread -> client socket
        self._handlers_lock = threading.Lock()
        # warm-standby replication (docs/CHAOS.md): socket to a mirror
        # server that receives every mutation synchronously
        self._mirror_sock: Optional[socket.socket] = None
        self._mirror_addr: Optional[tuple] = None

    # -- handler bookkeeping (deterministic shutdown) ---------------------

    def track_handler(self, thread: threading.Thread,
                      sock: socket.socket) -> None:
        with self._handlers_lock:
            self._handlers[thread] = sock

    def untrack_handler(self, thread: threading.Thread) -> None:
        with self._handlers_lock:
            self._handlers.pop(thread, None)

    @property
    def address(self) -> tuple[str, int]:
        host, port = self.server_address[:2]
        return host, port

    # -- warm-standby mirroring ------------------------------------------

    def mirror_to(self, address: tuple) -> None:
        """Synchronously replicate every mutation (``put``,
        ``delete_prefix``, ``reset``) to a warm-standby ``StoreServer`` at
        ``address``.  Forwarding happens under the dispatch lock, so the
        standby sees mutations in exactly the primary's serialization
        order — when the primary dies, clients failing over
        (``SocketTransport(failover=...)``) find an identical store.

        Connects eagerly (a missing standby at setup is an operator
        error); a standby dying *later* degrades silently — the primary
        keeps serving, replication just stops (logged once on stderr)."""
        addr = (str(address[0]), int(address[1]))
        sock = socket.create_connection(addr, timeout=10.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        with self._lock:
            self._mirror_addr = addr
            self._mirror_sock = sock

    def _mirror(self, req: dict) -> None:
        """Forward one mutation to the standby (caller holds the lock)."""
        if self._mirror_sock is None:
            return
        try:
            serde.send_frame(self._mirror_sock, serde.dumps(req))
            body = serde.recv_frame(self._mirror_sock)
            if body is None:
                raise ConnectionError("standby closed the connection")
        except Exception as e:  # noqa: BLE001 - degrade, don't die
            import sys
            print(f"store mirror to {self._mirror_addr} lost ({e}); "
                  f"continuing unreplicated", file=sys.stderr, flush=True)
            try:
                self._mirror_sock.close()
            except OSError:
                pass
            self._mirror_sock = None

    # -- request dispatch ------------------------------------------------

    def dispatch(self, req: dict) -> dict:
        op = req.get("op")
        with self._lock:
            if op == "put":
                entry = self.store.put(
                    req["key"], req["value"], actor=req.get("actor", "?"),
                    codec=req.get("codec"), meta=req.get("meta"))
                self._cond.notify_all()      # wake any blocked "wait" ops
                self._mirror(req)
                return {"ok": True, "digest": entry.digest,
                        "nbytes": entry.nbytes}
            if op == "wait":
                # block (lock released by the condition) until the key
                # exists or the slice expires; the slice is capped so a
                # stopping server never parks a handler for long — clients
                # loop on {"exists": False}
                import time as _time
                deadline = _time.monotonic() + min(
                    float(req.get("timeout", 0.5)), 5.0)
                while not self.store.exists(req["key"]):
                    remaining = deadline - _time.monotonic()
                    if remaining <= 0 or self._stopping:
                        return {"ok": True, "exists": False}
                    self._cond.wait(remaining)
                return {"ok": True, "exists": True}
            if op == "get":
                entry = self.store.fetch_entry(req["key"],
                                               actor=req.get("actor", "?"))
                return {"ok": True, "value": entry.payload,
                        "nbytes": entry.nbytes, "digest": entry.digest}
            if op == "exists":
                return {"ok": True, "exists": self.store.exists(req["key"])}
            if op == "delete_prefix":
                deleted = self.store.delete_prefix(req["prefix"])
                self._mirror(req)
                return {"ok": True, "deleted": deleted}
            if op == "keys":
                return {"ok": True,
                        "keys": self.store.keys(req.get("prefix", ""))}
            if op == "traffic_report":
                return {"ok": True, "report": self.store.traffic_report()}
            if op == "reset":
                self.store = StateStore()
                self._cond.notify_all()      # waiters re-check the new store
                self._mirror(req)
                return {"ok": True}
            if op == "ping":
                import os
                return {"ok": True, "pid": os.getpid(),
                        "n_keys": len(self.store.keys())}
            if op == "shutdown":
                return {"ok": True, "op": "shutdown"}
        return {"ok": False, "error": "UnknownOp", "message": repr(op)}

    # -- lifecycle -------------------------------------------------------

    def start(self) -> "StoreServer":
        """Serve from a daemon thread (in-process tests/benchmarks)."""
        self._thread = threading.Thread(target=self.serve_forever,
                                        name="store-server", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Deterministic teardown: stop accepting, close every live client
        socket (unblocks handler threads parked in ``recv``), join the
        handlers, close the listening socket, join the serve thread.
        After ``stop()`` returns no server thread or socket survives."""
        self._stopping = True
        with self._lock:
            self._cond.notify_all()   # unpark blocked "wait" handlers now
            if self._mirror_sock is not None:
                try:
                    self._mirror_sock.close()
                except OSError:
                    pass
                self._mirror_sock = None
        self.shutdown()
        self.close_handlers()
        self.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def close_handlers(self, timeout: float = 5.0) -> None:
        with self._handlers_lock:
            handlers = list(self._handlers.items())
        for thread, sock in handlers:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass   # peer already gone
            try:
                sock.close()
            except OSError:
                pass
        me = threading.current_thread()
        for thread, _ in handlers:
            if thread is not me:   # shutdown op: a handler may run stop()
                thread.join(timeout=timeout)


# ---------------------------------------------------------------------------
# process entry points
# ---------------------------------------------------------------------------


def serve(host: str = "127.0.0.1", port: int = 0,
          ready_queue: Any = None) -> None:
    """Blocking entry point for a dedicated store process.  Puts the bound
    (host, port) on ``ready_queue`` (if given) once accepting, so the
    parent can pass ``port=0`` and still learn the address."""
    server = StoreServer(host, port)
    if ready_queue is not None:
        ready_queue.put(server.address)
    try:
        server.serve_forever()
    finally:
        # same deterministic teardown as stop(): the spawn child exits
        # with no orphaned handler threads holding sockets open
        server._stopping = True
        with server._lock:
            server._cond.notify_all()
        server.close_handlers()
        server.server_close()


def spawn_store_server(host: str = "127.0.0.1"):
    """Launch a store server in a separate OS process (``spawn`` context —
    the child re-imports cleanly instead of forking a jax-initialized
    interpreter).  Returns ``(process, (host, port))``; blocks until the
    child is accepting connections.  Stop it with
    ``SocketTransport.stop_server()`` or ``process.terminate()``."""
    import multiprocessing as mp
    import queue as queue_mod

    ctx = mp.get_context("spawn")
    queue = ctx.Queue()
    proc = ctx.Process(target=serve, args=(host, 0, queue), daemon=True,
                       name="store-server")
    proc.start()
    while True:          # a crashed child would otherwise hang .get() forever
        try:
            address = queue.get(timeout=0.5)
            break
        except queue_mod.Empty:
            if not proc.is_alive():
                raise RuntimeError(
                    f"store server process died before binding "
                    f"(exit code {proc.exitcode})") from None
    return proc, address


def main(argv: Optional[list] = None) -> None:  # pragma: no cover - CLI
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8799)
    args = p.parse_args(argv)
    print(f"store server listening on {args.host}:{args.port}", flush=True)
    serve(args.host, args.port)


if __name__ == "__main__":  # pragma: no cover
    main()
