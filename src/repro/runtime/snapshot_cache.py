"""``DiskSnapshotCache``: crash-recovery snapshots for swarm actors.

The icetrust production pattern (ROADMAP item 2): every miner keeps a
small rolling cache of *local* epoch-boundary snapshots on disk, written
atomically (``checkpoint.save_pytree``'s tmp+rename) and restored with
digest verification.  A killed miner process respawns, restores the
newest good snapshot, and replays forward from the store's ``control/``
watermarks — instead of re-deriving epoch 0 state from the seed and
poisoning the epoch it rejoins.

Corruption handling (the reason restores go through the typed
``SnapshotCorrupt``): a crash *during* a write can't corrupt anything
(atomic rename), but disks rot and operators truncate files.  On a
digest mismatch ``restore_latest`` quarantines the bad epoch directory
(renames it ``ep_NNNN.corrupt`` so it is never retried and an operator
can inspect it) and falls back to the previous good snapshot.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Optional

from repro.checkpoint.checkpoint import (
    SnapshotCorrupt,
    restore_pytree,
    save_pytree,
)


class DiskSnapshotCache:
    """Rolling per-actor cache of epoch-boundary snapshots.

    Layout: ``<root>/ep_00000003/`` (one ``save_pytree`` dir per epoch).
    ``keep`` bounds disk usage; at least 2 are kept so a corrupt newest
    snapshot always has a fallback.
    """

    def __init__(self, root: str, keep: int = 2):
        assert keep >= 2, "keep >= 2: corruption fallback needs a spare"
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def _dir(self, epoch: int) -> str:
        return os.path.join(self.root, f"ep_{epoch:08d}")

    def epochs(self) -> list[int]:
        """Epochs with a (non-quarantined, non-tmp) snapshot, ascending."""
        out = []
        for name in os.listdir(self.root):
            if not name.startswith("ep_") or "." in name:
                continue   # skips ep_*.tmp and ep_*.corrupt
            try:
                out.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_epoch(self) -> Optional[int]:
        eps = self.epochs()
        return eps[-1] if eps else None

    def save(self, epoch: int, tree: Any,
             metadata: Optional[dict] = None) -> None:
        """Atomically snapshot ``tree`` for ``epoch``, then GC old epochs."""
        save_pytree(tree, self._dir(epoch),
                    dict(metadata or {}, epoch=epoch))
        for old in self.epochs()[:-self.keep]:
            shutil.rmtree(self._dir(old), ignore_errors=True)

    def restore(self, template: Any, epoch: int) -> tuple[Any, dict]:
        return restore_pytree(template, self._dir(epoch))

    def restore_latest(self, template: Any
                       ) -> Optional[tuple[int, Any, dict]]:
        """Restore the newest snapshot that verifies.

        Returns ``(epoch, tree, metadata)``, or ``None`` when no usable
        snapshot exists (fresh actor — derive state from the seed).  A
        snapshot failing digest verification is quarantined and the next
        older one is tried.
        """
        for epoch in reversed(self.epochs()):
            try:
                tree, meta = self.restore(template, epoch)
                return epoch, tree, meta
            except SnapshotCorrupt:
                self._quarantine(epoch)
        return None

    def _quarantine(self, epoch: int) -> None:
        src = self._dir(epoch)
        dst = src + ".corrupt"
        shutil.rmtree(dst, ignore_errors=True)
        try:
            os.rename(src, dst)
        except OSError:
            shutil.rmtree(src, ignore_errors=True)
