"""Concurrent actor runtime: miners/validators as real OS processes.

The paper's SWARM peers (§2) are autonomous workers polling a globally
accessible store — no global barrier stepping.  Everything before this
module simulated that: PR 5 made the *store* a process, but every actor
still took turns inside one Python loop.  Here each miner and validator
is a ``spawn``-context process with its own ``SocketTransport`` (its
thread-safe store handle), pulling work off the store through a
``WorkQueue`` and publishing results the ``EventDriver``
(``repro.api.phases``) advances on.

Process model:

  * ``ActorProcess``   base: spawn entry, per-actor store connection, a
                       tiny TCP *health endpoint* (serde frames; ``ping``
                       answers a ``HeartbeatMsg`` envelope, ``stop``
                       requests a clean exit), the epoch loop (await
                       plan -> process -> next), clean shutdown;
  * ``MinerActor``     wraps a ``runtime.Miner``: derives its tick jobs
                       from the plan, awaits each input activation,
                       forwards/backwards, publishes activations,
                       gradients, the tick-loss watermark, its weight
                       upload and (sharded) its reduce work;
  * ``ValidatorActor`` replays its tracked miner from the store alone —
                       snapshot + activations + gradients + labels —
                       mirroring ``Validator.validate_epoch`` bit-exactly,
                       and publishes the ``ScoreMsg`` watermark;
  * ``ActorSupervisor``spawns/pings/stops the fleet and turns a dead
                       child into ``ActorDied`` instead of a hang;
  * ``ActorSwarm``     the ``Swarm`` facade over all of it —
                       ``Swarm.create(..., runtime="actors")`` builds one.

Determinism: the driver does every swarm RNG draw at plan time in the
lockstep order; actors interact only through bit-exact store payloads
and each actor processes its own jobs in tick order, so per-miner update
sequences — and the loss trajectory — equal the in-process oracle at the
same seed.  Payload-corrupting faults (tamper, free-ride) run *inside*
the owning actor (each child seeds its own fault RNG from the spec), so
adversarial scenarios work under the concurrent runtime too;
drop/straggle stay schedule-only (plan-time rolls in the parent).

Chaos additions (docs/CHAOS.md):

  * crash-resume — ``ActorSpec.snapshot_dir`` gives a miner a
    ``DiskSnapshotCache``; it snapshots at every epoch boundary and a
    respawned process restores the newest good snapshot, catches up to
    the newest visible anchor of its stage and fast-forwards to the
    in-flight epoch;
  * plan revisions — when the ``EventDriver`` re-plans around a death it
    publishes ``control/ep{E}/plan/r{R}``; blocked actors notice via the
    ``WorkQueue.abort_if`` hook (``WorkRescheduled``) and re-derive
    their work from the latest revision;
  * fault injection — ``ActorSpec.chaos`` wraps the child's transport in
    a seeded ``ChaosTransport``; ``ActorSpec.store_failover`` hands the
    child the warm-standby store addresses.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import serde
from repro.api.config import SwarmConfig
from repro.api.keys import KeySchema
from repro.api.messages import (
    ActivationMsg,
    AnchorMsg,
    GradientMsg,
    HeartbeatMsg,
    ScoreMsg,
    SnapshotMsg,
    TickLossMsg,
    WeightUploadMsg,
)
from repro.api.phases import EventDriver, StageServer
from repro.api.swarm import Swarm
from repro.api.transport import SocketTransport
from repro.common import cosine_similarity
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import butterfly, compression
from repro.optim import adamw
from repro.optim.schedules import cosine_warmup
from repro.runtime import stage_model as sm
from repro.runtime.chaos import wrap_transport
from repro.runtime.miner import Miner
from repro.runtime.network import FaultModel, MinerBehavior
from repro.runtime.snapshot_cache import DiskSnapshotCache
from repro.runtime.validator import COSINE_THRESHOLD


class ActorStopped(Exception):
    """Raised inside an actor when a stop request interrupts polling."""


class WorkRescheduled(Exception):
    """The work an actor was blocked on has been invalidated by a newer
    plan revision (``control/ep{E}/plan/r{R}``) — re-derive the work
    list from the latest revision instead of waiting for a key that may
    never arrive."""


class ActorDied(RuntimeError):
    """A spawned actor process exited while the swarm still needed it."""

    def __init__(self, actor: str, exitcode: Optional[int],
                 last: Optional[HeartbeatMsg] = None):
        msg = (f"actor process {actor!r} died (exit code {exitcode}) "
               f"while the epoch was in flight")
        if last is not None:
            msg += (f"; last heartbeat: epoch={last.epoch} "
                    f"items_done={last.items_done} state={last.state!r}")
        super().__init__(msg)
        self.actor = actor
        self.exitcode = exitcode
        self.last = last


class WorkQueue:
    """Pull-based work discovery: an actor blocks on the store key that
    carries its next input instead of being called by a driver.

    ``await_key`` blocks until the key appears, a stop request lands
    (``ActorStopped``), the ``liveness`` hook raises (driver-side: a
    crashed peer), the ``abort_if`` hook reports the wait is moot
    (``WorkRescheduled`` — a plan revision reassigned the work), or
    ``timeout`` expires.  When the transport offers ``wait_for``
    (``SocketTransport`` against a ``StoreServer``) the wait parks
    server-side on a condition variable in bounded slices — zero CPU
    while idle; otherwise it falls back to exists-polling at
    ``poll_interval``."""

    def __init__(self, transport, poll_interval: float = 0.001,
                 timeout: float = 120.0, liveness=None,
                 stop_event: Optional[threading.Event] = None,
                 liveness_every: int = 25):
        self.transport = transport
        self.poll_interval = poll_interval
        self.timeout = timeout
        self.liveness = liveness
        self.stop_event = stop_event
        self.liveness_every = max(int(liveness_every), 1)
        # chaos hook: a zero-arg callable; when it returns True the
        # current wait is abandoned with WorkRescheduled (installed by
        # actors while a plan revision may still land)
        self.abort_if = None

    wait_slice = 0.25    # bounded server-side park: stop/liveness cadence

    def await_key(self, key: str) -> None:
        deadline = time.monotonic() + self.timeout
        wait_for = getattr(self.transport, "wait_for", None)
        polls = 0
        while True:
            if self.stop_event is not None and self.stop_event.is_set():
                raise ActorStopped(key)
            if self.liveness is not None \
                    and polls % self.liveness_every == 0:
                self.liveness()
            if self.abort_if is not None and self.abort_if():
                raise WorkRescheduled(key)
            if wait_for is not None:
                if wait_for(key, timeout=self.wait_slice):
                    return
            else:
                if self.transport.exists(key):
                    return
                time.sleep(self.poll_interval)
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"work queue timed out after {self.timeout}s "
                    f"awaiting {key!r}")
            polls += 1

    def get(self, key: str, actor: str = "?") -> Any:
        self.await_key(key)
        return self.transport.get(key, actor=actor)


@runtime_checkable
class Actor(Protocol):
    """The surface every actor-process implementation must provide (the
    swarmlint ``protocol-conformance`` rule binds ``*Actor`` classes to
    this protocol; ``ActorProcess`` supplies the base implementation)."""
    actor: str

    def setup(self) -> None: ...

    def process_epoch(self, plan: dict) -> None: ...

    def status(self) -> HeartbeatMsg: ...

    def shutdown(self) -> None: ...


@dataclasses.dataclass(frozen=True)
class ActorSpec:
    """Picklable spawn arguments: everything a child process needs to
    rebuild its world deterministically (params re-derive from the seed,
    they never cross the process boundary at spawn).

    Chaos fields: ``behavior`` makes the child run its own payload
    faults (tamper/free-ride) with a per-uid seeded RNG;
    ``snapshot_dir`` turns on the crash-resume ``DiskSnapshotCache``;
    ``chaos`` (a ``runtime.chaos.FaultSchedule``) wraps the child's
    transport; ``store_failover`` lists warm-standby store addresses."""
    kind: str                 # "miner" | "validator" | "server"
    uid: int
    stage: int                # -1 for validators
    model_cfg: ModelConfig
    config: SwarmConfig
    train_cfg: TrainConfig
    store_address: tuple
    start_epoch: int = 0
    behavior: Optional[MinerBehavior] = None
    snapshot_dir: Optional[str] = None
    chaos: Any = None         # FaultSchedule | None
    store_failover: tuple = ()


class ActorProcess:
    """Base actor: spawn-context process body, own store connection,
    heartbeat/health endpoint over a tiny TCP socket, clean shutdown.

    The epoch loop awaits ``control/ep{E}/plan``, hands the decoded plan
    to ``process_epoch`` and advances; a plan with ``stop=True`` (or a
    ``stop`` op on the health endpoint) ends the loop cleanly."""

    health_poll = 0.2         # accept() timeout: stop-flag check cadence
    schema_version = 4        # key plane the actor speaks (serve uses v5)

    def __init__(self, spec: ActorSpec):
        self.spec = spec
        self.actor = f"{spec.kind}{spec.uid}"
        self.epoch = spec.start_epoch
        self.items_done = 0
        self.state = "init"
        self.transport: Optional[SocketTransport] = None
        self.queue: Optional[WorkQueue] = None
        self._stop = threading.Event()
        self._health_sock: Optional[socket.socket] = None
        self.model_spec: Optional[sm.SwarmModelSpec] = None

    # -- lifecycle -------------------------------------------------------

    def setup(self) -> None:
        S = self.spec.config
        self.transport = SocketTransport(
            self.spec.store_address,
            schema=KeySchema(version=self.schema_version),
            failover=tuple(self.spec.store_failover or ()))
        if self.spec.chaos is not None:
            self.transport = wrap_transport(self.transport,
                                            self.spec.chaos,
                                            actor_tag=self.actor)
        self.queue = WorkQueue(self.transport, stop_event=self._stop)
        self.model_spec = sm.SwarmModelSpec(
            self.spec.model_cfg, S.n_stages, S.compress, S.bottleneck_dim)

    # -- plan revisions (graceful degradation) ---------------------------

    def _latest_plan(self, epoch: int, plan: dict) -> dict:
        """Fold in every published plan revision for ``epoch`` and arm
        the work queue's abort hook on the next (still unpublished) one,
        so a blocked await abandons work a revision reassigns."""
        schema = self.transport.schema
        if schema.version < 4:
            return plan
        rev = int(plan.get("rev", 0))
        while True:
            key = schema.plan_rev(epoch, rev + 1)
            if not self.transport.exists(key):
                break
            plan = self.transport.get(key, actor=self.actor)
            rev = int(plan.get("rev", rev + 1))
        nxt = schema.plan_rev(epoch, rev + 1)
        self.queue.abort_if = lambda: self.transport.exists(nxt)
        return plan

    def _newest_plan_epoch(self) -> Optional[int]:
        """Highest epoch with a visible plan — the fast-forward target
        for an actor that fell behind the swarm (crash-resume)."""
        schema = self.transport.schema
        best = None
        for key in self.transport.keys(""):
            try:
                parsed = schema.parse(key)
            except ValueError:
                continue
            if parsed.kind == "plan":
                ep = parsed.fields["epoch"]
                if best is None or ep > best:
                    best = ep
        return best

    def status(self) -> HeartbeatMsg:
        import os
        return HeartbeatMsg(self.actor, pid=os.getpid(), epoch=self.epoch,
                            items_done=self.items_done, state=self.state)

    def shutdown(self) -> None:
        self._stop.set()
        if self._health_sock is not None:
            try:
                self._health_sock.close()
            except OSError:
                pass
            self._health_sock = None
        if self.transport is not None:
            self.transport.close()

    def process_epoch(self, plan: dict) -> None:
        raise NotImplementedError

    # -- health endpoint -------------------------------------------------

    def _serve_health(self) -> None:
        srv = self._health_sock
        while not self._stop.is_set():
            try:
                conn, _ = srv.accept()
            except (OSError, socket.timeout):
                if self._stop.is_set():
                    return
                continue
            try:
                conn.settimeout(2.0)
                while True:
                    frame = serde.recv_frame(conn)
                    if frame is None:
                        break
                    req = serde.loads(frame)
                    if req.get("op") == "stop":
                        self.state = "stopping"
                        self._stop.set()
                    serde.send_frame(conn,
                                     serde.encode_message(self.status()))
            except (OSError, socket.timeout, ConnectionError):
                pass
            finally:
                try:
                    conn.close()
                except OSError:
                    pass

    def run(self, ready_queue: Any = None) -> None:
        """Blocking process body: health endpoint up, report ready, loop
        epochs until a stop plan / stop ping / ActorStopped."""
        self.setup()
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.bind(("127.0.0.1", 0))
        srv.listen(4)
        srv.settimeout(self.health_poll)
        self._health_sock = srv
        threading.Thread(target=self._serve_health,
                         name=f"{self.actor}-health", daemon=True).start()
        if ready_queue is not None:
            ready_queue.put((self.actor, srv.getsockname()[:2]))
        try:
            self._main_loop()
        except ActorStopped:
            pass
        finally:
            self.state = "stopped"
            self.shutdown()

    def _main_loop(self) -> None:
        """Plan-driven work loop; ``ServeActor`` overrides this with the
        round-plan variant (same health/ready/stop machinery in run())."""
        while not self._stop.is_set():
            self.state = "awaiting-plan"
            plan_key = self.transport.schema.plan(self.epoch)
            while True:
                try:
                    self.queue.await_key(plan_key)
                    break
                except TimeoutError:
                    # idle between epochs is not a failure — but a
                    # resumed actor may be awaiting a plan the swarm
                    # GC'd: fast-forward to the newest visible one
                    newest = self._newest_plan_epoch()
                    if newest is not None and newest > self.epoch:
                        self.epoch = newest
                        plan_key = self.transport.schema.plan(
                            self.epoch)
                    continue
            plan = self.transport.get(plan_key, actor=self.actor)
            if plan.get("stop"):
                break
            self.state = "working"
            self.process_epoch(plan)
            self.epoch += 1


class MinerActor(ActorProcess):
    """A ``runtime.Miner`` driven by the store instead of the driver.

    Crash-resume: with ``spec.snapshot_dir`` set the miner snapshots its
    full state (params, opt state, inner step) to a
    ``DiskSnapshotCache`` at every epoch boundary, *before* any tick
    mutates it.  A respawned process restores the newest good snapshot,
    downloads the newest anchor of its stage (the store's catch-up
    artifact), fast-forwards to the in-flight epoch and rejoins — it
    never restarts from the seed."""

    def __init__(self, spec: ActorSpec):
        super().__init__(spec)
        self.miner: Optional[Miner] = None
        self._cache: Optional[DiskSnapshotCache] = None
        self.resumed_from: Optional[int] = None
        b = spec.behavior
        self._behavior = b if b is not None and not b.honest else None
        # the child's own fault RNG (the lockstep timeline draws from the
        # parent's FaultModel; here corruption is owned by the actor)
        self._faults = FaultModel(
            {spec.uid: b} if b is not None else {},
            seed=(spec.config.seed * 7919 + spec.uid) & 0x7FFFFFFF)

    def setup(self) -> None:
        super().setup()
        S = self.spec.config
        stage = self.spec.stage
        # same init as Swarm.register_miner: params copy the stage anchor,
        # which is init_stage_params at the folded seed — re-derived here
        # so no weights cross the spawn boundary
        params = sm.init_stage_params(
            jax.random.fold_in(jax.random.key(S.seed), stage),
            self.model_spec, stage)
        self.miner = Miner(self.spec.uid, stage, self.model_spec,
                           jax.tree.map(jnp.copy, params), self.transport,
                           self.spec.train_cfg)
        if self.spec.snapshot_dir:
            self._cache = DiskSnapshotCache(self.spec.snapshot_dir)
            self._try_resume()

    # -- crash-resume ----------------------------------------------------

    def _try_resume(self) -> None:
        """Restore the newest good snapshot and replay forward: load the
        newest visible anchor of this stage, then fast-forward the epoch
        cursor to the newest visible plan (corrupt snapshots are
        quarantined by the cache and the next older one used)."""
        m = self.miner
        got = self._cache.restore_latest(m.snapshot())
        if got is None:
            return                      # fresh actor: seed-derived state
        snap_epoch, tree, _meta = got
        m.params = jax.tree.map(jnp.asarray, tree["params"])
        m.opt_state = jax.tree.map(jnp.asarray, tree["opt_state"])
        m.inner_step = jnp.asarray(tree["inner_step"], jnp.int32)
        self.epoch = max(self.epoch, snap_epoch)
        self.resumed_from = snap_epoch
        schema = self.transport.schema
        best = None
        for key in self.transport.keys(""):
            try:
                parsed = schema.parse(key)
            except ValueError:
                continue
            if parsed.kind == "anchor" \
                    and parsed.fields["stage"] == m.stage:
                ep = parsed.fields["epoch"]
                if ep >= snap_epoch and (best is None or ep > best):
                    best = ep
        if best is not None:
            m.load_weights_vector(np.asarray(self.transport.get(
                schema.anchor(best, m.stage), actor=self.actor)))
            # an anchor for epoch E means E *completed* — replaying E is
            # impossible anyway (its activation/gradient planes are GC'd
            # at epoch end), so rejoin at the boundary after it
            self.epoch = max(self.epoch, best + 1)
        newest = self._newest_plan_epoch()
        if newest is not None and newest > self.epoch:
            self.epoch = newest
        self.state = f"resumed@{snap_epoch}"
        # store-side marker so scenarios can assert a real resume
        self.transport.put(schema.heartbeat(self.actor),
                           {"resumed_from": snap_epoch,
                            "epoch": self.epoch},
                           actor=self.actor)

    # -- the epoch -------------------------------------------------------

    def process_epoch(self, plan: dict) -> None:
        m = self.miner
        epoch = plan["epoch"]
        m.reset_epoch()
        if self._cache is not None:
            # epoch-boundary snapshot, before any tick mutates state: a
            # respawn restores exactly here
            self._cache.save(epoch, m.snapshot(),
                             {"uid": m.uid, "stage": m.stage})
        plan = self._latest_plan(epoch, plan)
        if m.uid not in set(plan.get("dead", ())) \
                and m.uid in set(plan["tracked"].values()):
            # epoch-start snapshot, before any tick mutates state: the
            # tracked validator replays from exactly here
            self.transport.publish(SnapshotMsg(epoch, m.uid), m.snapshot(),
                                   actor=self.actor)
        done: set = set()
        self._uploaded = False
        self._reduced = False
        self._shard_ex = None
        while True:
            dropped = set(plan.get("dropped", ()))
            orphaned = set(plan.get("orphaned", ()))
            try:
                for tick, uids in plan["ticks"]:
                    uids = tuple(uids)
                    if uids[m.stage] != m.uid or tick in done \
                            or tick in dropped:
                        continue
                    brk = self._orphan_break(plan, uids) \
                        if tick in orphaned else None
                    self._process_tick(epoch, tick, uids,
                                       orphan_break=brk)
                    done.add(tick)
                    self.items_done += 1
                # my ticks (under this fold of the plan) are done — but a
                # revision can still hand me a dead peer's remaining work
                # while I park at the full-sync anchor, so the anchor
                # await keeps the revision abort armed and a reschedule
                # re-enters the tick scan above.  Only the rev check
                # keeps this loop finite.
                rev = plan.get("rev", 0)
                plan = self._latest_plan(epoch, plan)
                if plan.get("rev", 0) != rev:
                    continue           # fresh revision: rescan for work
                if plan["merge"]:
                    self._share_and_sync(epoch, plan)
                break
            except WorkRescheduled:
                plan = self._latest_plan(epoch, plan)
        self.queue.abort_if = None

    @staticmethod
    def _orphan_break(plan: dict, uids: tuple) -> Optional[int]:
        """Lowest dead stage on this pathway: backward is broken *below*
        it (the dead miner never forwarded its gradient), intact above."""
        dead = set(plan.get("dead", ()))
        stages = [plan["stage_of"][u] for u in uids if u in dead]
        return min(stages) if stages else None

    def _process_tick(self, epoch: int, tick: int, uids: tuple,
                      orphan_break: Optional[int] = None) -> None:
        m, schema = self.miner, self.transport.schema
        s, last = m.stage, self.spec.config.n_stages - 1
        in_key = schema.tokens(epoch, tick) if s == 0 \
            else schema.activation(epoch, tick, s - 1, uids[s - 1])
        out_key = schema.activation(epoch, tick, s, m.uid)
        if orphan_break is not None:
            # an orphaned tick's forward chain completed before the
            # death (its loss is published) — never re-forward, params
            # may have moved since; only the backward may be pending
            if s == last or s < orphan_break:
                return               # chain broken below the casualty
            g = m.backward(in_key, self._decode_gradient(
                self.queue.get(schema.gradient_for(out_key), self.actor)))
            if s > 0:
                self._publish_gradient(epoch, tick, s - 1, uids[s - 1], g)
            return
        self.queue.await_key(in_key)
        out = m.forward(tick, in_key, out_key)
        b = self._behavior
        if b is not None and s < last \
                and (b.free_ride or b.tamper_activations > 0):
            # adversarial republish over the honest output — validators
            # catch the mismatch on replay, CLASP the loss inflation
            # (mirrors the lockstep TrainingPhase, but actor-owned)
            corrupted = self._faults.corrupt_activation(
                m.uid, np.asarray(out, np.float32))
            self.transport.publish(
                ActivationMsg(epoch, tick, s, m.uid),
                jnp.asarray(corrupted).astype(jnp.asarray(out).dtype),
                actor=self.actor)
        if s == last:
            lab_key = schema.labels(epoch, tick)
            loss, g = m.backward_last(in_key,
                                      self.queue.get(lab_key, self.actor))
            # the training watermark the EventDriver folds into records
            self.transport.publish(TickLossMsg(epoch, tick), float(loss),
                                   actor=self.actor)
        else:
            g_key = schema.gradient_for(out_key)
            g = m.backward(in_key, self._decode_gradient(
                self.queue.get(g_key, self.actor)))
        if s > 0:
            self._publish_gradient(epoch, tick, s - 1, uids[s - 1], g)

    def _publish_gradient(self, epoch: int, tick: int, stage: int,
                          uid: int, g) -> None:
        msg = GradientMsg(epoch, tick, stage, uid)
        if self.spec.config.wire_codec == "int8":
            # the lockstep driver's int8 gradient wire, producer-side; the
            # extra "dtype" key lets the consumer replicate the exact
            # decode->astype the in-process loop applies (it knows g's
            # dtype in-process; over the wire it must be carried)
            flat = jnp.ravel(jnp.asarray(g, jnp.float32))
            payload = dict(compression.encode(flat, "int8"),
                           shape=tuple(np.shape(g)),
                           dtype=str(jnp.asarray(g).dtype))
            self.transport.publish(msg, payload, actor=self.actor)
        else:
            self.transport.publish(msg, g, actor=self.actor)

    def _decode_gradient(self, g):
        if isinstance(g, dict) and g.get("codec"):
            return jnp.reshape(compression.decode(g), g["shape"]).astype(
                serde._np_dtype(g["dtype"]))
        return g

    # -- sharing + sync --------------------------------------------------

    def _share_and_sync(self, epoch: int, plan: dict) -> None:
        m, S = self.miner, self.spec.config
        schema = self.transport.schema
        qual = plan["qualified"].get(m.stage, ())
        if m.uid in qual:
            if not self._uploaded:
                # once per epoch: a reschedule from the anchor park below
                # can re-enter here after re-planned ticks moved the
                # weights, and republishing the upload key with different
                # bits would be a digest conflict — the merge averages
                # the pre-revision vector, which is what the plan-time
                # layout expects
                self._uploaded = True
                vec = m.weights_vector()
                b = self._behavior
                if b is not None and b.tamper_weights > 0:
                    # dishonest upload (the agreement matrix exposes it)
                    vec = self._faults.corrupt_weights(
                        m.uid, np.asarray(vec, np.float32))
                if S.sync_mode == "sharded":
                    self._shard_upload(epoch, tuple(qual), vec)
                else:
                    payload = compression.encode(jnp.asarray(vec),
                                                 S.share_codec)
                    self.transport.publish(
                        WeightUploadMsg(epoch, m.stage, m.uid,
                                        codec=S.share_codec),
                        payload, actor=self.actor)
            if S.sync_mode == "sharded" and not self._reduced:
                self._reduce_shards(plan, tuple(qual))
        if m.stage in plan["qualified"]:
            # full sync: everyone in a merged stage (stragglers included)
            # downloads the anchor the driver publishes.  The await keeps
            # the revision abort armed: WorkRescheduled propagates to the
            # process_epoch loop, which folds the revision and rescans
            # for re-planned ticks before parking here again.
            anchor = AnchorMsg(epoch, m.stage)
            self.queue.await_key(anchor.key(schema))
            m.load_weights_vector(self.transport.fetch(anchor,
                                                       actor=self.actor))

    def _shard_upload(self, epoch: int, qual: tuple, vec) -> None:
        m, S = self.miner, self.spec.config
        align = compression.INT8_BLOCK if S.share_codec == "int8" else 1
        plan_b = butterfly.make_plan(len(qual), int(vec.shape[0]),
                                     seed=S.seed + epoch * 131 + m.stage,
                                     align=align)
        self._shard_ex = butterfly.ButterflyExecutor(
            plan_b, self.transport, epoch=epoch, stage=m.stage,
            uids=list(qual), codec=S.share_codec)
        self._shard_ex.upload_vector(qual.index(m.uid), vec,
                                     actor=self.actor)

    def _reduce_shards(self, plan: dict, qual: tuple) -> None:
        """Input barrier + reduce.  ``reduce_one`` masks *missing*
        uploads out of the merge, so every input must exist before
        reducing — await them all, except a dead peer's, which will
        never come (the store is immutable, so every live reducer masks
        the same set and the redundant copies stay bit-identical).  The
        barrier keeps the revision abort armed — a mid-barrier death
        reschedules and re-enters with the new ``dead`` list; only the
        reduce itself publishes, and runs uninterruptible."""
        m = self.miner
        ex, idx = self._shard_ex, qual.index(m.uid)
        dead = set(plan.get("dead", ()))
        for a in ex.assignments_for(idx):
            for i, key in enumerate(a.upload_keys):
                if qual[i] in dead:
                    continue
                self.queue.await_key(key)
        armed, self.queue.abort_if = self.queue.abort_if, None
        try:
            b = self._behavior
            m.run_reduce(ex, idx,
                         tamper=b.tamper_weights if b is not None else 0.0)
        finally:
            self.queue.abort_if = armed
        self._reduced = True


class ValidatorActor(ActorProcess):
    """Replays its tracked miner purely from store artifacts (snapshot,
    activations, gradients, labels), mirroring
    ``Validator.validate_epoch`` operation for operation, then publishes
    the ``ScoreMsg`` watermark the driver's ledger waits on."""

    def __init__(self, spec: ActorSpec):
        super().__init__(spec)
        self.opt = None

    def setup(self) -> None:
        super().setup()
        tc = self.spec.train_cfg
        # the same inner optimizer Miner builds: replayed updates must
        # track the miner's own update rule exactly
        self.opt = adamw(cosine_warmup(tc.lr, tc.warmup_steps, 10_000),
                         beta1=tc.beta1, beta2=tc.beta2,
                         weight_decay=tc.weight_decay)

    def process_epoch(self, plan: dict) -> None:
        S = self.spec.config
        schema = self.transport.schema
        epoch = plan["epoch"]
        plan = self._latest_plan(epoch, plan)
        uid = plan["tracked"].get(self.spec.uid)
        if uid is None:
            self.queue.abort_if = None
            return
        stage = plan["stage_of"][uid]
        role = self.model_spec.role(stage)
        params = opt_state = inner_step = None

        checked = passed = 0
        validated = 0.0
        min_cos = 1.0
        done: set = set()
        while True:
            if uid in set(plan.get("dead", ())):
                # tracked miner is the casualty: publish the partial
                # score over what was already checked (the driver's
                # ledger is waiting on this watermark)
                break
            dropped = set(plan.get("dropped", ()))
            orphaned = set(plan.get("orphaned", ()))
            items = [(t, tuple(uids)) for t, uids in plan["ticks"]
                     if tuple(uids)[stage] == uid and t not in dropped]
            if S.validate_max_items is not None:
                items = items[:S.validate_max_items]
            try:
                if params is None:
                    snap = self.queue.get(schema.snapshot(epoch, uid),
                                          self.actor)
                    params = jax.tree.map(jnp.asarray, snap["params"])
                    opt_state = jax.tree.map(jnp.asarray,
                                             snap["opt_state"])
                    inner_step = jnp.asarray(snap["inner_step"])
                for tick, uids in items:
                    if tick in done:
                        continue
                    brk = MinerActor._orphan_break(plan, uids) \
                        if tick in orphaned else None
                    sample_key = schema.tokens(epoch, tick) if stage == 0 \
                        else schema.activation(epoch, tick, stage - 1,
                                               uids[stage - 1])
                    out_key = schema.activation(epoch, tick, stage, uid)
                    x_in = self.queue.get(sample_key, self.actor)
                    mine = sm.stage_forward(params, x_in, self.model_spec,
                                            role)
                    theirs = self.queue.get(out_key, self.actor)
                    cos = float(cosine_similarity(
                        jnp.asarray(mine, jnp.float32),
                        jnp.asarray(theirs, jnp.float32)))
                    checked += 1
                    min_cos = min(min_cos, cos)
                    ok = cos >= COSINE_THRESHOLD
                    passed += int(ok)
                    if brk is not None and stage < brk:
                        # orphaned below the break: the miner never ran
                        # this backward either — forward check only
                        if ok:
                            validated += 1.0
                        done.add(tick)
                        self.items_done += 1
                        continue
                    # every completed pathway item ran a backward; replay
                    # it so later items line up (Validator.validate_epoch)
                    if role == "last":
                        labels = self.queue.get(schema.labels(epoch, tick),
                                                self.actor)
                        _, g_params, _ = sm.last_stage_loss_and_grads(
                            params, x_in, labels, self.model_spec)
                    else:
                        g_out = self.queue.get(schema.gradient_for(out_key),
                                               self.actor)
                        if isinstance(g_out, dict) and g_out.get("codec"):
                            g_out = jnp.reshape(compression.decode(g_out),
                                                g_out["shape"])
                        g_params, _ = sm.stage_backward(
                            params, x_in, g_out, self.model_spec, role)
                    params, opt_state = self.opt.update(
                        g_params, opt_state, params, inner_step)
                    inner_step = inner_step + 1
                    if ok:
                        validated += 1.0
                    done.add(tick)
                    self.items_done += 1
                break
            except WorkRescheduled:
                plan = self._latest_plan(epoch, plan)

        self.queue.abort_if = None
        self.transport.publish(
            ScoreMsg(epoch, self.spec.uid, uid),
            np.asarray([validated, checked, passed, min_cos], np.float32),
            actor=self.actor)


class ServeActor(ActorProcess):
    """One decode-pipeline stage as a store-driven process (kind
    ``"server"``): the serve-plane sibling of ``MinerActor``.

    The loop speaks KeySchema v5: await the session plan (``serve/plan``
    — lane count, max length, wire codec, weight seed), build the
    ``StageServer`` with deterministically re-derived stage params, then
    process round plans (``serve/round{N}/plan``) in order until one
    carries ``stop``.  All compute is deterministic and sampling lives in
    the driver, so an actor fleet serves tokens bit-identical to the
    in-process pipeline and the sequential oracle."""

    schema_version = 5

    def __init__(self, spec: ActorSpec):
        super().__init__(spec)
        self.server: Optional[StageServer] = None
        self.round = 0

    def process_epoch(self, plan: dict) -> None:
        """One round plan: run this stage's timetable cells.  For a fixed
        stage the decode timetable orders slots by ascending lane
        (``f[(s, m)] = s + m``), which is the order entries arrive in."""
        schema = self.transport.schema
        for entry in plan["entries"]:
            self.server.process_slot(self.transport, schema,
                                     self.round, entry)
            self.items_done += 1

    def _main_loop(self) -> None:
        schema = self.transport.schema
        self.state = "awaiting-plan"
        while not self._stop.is_set():
            try:
                self.queue.await_key(schema.serve_plan())
                break
            except TimeoutError:
                continue          # no session yet — idle, not a failure
        if self._stop.is_set():
            return
        sess = self.transport.get(schema.serve_plan(), actor=self.actor)
        self.server = StageServer(
            self.model_spec, self.spec.stage,
            sm.serve_stage_params(self.model_spec, int(sess["seed"]),
                                  self.spec.stage),
            n_lanes=int(sess["n_lanes"]), max_len=int(sess["max_len"]),
            wire_codec=str(sess["wire_codec"]))
        while not self._stop.is_set():
            self.state = "awaiting-plan"
            plan_key = schema.serve_round_plan(self.round)
            try:
                self.queue.await_key(plan_key)
            except TimeoutError:
                continue          # idle between rounds is not a failure
            plan = self.transport.get(plan_key, actor=self.actor)
            if plan.get("stop"):
                break
            self.state = "working"
            self.process_epoch(plan)
            self.round += 1
            self.epoch = self.round   # heartbeat visibility


_ACTOR_KINDS = {"miner": MinerActor, "validator": ValidatorActor,
                "server": ServeActor}


def _child_main(spec: ActorSpec, ready_queue: Any) -> None:
    """Spawn entry point (module-level: the child pickles a reference)."""
    _ACTOR_KINDS[spec.kind](spec).run(ready_queue)


class ActorSupervisor:
    """Owns the actor process fleet: spawn, health pings, stop, the
    liveness check that turns a dead child into ``ActorDied``, and the
    chaos controls — ``kill`` (hard crash), ``forget`` (drop a dead
    child from liveness so the epoch can degrade around it) and
    ``respawn`` (relaunch from the recorded spec, crash-resume)."""

    def __init__(self):
        self.procs: dict[str, Any] = {}
        self.health: dict[str, tuple] = {}
        self.specs: dict[str, ActorSpec] = {}
        self.last_seen: dict[str, HeartbeatMsg] = {}

    def spawn(self, specs: list) -> None:
        import multiprocessing as mp
        import queue as queue_mod

        ctx = mp.get_context("spawn")
        ready = ctx.Queue()
        for spec in specs:
            name = f"{spec.kind}{spec.uid}"
            proc = ctx.Process(target=_child_main, args=(spec, ready),
                               daemon=True, name=name)
            proc.start()
            self.procs[name] = proc
            self.specs[name] = spec
        pending = len(specs)
        while pending:
            try:
                name, addr = ready.get(timeout=0.5)
                self.health[name] = (str(addr[0]), int(addr[1]))
                pending -= 1
            except queue_mod.Empty:
                for name, proc in self.procs.items():
                    if not proc.is_alive():
                        raise ActorDied(name, proc.exitcode,
                                        last=self.last_seen.get(name))

    def _health_request(self, name: str, op: str,
                        timeout: float = 5.0) -> HeartbeatMsg:
        addr = self.health[name]
        with socket.create_connection(addr, timeout=timeout) as sock:
            serde.send_frame(sock, serde.dumps({"op": op}))
            frame = serde.recv_frame(sock)
        if frame is None:
            raise ConnectionError(f"health endpoint of {name!r} closed")
        return serde.decode_message(frame)

    def ping(self, name: str) -> HeartbeatMsg:
        hb = self._health_request(name, "ping")
        self.last_seen[name] = hb
        return hb

    def progress(self) -> dict[str, HeartbeatMsg]:
        """Last known ``HeartbeatMsg`` per actor — live pings where the
        health endpoint answers, the cached heartbeat where it doesn't
        (a stalled or dead child keeps its last report)."""
        out: dict[str, HeartbeatMsg] = {}
        for name in sorted(self.procs):
            try:
                out[name] = self.ping(name)
            except (OSError, ConnectionError):
                hb = self.last_seen.get(name)
                if hb is not None:
                    out[name] = hb
        return out

    def stop(self, name: str) -> None:
        try:
            self._health_request(name, "stop", timeout=2.0)
        except (OSError, ConnectionError):
            pass                     # already gone: stopping is idempotent

    def kill(self, name: str) -> None:
        """Hard-crash a child (SIGTERM, no cleanup) — the chaos
        scenarios' crash primitive.  The dead process stays registered,
        so the next ``check()`` surfaces ``ActorDied`` and the driver's
        graceful degradation takes over."""
        proc = self.procs[name]
        if proc.is_alive():
            proc.terminate()
        proc.join(timeout=5.0)

    def forget(self, name: str) -> None:
        """Drop a dead child from liveness tracking (the driver calls
        this after re-planning around it) so ``check()`` stops raising
        for a casualty the epoch already degraded around."""
        self.procs.pop(name, None)
        self.health.pop(name, None)

    def respawn(self, name: str,
                start_epoch: Optional[int] = None) -> None:
        """Relaunch a (dead) actor from its recorded spec.  With a
        ``snapshot_dir`` in the spec the child crash-resumes from its
        newest good snapshot; ``start_epoch`` seeds the epoch cursor."""
        spec = self.specs[name]
        if start_epoch is not None:
            spec = dataclasses.replace(spec, start_epoch=start_epoch)
        self.forget(name)
        self.spawn([spec])

    def check(self) -> None:
        """Raise ``ActorDied`` if any child exited — called from await
        loops so a crash surfaces immediately instead of as a timeout.
        The error carries the casualty's last heartbeat (epoch,
        items_done, state) for the post-mortem."""
        for name, proc in self.procs.items():
            if not proc.is_alive():
                raise ActorDied(name, proc.exitcode,
                                last=self.last_seen.get(name))

    def join_all(self, timeout: float = 10.0) -> None:
        deadline = time.monotonic() + timeout
        for proc in self.procs.values():
            proc.join(timeout=max(deadline - time.monotonic(), 0.1))

    def terminate_all(self) -> None:
        for proc in self.procs.values():
            if proc.is_alive():
                proc.terminate()
        for proc in self.procs.values():
            proc.join(timeout=2.0)

    @property
    def names(self) -> list[str]:
        return sorted(self.procs)


class ActorSwarm(Swarm):
    """``Swarm`` whose miners and validators are concurrent processes.

    The parent keeps the facade state (anchors, outer optimizer, ledger,
    corpus, RNG — and placeholder ``Miner`` objects used only for uids /
    stages / census), the ``EventDriver`` timeline, and the supervisor;
    all forward/backward/replay compute runs in the children.  With no
    ``store_address`` an in-process threaded ``StoreServer`` is started
    and owned (real sockets, no extra spawn cost); pass an address to
    point the whole swarm at an external store process instead.

        swarm = Swarm.create(model_cfg, cfg, runtime="actors")
        try:
            stats = swarm.run(3)      # actors spawn on first epoch
        finally:
            swarm.shutdown()
    """

    def __init__(self, model_cfg: ModelConfig,
                 config: Optional[SwarmConfig] = None, *,
                 faults: Optional[FaultModel] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 store_address: Optional[tuple] = None,
                 driver: Optional[EventDriver] = None,
                 snapshot_root: Optional[str] = None,
                 chaos: Any = None,
                 store_standby: bool = False):
        config = config or SwarmConfig()
        faults = faults or FaultModel({}, seed=config.seed)
        self._own_server = None
        self._standby = None
        if store_address is None:
            from repro.runtime.store_server import StoreServer
            self._own_server = StoreServer().start()
            store_address = self._own_server.address
            if store_standby:
                # warm standby: the primary mirrors every mutation
                # synchronously; clients carry the standby address and
                # fail over when the primary drops
                self._standby = StoreServer().start()
                self._own_server.mirror_to(self._standby.address)
        elif store_standby:
            raise ValueError(
                "store_standby=True needs the swarm-owned store (omit "
                "store_address); an external store manages its own "
                "replica")
        self.store_address = (str(store_address[0]), int(store_address[1]))
        self._failover = ((self._standby.address,)
                          if self._standby is not None else ())
        transport = SocketTransport(self.store_address,
                                    schema=KeySchema(version=4),
                                    failover=self._failover)
        super().__init__(model_cfg, config, faults=faults,
                         transport=transport, train_cfg=train_cfg,
                         driver=driver or EventDriver())
        self.supervisor = ActorSupervisor()
        self._started = False
        self.dead_uids: set = set()
        self.snapshot_root = snapshot_root
        self.chaos = chaos

    # -- fleet lifecycle -------------------------------------------------

    def _snapshot_dir(self, uid: int) -> Optional[str]:
        if self.snapshot_root is None:
            return None
        import os
        return os.path.join(self.snapshot_root, f"miner{uid}")

    def start(self) -> "ActorSwarm":
        if self._started:
            return self
        specs = [ActorSpec("miner", m.uid, m.stage, self.cfg, self.config,
                           self.train_cfg, self.store_address,
                           start_epoch=self.epoch,
                           behavior=self.faults.behaviors.get(m.uid),
                           snapshot_dir=self._snapshot_dir(m.uid),
                           chaos=self.chaos,
                           store_failover=self._failover)
                 for m in self.miners.values()]
        specs += [ActorSpec("validator", v.uid, -1, self.cfg, self.config,
                            self.train_cfg, self.store_address,
                            start_epoch=self.epoch,
                            chaos=self.chaos,
                            store_failover=self._failover)
                  for v in self.validators]
        self.supervisor.spawn(specs)
        self._started = True
        return self

    def check_liveness(self) -> None:
        """The EventDriver's await-loop hook: a dead child is an
        ``ActorDied`` now, not a watermark timeout two minutes later."""
        if self._started:
            self.supervisor.check()

    # -- chaos controls --------------------------------------------------

    def kill_miner(self, uid: int) -> None:
        """Hard-crash a miner process mid-run.  The next driver await
        surfaces ``ActorDied`` and graceful degradation re-plans the
        epoch around the casualty."""
        self.supervisor.kill(f"miner{uid}")

    def respawn_miner(self, uid: int) -> None:
        """Relaunch a killed miner.  Pins store GC retention at the
        miner's newest snapshot epoch (the keys its forward replay needs
        must survive), clears it from the dead census so the next plan
        schedules it, and crash-resumes the process."""
        name = f"miner{uid}"
        spec = self.supervisor.specs[name]
        snap_epoch = None
        if spec.snapshot_dir:
            snap_epoch = DiskSnapshotCache(spec.snapshot_dir).latest_epoch()
        rejoin = snap_epoch if snap_epoch is not None else self.epoch
        self.driver.pin_retention(name, rejoin)
        self.dead_uids.discard(uid)
        self.supervisor.respawn(name, start_epoch=rejoin)

    def fail_primary(self) -> None:
        """Kill the primary store server mid-run: every transport in the
        swarm (parent and children) reconnects, fails over to the warm
        standby and replays its pending requests there."""
        if self._standby is None:
            raise RuntimeError(
                "no warm standby: construct with store_standby=True")
        self._own_server.stop()
        self._own_server, self._standby = self._standby, None
        self.store_address = (str(self._own_server.address[0]),
                              int(self._own_server.address[1]))
        self._failover = ()

    def run_epoch(self):
        self.start()
        stats = self.driver.run_epoch(self)
        self._release_caught_up_pins()
        return stats

    def _release_caught_up_pins(self) -> None:
        """Retention pins hold GC only while the respawned miner is
        behind; once its heartbeat shows it reached the swarm's epoch
        the pin is dropped and the GC floors advance again."""
        for tag in list(getattr(self.driver, "_pins", {})):
            if tag not in self.supervisor.procs:
                self.driver.release_retention(tag)
                continue
            try:
                hb = self.supervisor.ping(tag)
            except (OSError, ConnectionError):
                continue
            if hb.epoch >= self.epoch:
                self.driver.release_retention(tag)

    def shutdown(self, stop_server: bool = True) -> None:
        """Stop the fleet (stop plan for the next epoch + health-endpoint
        stop pings), join, terminate stragglers, then stop the owned
        store server.  Idempotent."""
        from repro.api.messages import EpochPlanMsg
        if self._started:
            try:
                self.transport.publish(
                    EpochPlanMsg(self.epoch),
                    {"stop": True, "epoch": self.epoch},
                    actor="orchestrator")
            except (OSError, RuntimeError, ConnectionError):
                pass                 # store already down: fall through
            for name in self.supervisor.names:
                self.supervisor.stop(name)
            self.supervisor.join_all(timeout=10.0)
            self.supervisor.terminate_all()
            self._started = False
        if self._own_server is not None and stop_server:
            self._own_server.stop()
            self._own_server = None
        if self._standby is not None and stop_server:
            self._standby.stop()
            self._standby = None
        self.transport.close()

    def __enter__(self) -> "ActorSwarm":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
