"""Concurrent actor runtime: miners/validators as real OS processes.

The paper's SWARM peers (§2) are autonomous workers polling a globally
accessible store — no global barrier stepping.  Everything before this
module simulated that: PR 5 made the *store* a process, but every actor
still took turns inside one Python loop.  Here each miner and validator
is a ``spawn``-context process with its own ``SocketTransport`` (its
thread-safe store handle), pulling work off the store through a
``WorkQueue`` and publishing results the ``EventDriver``
(``repro.api.phases``) advances on.

Process model:

  * ``ActorProcess``   base: spawn entry, per-actor store connection, a
                       tiny TCP *health endpoint* (serde frames; ``ping``
                       answers a ``HeartbeatMsg`` envelope, ``stop``
                       requests a clean exit), the epoch loop (await
                       plan -> process -> next), clean shutdown;
  * ``MinerActor``     wraps a ``runtime.Miner``: derives its tick jobs
                       from the plan, awaits each input activation,
                       forwards/backwards, publishes activations,
                       gradients, the tick-loss watermark, its weight
                       upload and (sharded) its reduce work;
  * ``ValidatorActor`` replays its tracked miner from the store alone —
                       snapshot + activations + gradients + labels —
                       mirroring ``Validator.validate_epoch`` bit-exactly,
                       and publishes the ``ScoreMsg`` watermark;
  * ``ActorSupervisor``spawns/pings/stops the fleet and turns a dead
                       child into ``ActorDied`` instead of a hang;
  * ``ActorSwarm``     the ``Swarm`` facade over all of it —
                       ``Swarm.create(..., runtime="actors")`` builds one.

Determinism: the driver does every swarm RNG draw at plan time in the
lockstep order; actors interact only through bit-exact store payloads
and each actor processes its own jobs in tick order, so per-miner update
sequences — and the loss trajectory — equal the in-process oracle at the
same seed.  Payload-corrupting faults (tamper, free-ride) live in the
lockstep driver's process and are rejected here; drop/straggle are
schedule-only and supported.
"""
from __future__ import annotations

import dataclasses
import socket
import threading
import time
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import serde
from repro.api.config import SwarmConfig
from repro.api.keys import KeySchema
from repro.api.messages import (
    AnchorMsg,
    GradientMsg,
    HeartbeatMsg,
    ScoreMsg,
    SnapshotMsg,
    TickLossMsg,
    WeightUploadMsg,
)
from repro.api.phases import EventDriver
from repro.api.swarm import Swarm
from repro.api.transport import SocketTransport
from repro.common import cosine_similarity
from repro.configs.base import ModelConfig, TrainConfig
from repro.core import butterfly, compression
from repro.optim import adamw
from repro.optim.schedules import cosine_warmup
from repro.runtime import stage_model as sm
from repro.runtime.miner import Miner
from repro.runtime.network import FaultModel
from repro.runtime.validator import COSINE_THRESHOLD


class ActorStopped(Exception):
    """Raised inside an actor when a stop request interrupts polling."""


class ActorDied(RuntimeError):
    """A spawned actor process exited while the swarm still needed it."""

    def __init__(self, actor: str, exitcode: Optional[int]):
        super().__init__(
            f"actor process {actor!r} died (exit code {exitcode}) while "
            f"the epoch was in flight")
        self.actor = actor
        self.exitcode = exitcode


class WorkQueue:
    """Pull-based work discovery: an actor blocks on the store key that
    carries its next input instead of being called by a driver.

    ``await_key`` blocks until the key appears, a stop request lands
    (``ActorStopped``), the ``liveness`` hook raises (driver-side: a
    crashed peer), or ``timeout`` expires.  When the transport offers
    ``wait_for`` (``SocketTransport`` against a ``StoreServer``) the
    wait parks server-side on a condition variable in bounded slices —
    zero CPU while idle; otherwise it falls back to exists-polling at
    ``poll_interval``."""

    def __init__(self, transport, poll_interval: float = 0.001,
                 timeout: float = 120.0, liveness=None,
                 stop_event: Optional[threading.Event] = None,
                 liveness_every: int = 25):
        self.transport = transport
        self.poll_interval = poll_interval
        self.timeout = timeout
        self.liveness = liveness
        self.stop_event = stop_event
        self.liveness_every = max(int(liveness_every), 1)

    wait_slice = 0.25    # bounded server-side park: stop/liveness cadence

    def await_key(self, key: str) -> None:
        deadline = time.monotonic() + self.timeout
        wait_for = getattr(self.transport, "wait_for", None)
        polls = 0
        while True:
            if self.stop_event is not None and self.stop_event.is_set():
                raise ActorStopped(key)
            if self.liveness is not None \
                    and polls % self.liveness_every == 0:
                self.liveness()
            if wait_for is not None:
                if wait_for(key, timeout=self.wait_slice):
                    return
            else:
                if self.transport.exists(key):
                    return
                time.sleep(self.poll_interval)
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"work queue timed out after {self.timeout}s "
                    f"awaiting {key!r}")
            polls += 1

    def get(self, key: str, actor: str = "?") -> Any:
        self.await_key(key)
        return self.transport.get(key, actor=actor)


@runtime_checkable
class Actor(Protocol):
    """The surface every actor-process implementation must provide (the
    swarmlint ``protocol-conformance`` rule binds ``*Actor`` classes to
    this protocol; ``ActorProcess`` supplies the base implementation)."""
    actor: str

    def setup(self) -> None: ...

    def process_epoch(self, plan: dict) -> None: ...

    def status(self) -> HeartbeatMsg: ...

    def shutdown(self) -> None: ...


@dataclasses.dataclass(frozen=True)
class ActorSpec:
    """Picklable spawn arguments: everything a child process needs to
    rebuild its world deterministically (params re-derive from the seed,
    they never cross the process boundary at spawn)."""
    kind: str                 # "miner" | "validator"
    uid: int
    stage: int                # -1 for validators
    model_cfg: ModelConfig
    config: SwarmConfig
    train_cfg: TrainConfig
    store_address: tuple
    start_epoch: int = 0


class ActorProcess:
    """Base actor: spawn-context process body, own store connection,
    heartbeat/health endpoint over a tiny TCP socket, clean shutdown.

    The epoch loop awaits ``control/ep{E}/plan``, hands the decoded plan
    to ``process_epoch`` and advances; a plan with ``stop=True`` (or a
    ``stop`` op on the health endpoint) ends the loop cleanly."""

    health_poll = 0.2         # accept() timeout: stop-flag check cadence

    def __init__(self, spec: ActorSpec):
        self.spec = spec
        self.actor = f"{spec.kind}{spec.uid}"
        self.epoch = spec.start_epoch
        self.items_done = 0
        self.state = "init"
        self.transport: Optional[SocketTransport] = None
        self.queue: Optional[WorkQueue] = None
        self._stop = threading.Event()
        self._health_sock: Optional[socket.socket] = None
        self.model_spec: Optional[sm.SwarmModelSpec] = None

    # -- lifecycle -------------------------------------------------------

    def setup(self) -> None:
        S = self.spec.config
        self.transport = SocketTransport(self.spec.store_address,
                                         schema=KeySchema(version=3))
        self.queue = WorkQueue(self.transport, stop_event=self._stop)
        self.model_spec = sm.SwarmModelSpec(
            self.spec.model_cfg, S.n_stages, S.compress, S.bottleneck_dim)

    def status(self) -> HeartbeatMsg:
        import os
        return HeartbeatMsg(self.actor, pid=os.getpid(), epoch=self.epoch,
                            items_done=self.items_done, state=self.state)

    def shutdown(self) -> None:
        self._stop.set()
        if self._health_sock is not None:
            try:
                self._health_sock.close()
            except OSError:
                pass
            self._health_sock = None
        if self.transport is not None:
            self.transport.close()

    def process_epoch(self, plan: dict) -> None:
        raise NotImplementedError

    # -- health endpoint -------------------------------------------------

    def _serve_health(self) -> None:
        srv = self._health_sock
        while not self._stop.is_set():
            try:
                conn, _ = srv.accept()
            except (OSError, socket.timeout):
                if self._stop.is_set():
                    return
                continue
            try:
                conn.settimeout(2.0)
                while True:
                    frame = serde.recv_frame(conn)
                    if frame is None:
                        break
                    req = serde.loads(frame)
                    if req.get("op") == "stop":
                        self.state = "stopping"
                        self._stop.set()
                    serde.send_frame(conn,
                                     serde.encode_message(self.status()))
            except (OSError, socket.timeout, ConnectionError):
                pass
            finally:
                try:
                    conn.close()
                except OSError:
                    pass

    def run(self, ready_queue: Any = None) -> None:
        """Blocking process body: health endpoint up, report ready, loop
        epochs until a stop plan / stop ping / ActorStopped."""
        self.setup()
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.bind(("127.0.0.1", 0))
        srv.listen(4)
        srv.settimeout(self.health_poll)
        self._health_sock = srv
        threading.Thread(target=self._serve_health,
                         name=f"{self.actor}-health", daemon=True).start()
        if ready_queue is not None:
            ready_queue.put((self.actor, srv.getsockname()[:2]))
        try:
            while not self._stop.is_set():
                self.state = "awaiting-plan"
                plan_key = self.transport.schema.plan(self.epoch)
                while True:
                    try:
                        self.queue.await_key(plan_key)
                        break
                    except TimeoutError:
                        continue   # idle between epochs is not a failure
                plan = self.transport.get(plan_key, actor=self.actor)
                if plan.get("stop"):
                    break
                self.state = "working"
                self.process_epoch(plan)
                self.epoch += 1
        except ActorStopped:
            pass
        finally:
            self.state = "stopped"
            self.shutdown()


class MinerActor(ActorProcess):
    """A ``runtime.Miner`` driven by the store instead of the driver."""

    def __init__(self, spec: ActorSpec):
        super().__init__(spec)
        self.miner: Optional[Miner] = None

    def setup(self) -> None:
        super().setup()
        S = self.spec.config
        stage = self.spec.stage
        # same init as Swarm.register_miner: params copy the stage anchor,
        # which is init_stage_params at the folded seed — re-derived here
        # so no weights cross the spawn boundary
        params = sm.init_stage_params(
            jax.random.fold_in(jax.random.key(S.seed), stage),
            self.model_spec, stage)
        self.miner = Miner(self.spec.uid, stage, self.model_spec,
                           jax.tree.map(jnp.copy, params), self.transport,
                           self.spec.train_cfg)

    # -- the epoch -------------------------------------------------------

    def process_epoch(self, plan: dict) -> None:
        m = self.miner
        epoch = plan["epoch"]
        m.reset_epoch()
        if m.uid in set(plan["tracked"].values()):
            # epoch-start snapshot, before any tick mutates state: the
            # tracked validator replays from exactly here
            self.transport.publish(SnapshotMsg(epoch, m.uid), m.snapshot(),
                                   actor=self.actor)
        for tick, uids in plan["ticks"]:
            if uids[m.stage] != m.uid:
                continue
            self._process_tick(epoch, tick, uids)
            self.items_done += 1
        if plan["merge"]:
            self._share_and_sync(epoch, plan)

    def _process_tick(self, epoch: int, tick: int, uids: tuple) -> None:
        m, schema = self.miner, self.transport.schema
        s, last = m.stage, self.spec.config.n_stages - 1
        in_key = schema.tokens(epoch, tick) if s == 0 \
            else schema.activation(epoch, tick, s - 1, uids[s - 1])
        out_key = schema.activation(epoch, tick, s, m.uid)
        self.queue.await_key(in_key)
        m.forward(tick, in_key, out_key)
        if s == last:
            lab_key = schema.labels(epoch, tick)
            loss, g = m.backward_last(in_key,
                                      self.queue.get(lab_key, self.actor))
            # the training watermark the EventDriver folds into records
            self.transport.publish(TickLossMsg(epoch, tick), float(loss),
                                   actor=self.actor)
        else:
            g_key = schema.gradient_for(out_key)
            g = m.backward(in_key, self._decode_gradient(
                self.queue.get(g_key, self.actor)))
        if s > 0:
            self._publish_gradient(epoch, tick, s - 1, uids[s - 1], g)

    def _publish_gradient(self, epoch: int, tick: int, stage: int,
                          uid: int, g) -> None:
        msg = GradientMsg(epoch, tick, stage, uid)
        if self.spec.config.wire_codec == "int8":
            # the lockstep driver's int8 gradient wire, producer-side; the
            # extra "dtype" key lets the consumer replicate the exact
            # decode->astype the in-process loop applies (it knows g's
            # dtype in-process; over the wire it must be carried)
            flat = jnp.ravel(jnp.asarray(g, jnp.float32))
            payload = dict(compression.encode(flat, "int8"),
                           shape=tuple(np.shape(g)),
                           dtype=str(jnp.asarray(g).dtype))
            self.transport.publish(msg, payload, actor=self.actor)
        else:
            self.transport.publish(msg, g, actor=self.actor)

    def _decode_gradient(self, g):
        if isinstance(g, dict) and g.get("codec"):
            return jnp.reshape(compression.decode(g), g["shape"]).astype(
                serde._np_dtype(g["dtype"]))
        return g

    # -- sharing + sync --------------------------------------------------

    def _share_and_sync(self, epoch: int, plan: dict) -> None:
        m, S = self.miner, self.spec.config
        schema = self.transport.schema
        qual = plan["qualified"].get(m.stage, ())
        if m.uid in qual:
            vec = m.weights_vector()
            if S.sync_mode == "sharded":
                self._share_sharded(epoch, tuple(qual), vec)
            else:
                payload = compression.encode(jnp.asarray(vec), S.share_codec)
                self.transport.publish(
                    WeightUploadMsg(epoch, m.stage, m.uid,
                                    codec=S.share_codec),
                    payload, actor=self.actor)
        if m.stage in plan["qualified"]:
            # full sync: everyone in a merged stage (stragglers included)
            # downloads the anchor the driver publishes
            anchor = AnchorMsg(epoch, m.stage)
            self.queue.await_key(anchor.key(schema))
            m.load_weights_vector(self.transport.fetch(anchor,
                                                       actor=self.actor))

    def _share_sharded(self, epoch: int, qual: tuple, vec) -> None:
        m, S = self.miner, self.spec.config
        align = compression.INT8_BLOCK if S.share_codec == "int8" else 1
        plan_b = butterfly.make_plan(len(qual), int(vec.shape[0]),
                                     seed=S.seed + epoch * 131 + m.stage,
                                     align=align)
        ex = butterfly.ButterflyExecutor(
            plan_b, self.transport, epoch=epoch, stage=m.stage,
            uids=list(qual), codec=S.share_codec)
        idx = list(qual).index(m.uid)
        ex.upload_vector(idx, vec, actor=self.actor)
        # reduce_one masks *missing* uploads out of the merge, so every
        # input must exist before reducing — await them all (the lockstep
        # phase barrier, reduced to exactly the keys this reducer reads)
        for a in ex.assignments_for(idx):
            for key in a.upload_keys:
                self.queue.await_key(key)
        m.run_reduce(ex, idx)


class ValidatorActor(ActorProcess):
    """Replays its tracked miner purely from store artifacts (snapshot,
    activations, gradients, labels), mirroring
    ``Validator.validate_epoch`` operation for operation, then publishes
    the ``ScoreMsg`` watermark the driver's ledger waits on."""

    def __init__(self, spec: ActorSpec):
        super().__init__(spec)
        self.opt = None

    def setup(self) -> None:
        super().setup()
        tc = self.spec.train_cfg
        # the same inner optimizer Miner builds: replayed updates must
        # track the miner's own update rule exactly
        self.opt = adamw(cosine_warmup(tc.lr, tc.warmup_steps, 10_000),
                         beta1=tc.beta1, beta2=tc.beta2,
                         weight_decay=tc.weight_decay)

    def process_epoch(self, plan: dict) -> None:
        S = self.spec.config
        schema = self.transport.schema
        epoch = plan["epoch"]
        uid = plan["tracked"].get(self.spec.uid)
        if uid is None:
            return
        stage = plan["stage_of"][uid]
        role = self.model_spec.role(stage)
        snap = self.queue.get(schema.snapshot(epoch, uid), self.actor)
        params = jax.tree.map(jnp.asarray, snap["params"])
        opt_state = jax.tree.map(jnp.asarray, snap["opt_state"])
        inner_step = jnp.asarray(snap["inner_step"])

        items = [(t, uids) for t, uids in plan["ticks"]
                 if uids[stage] == uid]
        if S.validate_max_items is not None:
            items = items[:S.validate_max_items]

        checked = passed = 0
        validated = 0.0
        min_cos = 1.0
        for tick, uids in items:
            sample_key = schema.tokens(epoch, tick) if stage == 0 \
                else schema.activation(epoch, tick, stage - 1,
                                       uids[stage - 1])
            out_key = schema.activation(epoch, tick, stage, uid)
            x_in = self.queue.get(sample_key, self.actor)
            mine = sm.stage_forward(params, x_in, self.model_spec, role)
            theirs = self.queue.get(out_key, self.actor)
            cos = float(cosine_similarity(jnp.asarray(mine, jnp.float32),
                                          jnp.asarray(theirs, jnp.float32)))
            checked += 1
            min_cos = min(min_cos, cos)
            ok = cos >= COSINE_THRESHOLD
            passed += int(ok)
            # every scheduled pathway item ran a backward; replay it so
            # later items line up (same as Validator.validate_epoch)
            if role == "last":
                labels = self.queue.get(schema.labels(epoch, tick),
                                        self.actor)
                _, g_params, _ = sm.last_stage_loss_and_grads(
                    params, x_in, labels, self.model_spec)
            else:
                g_out = self.queue.get(schema.gradient_for(out_key),
                                       self.actor)
                if isinstance(g_out, dict) and g_out.get("codec"):
                    g_out = jnp.reshape(compression.decode(g_out),
                                        g_out["shape"])
                g_params, _ = sm.stage_backward(params, x_in, g_out,
                                                self.model_spec, role)
            params, opt_state = self.opt.update(g_params, opt_state,
                                                params, inner_step)
            inner_step = inner_step + 1
            if ok:
                validated += 1.0
            self.items_done += 1

        self.transport.publish(
            ScoreMsg(epoch, self.spec.uid, uid),
            np.asarray([validated, checked, passed, min_cos], np.float32),
            actor=self.actor)


def _child_main(spec: ActorSpec, ready_queue: Any) -> None:
    """Spawn entry point (module-level: the child pickles a reference)."""
    cls = MinerActor if spec.kind == "miner" else ValidatorActor
    cls(spec).run(ready_queue)


class ActorSupervisor:
    """Owns the actor process fleet: spawn, health pings, stop, and the
    liveness check that turns a dead child into ``ActorDied``."""

    def __init__(self):
        self.procs: dict[str, Any] = {}
        self.health: dict[str, tuple] = {}

    def spawn(self, specs: list) -> None:
        import multiprocessing as mp
        import queue as queue_mod

        ctx = mp.get_context("spawn")
        ready = ctx.Queue()
        for spec in specs:
            name = f"{spec.kind}{spec.uid}"
            proc = ctx.Process(target=_child_main, args=(spec, ready),
                               daemon=True, name=name)
            proc.start()
            self.procs[name] = proc
        pending = len(specs)
        while pending:
            try:
                name, addr = ready.get(timeout=0.5)
                self.health[name] = (str(addr[0]), int(addr[1]))
                pending -= 1
            except queue_mod.Empty:
                for name, proc in self.procs.items():
                    if not proc.is_alive():
                        raise ActorDied(name, proc.exitcode)

    def _health_request(self, name: str, op: str,
                        timeout: float = 5.0) -> HeartbeatMsg:
        addr = self.health[name]
        with socket.create_connection(addr, timeout=timeout) as sock:
            serde.send_frame(sock, serde.dumps({"op": op}))
            frame = serde.recv_frame(sock)
        if frame is None:
            raise ConnectionError(f"health endpoint of {name!r} closed")
        return serde.decode_message(frame)

    def ping(self, name: str) -> HeartbeatMsg:
        return self._health_request(name, "ping")

    def stop(self, name: str) -> None:
        try:
            self._health_request(name, "stop", timeout=2.0)
        except (OSError, ConnectionError):
            pass                     # already gone: stopping is idempotent

    def check(self) -> None:
        """Raise ``ActorDied`` if any child exited — called from await
        loops so a crash surfaces immediately instead of as a timeout."""
        for name, proc in self.procs.items():
            if not proc.is_alive():
                raise ActorDied(name, proc.exitcode)

    def join_all(self, timeout: float = 10.0) -> None:
        deadline = time.monotonic() + timeout
        for proc in self.procs.values():
            proc.join(timeout=max(deadline - time.monotonic(), 0.1))

    def terminate_all(self) -> None:
        for proc in self.procs.values():
            if proc.is_alive():
                proc.terminate()
        for proc in self.procs.values():
            proc.join(timeout=2.0)

    @property
    def names(self) -> list[str]:
        return sorted(self.procs)


class ActorSwarm(Swarm):
    """``Swarm`` whose miners and validators are concurrent processes.

    The parent keeps the facade state (anchors, outer optimizer, ledger,
    corpus, RNG — and placeholder ``Miner`` objects used only for uids /
    stages / census), the ``EventDriver`` timeline, and the supervisor;
    all forward/backward/replay compute runs in the children.  With no
    ``store_address`` an in-process threaded ``StoreServer`` is started
    and owned (real sockets, no extra spawn cost); pass an address to
    point the whole swarm at an external store process instead.

        swarm = Swarm.create(model_cfg, cfg, runtime="actors")
        try:
            stats = swarm.run(3)      # actors spawn on first epoch
        finally:
            swarm.shutdown()
    """

    def __init__(self, model_cfg: ModelConfig,
                 config: Optional[SwarmConfig] = None, *,
                 faults: Optional[FaultModel] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 store_address: Optional[tuple] = None,
                 driver: Optional[EventDriver] = None):
        config = config or SwarmConfig()
        faults = faults or FaultModel({}, seed=config.seed)
        for uid, b in sorted(faults.behaviors.items()):
            if not b.honest:
                raise ValueError(
                    f"runtime='actors' cannot inject payload-corrupting "
                    f"faults (miner {uid}: tamper/free-ride): corruption "
                    f"is driver-side in the lockstep timeline; use the "
                    f"in-process runtime for adversarial scenarios")
        self._own_server = None
        if store_address is None:
            from repro.runtime.store_server import StoreServer
            self._own_server = StoreServer().start()
            store_address = self._own_server.address
        self.store_address = (str(store_address[0]), int(store_address[1]))
        transport = SocketTransport(self.store_address,
                                    schema=KeySchema(version=3))
        super().__init__(model_cfg, config, faults=faults,
                         transport=transport, train_cfg=train_cfg,
                         driver=driver or EventDriver())
        self.supervisor = ActorSupervisor()
        self._started = False

    # -- fleet lifecycle -------------------------------------------------

    def start(self) -> "ActorSwarm":
        if self._started:
            return self
        specs = [ActorSpec("miner", m.uid, m.stage, self.cfg, self.config,
                           self.train_cfg, self.store_address,
                           start_epoch=self.epoch)
                 for m in self.miners.values()]
        specs += [ActorSpec("validator", v.uid, -1, self.cfg, self.config,
                            self.train_cfg, self.store_address,
                            start_epoch=self.epoch)
                  for v in self.validators]
        self.supervisor.spawn(specs)
        self._started = True
        return self

    def check_liveness(self) -> None:
        """The EventDriver's await-loop hook: a dead child is an
        ``ActorDied`` now, not a watermark timeout two minutes later."""
        if self._started:
            self.supervisor.check()

    def run_epoch(self):
        self.start()
        return self.driver.run_epoch(self)

    def shutdown(self, stop_server: bool = True) -> None:
        """Stop the fleet (stop plan for the next epoch + health-endpoint
        stop pings), join, terminate stragglers, then stop the owned
        store server.  Idempotent."""
        from repro.api.messages import EpochPlanMsg
        if self._started:
            try:
                self.transport.publish(
                    EpochPlanMsg(self.epoch),
                    {"stop": True, "epoch": self.epoch},
                    actor="orchestrator")
            except (OSError, RuntimeError, ConnectionError):
                pass                 # store already down: fall through
            for name in self.supervisor.names:
                self.supervisor.stop(name)
            self.supervisor.join_all(timeout=10.0)
            self.supervisor.terminate_all()
            self._started = False
        if self._own_server is not None and stop_server:
            self._own_server.stop()
            self._own_server = None
        self.transport.close()

    def __enter__(self) -> "ActorSwarm":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
