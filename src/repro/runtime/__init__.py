from repro.runtime.network import FaultModel, MinerBehavior  # noqa: F401
from repro.runtime.state_store import StateStore, StoreKeyError  # noqa: F401

# Orchestrator/SwarmConfig re-export lazily (PEP 562): orchestrator.py and
# store_server.py sit on top of repro.api, which itself imports runtime
# submodules — an eager import here would make ``import repro.api`` hit
# this package mid-cycle.
_LAZY = {
    "Orchestrator": "orchestrator",
    "SwarmConfig": "orchestrator",
    "EpochStats": "orchestrator",
    "StoreServer": "store_server",
    "spawn_store_server": "store_server",
    # the concurrent actor runtime (actor.py imports repro.api too)
    "ActorSwarm": "actor",
    "ActorProcess": "actor",
    "ActorSupervisor": "actor",
    "ActorSpec": "actor",
    "MinerActor": "actor",
    "ValidatorActor": "actor",
    "WorkQueue": "actor",
    "ActorDied": "actor",
    "ActorStopped": "actor",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.runtime.{_LAZY[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
