from repro.runtime.orchestrator import Orchestrator, SwarmConfig  # noqa: F401
from repro.runtime.network import FaultModel, MinerBehavior  # noqa: F401
from repro.runtime.state_store import StateStore  # noqa: F401
