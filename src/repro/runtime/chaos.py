"""Deterministic fault injection: ``ChaosTransport`` + ``FaultSchedule``.

The paper's operating environment is a permissionless swarm on consumer
links: puts get dropped, connections reset mid-epoch, payloads arrive
corrupted, the store partitions.  ``runtime.network.FaultModel`` injects
*behavioral* faults (a miner straggles or tampers); this module injects
*infrastructure* faults at the transport seam, so every scenario in
``repro.scenarios`` can compose them with any runtime — lockstep,
simulated-network, socket, or the spawned actor fleet — without touching
a single core-loop line.

``ChaosTransport`` wraps any ``Transport`` (``InProcessTransport`` and
``SocketTransport`` compose unchanged) and consults a seeded
``FaultSchedule`` on every operation.  The determinism contract, pinned
by tests and documented in docs/CHAOS.md: the schedule's RNG draws
happen in this wrapper's own operation order, so the same seed over the
same workload produces the same fault sequence — and because every
injected fault is one the system is built to absorb, the same loss
trajectory:

  * **dropped puts** are terminal but restricted to redundant planes
    (``drop_kinds``, default the butterfly's ``shard_reduced`` copies:
    §5.2 gives every shard two independent reducers precisely so one
    copy can vanish);
  * **dropped gets** model a flaky read: the first attempt "fails"
    (costing ``latency_s``) and the wrapper retries — the application
    never sees the fault, only the delay;
  * **injected latency** sleeps on a seeded coin flip (slow-link
    scenarios; trajectory-neutral by construction);
  * **connection resets** sever the inner ``SocketTransport``'s TCP
    sockets *without* clearing its pipeline — exercising the bounded
    reconnect + pending-replay path on a live workload;
  * **payload corruption** perturbs eligible puts (``corrupt_kinds``) —
    the consensus collect / reduce audit must catch it downstream;
  * **store partitions** are visibility blackouts: for a window of
    operations, ``exists``/``wait_for``/``keys`` report nothing new.
    Await-based consumers (``WorkQueue``, ``EventDriver``) simply wait
    out the window.  Do NOT enable partitions for lockstep *sharded*
    sync: ``ButterflyExecutor.reduce_one`` masks "missing" uploads out
    of the merge, so a hidden upload silently changes the anchor.

Everything is counted in ``chaos_report()`` so benchmarks can record
faults injected alongside recovery latency.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import zlib
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Seeded, picklable description of what goes wrong and how often.

    ``seed`` is mandatory and first: a scenario must *declare* its fault
    schedule seed (the swarmlint ``scenario-conformance`` rule checks
    this), because the determinism contract — same seed, same fault
    sequence, same trajectory — is the whole point."""
    seed: int
    drop_put: float = 0.0          # P(terminally drop an eligible put)
    drop_kinds: tuple = ("shard_reduced",)
    drop_get: float = 0.0          # P(first read attempt fails; retried)
    latency_prob: float = 0.0      # P(an op pays latency_s)
    latency_s: float = 0.0
    reset_every: int = 0           # sever TCP conns every N ops (0 = never)
    corrupt_put: float = 0.0       # P(corrupt an eligible put's payload)
    corrupt_kinds: tuple = ("shard_reduced",)
    corrupt_scale: float = 0.25    # additive offset (tamper semantics)
    partition_every: int = 0       # open a blackout every N ops (0 = never)
    partition_ops: int = 0         # ...hiding the next N visibility reads

    def __post_init__(self):
        for p in (self.drop_put, self.drop_get, self.latency_prob,
                  self.corrupt_put):
            assert 0.0 <= p <= 1.0, f"probabilities must be in [0,1]: {p}"


class ChaosTransport:
    """A ``Transport`` that injects a ``FaultSchedule`` between the caller
    and any inner transport.  Unknown attributes (``wire_report``,
    ``ping``, ``stop_server``, ``store`` ...) delegate to the inner
    transport, so the wrapper is drop-in everywhere the inner one was."""

    def __init__(self, inner, schedule: FaultSchedule,
                 actor_tag: str = ""):
        self.inner = inner
        self.schedule = schedule
        self.schema = inner.schema
        # per-wrapper RNG: each wrapped transport draws in its own op
        # order (deterministic per actor process / per lockstep run)
        self._rng = np.random.RandomState(
            (schedule.seed ^ zlib.crc32(actor_tag.encode())) & 0x7FFFFFFF)
        self._ops = 0
        self._partition_until = -1
        self.injected = {"dropped_puts": 0, "retried_gets": 0, "delays": 0,
                         "resets": 0, "corrupted_puts": 0, "partitions": 0}

    # -- schedule machinery ----------------------------------------------

    def _tick(self) -> None:
        """One operation: advance counters, fire reset/partition/latency."""
        self._ops += 1
        sch = self.schedule
        if sch.reset_every and self._ops % sch.reset_every == 0:
            self._sever()
        if (sch.partition_every and sch.partition_ops
                and self._ops % sch.partition_every == 0
                and self._ops > self._partition_until):
            self._partition_until = self._ops + sch.partition_ops
            self.injected["partitions"] += 1
        if sch.latency_prob and self._rng.rand() < sch.latency_prob:
            self._delay()

    def _delay(self) -> None:
        if self.schedule.latency_s > 0:
            time.sleep(self.schedule.latency_s)
        self.injected["delays"] += 1

    def _partitioned(self) -> bool:
        return self._ops <= self._partition_until

    def _sever(self) -> None:
        """Simulate a peer RST: close the inner transport's live sockets
        *without* clearing its pipelined state — the next request must
        reconnect and replay (``SocketTransport._io``)."""
        conns = getattr(self.inner, "_conns", None)
        if conns is None:
            return                       # in-process inner: nothing to sever
        for conn in list(conns.values()):
            sock = conn.sock
            if sock is not None:
                try:
                    sock.close()
                except OSError:
                    pass
                conn.sock = None
        self.injected["resets"] += 1

    def _kind(self, key: str) -> Optional[str]:
        try:
            return self.schema.parse(key).kind
        except ValueError:
            return None

    def _corrupt(self, value: Any) -> Any:
        """Additive perturbation of the float payload (the same semantics
        as ``FaultModel`` tamper, so agreement/audit thresholds apply)."""
        off = np.float32(self.schedule.corrupt_scale)

        def bend(x):
            arr = np.asarray(x)
            if arr.dtype.kind == "f":
                return arr + off.astype(arr.dtype)
            return x

        if isinstance(value, dict):
            return {k: bend(v) if not isinstance(v, (dict, str, tuple))
                    else v for k, v in value.items()}
        return bend(value)

    def chaos_report(self) -> dict:
        return dict(self.injected, ops=self._ops)

    # -- typed plane -----------------------------------------------------

    def publish(self, msg, payload: Any, actor: str = "?",
                meta: Optional[dict] = None) -> str:
        return self.put(msg.key(self.schema), payload, actor=actor,
                        meta=meta)

    def fetch(self, msg, actor: str = "?") -> Any:
        return self.get(msg.key(self.schema), actor=actor)

    # -- raw plane -------------------------------------------------------

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> str:
        self._tick()
        sch = self.schedule
        kind = None
        if sch.drop_put or sch.corrupt_put:
            kind = self._kind(key)
        if sch.drop_put and kind in sch.drop_kinds \
                and self._rng.rand() < sch.drop_put:
            # terminal drop: the payload never reaches the store.  The
            # digest of what WOULD have been stored is still returned —
            # callers treat put as fire-and-forget, redundancy absorbs it
            from repro.runtime.state_store import _digest
            self.injected["dropped_puts"] += 1
            return _digest(value)
        if sch.corrupt_put and kind in sch.corrupt_kinds \
                and self._rng.rand() < sch.corrupt_put:
            value = self._corrupt(value)
            self.injected["corrupted_puts"] += 1
        return self.inner.put(key, value, actor=actor, codec=codec,
                              meta=meta)

    def get(self, key: str, actor: str = "?") -> Any:
        self._tick()
        if self.schedule.drop_get \
                and self._rng.rand() < self.schedule.drop_get:
            # flaky read: first attempt fails, pay the latency, retry —
            # the caller sees the delay, never the failure
            self._delay()
            self.injected["retried_gets"] += 1
        return self.inner.get(key, actor=actor)

    def exists(self, key: str) -> bool:
        self._tick()
        if self._partitioned():
            return False
        return self.inner.exists(key)

    def wait_for(self, key: str, timeout: float = 0.5,
                 actor: str = "?") -> bool:
        self._tick()
        if self._partitioned():
            time.sleep(min(timeout, 0.05))   # blackout: nothing to see
            return False
        inner_wait = getattr(self.inner, "wait_for", None)
        if inner_wait is not None:
            return inner_wait(key, timeout=timeout, actor=actor)
        # emulate over transports without a server-side wait op
        deadline = time.monotonic() + timeout
        while not self.inner.exists(key):
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.002)
        return True

    def delete_prefix(self, prefix: str) -> int:
        self._tick()
        return self.inner.delete_prefix(prefix)

    def keys(self, prefix: str = "") -> list[str]:
        self._tick()
        if self._partitioned():
            return []
        return self.inner.keys(prefix)

    # -- timing / accounting ---------------------------------------------

    @contextlib.contextmanager
    def parallel(self):
        with self.inner.parallel():
            yield

    def traffic_report(self) -> dict:
        return self.inner.traffic_report()

    def link_report(self) -> dict:
        return self.inner.link_report()

    def elapsed_seconds(self) -> float:
        return self.inner.elapsed_seconds()

    # -- lifecycle / passthrough -----------------------------------------

    def close(self) -> None:
        close = getattr(self.inner, "close", None)
        if close is not None:
            close()

    def __getattr__(self, name: str):
        # everything else (wire_report, ping, reset_store, store, ...)
        # behaves exactly like the inner transport
        return getattr(self.inner, name)

    def __enter__(self) -> "ChaosTransport":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def wrap_transport(inner, schedule: Optional[FaultSchedule],
                   actor_tag: str = ""):
    """Wrap ``inner`` when a schedule is given; identity otherwise — the
    one-liner actor/scenario code uses so 'no chaos' stays zero-cost."""
    if schedule is None:
        return inner
    return ChaosTransport(inner, schedule, actor_tag=actor_tag)
