"""Miner: one layer-slice worker (paper §2.2).

Holds stage params + a local inner optimizer (the DiLoCo inner loop), streams
activations through its Transport (in-process or simulated-network), keeps a
local work log that validators can replay bit-exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, TYPE_CHECKING

import jax
from jax.flatten_util import ravel_pytree
import jax.numpy as jnp
import numpy as np

from repro.common import tree_cast
from repro.configs.base import TrainConfig
from repro.optim import adamw
from repro.optim.schedules import cosine_warmup
from repro.runtime import stage_model as sm

if TYPE_CHECKING:
    from repro.api.transport import Transport


@dataclasses.dataclass
class WorkItem:
    """One forward(+backward) unit, logged for validator replay."""
    tick: int
    sample_key: str          # store key of the input activation / tokens
    out_key: str             # store key of this miner's uploaded output
    did_backward: bool = False


@dataclasses.dataclass
class ReduceWorkItem:
    """One butterfly reduce unit (sharded sync): the shard-upload keys this
    miner downloaded and the reduced-copy key it re-uploaded.  Logged so
    CLASP/replay cover reduce work the same way forward/backward work is
    covered — a validator recomputes the masked merge from the same store
    inputs and compares against the uploaded copy."""
    shard: int
    in_keys: tuple[str, ...]
    out_key: str


class Miner:
    def __init__(self, uid: int, stage: int, spec: sm.SwarmModelSpec,
                 params: Any, transport: "Transport",
                 train_cfg: Optional[TrainConfig] = None):
        self.uid = uid
        self.stage = stage
        self.spec = spec
        self.role = spec.role(stage)
        self.transport = transport
        self.params = params
        tc = train_cfg or TrainConfig(lr=1e-3, warmup_steps=20)
        self.opt = adamw(cosine_warmup(tc.lr, tc.warmup_steps, 10_000),
                         beta1=tc.beta1, beta2=tc.beta2,
                         weight_decay=tc.weight_decay)
        self.opt_state = self.opt.init(params)
        self.inner_step = jnp.zeros((), jnp.int32)
        self.batches_done = 0
        self.work_log: list[WorkItem] = []
        self.reduce_log: list[ReduceWorkItem] = []
        self._pending: dict[str, Any] = {}     # sample_key -> input (for bwd)

    # ------------------------------------------------------------------

    @property
    def actor(self) -> str:
        return f"miner{self.uid}"

    def forward(self, tick: int, sample_key: str, out_key: str) -> Any:
        """Read input from the store, apply the stage, upload the output."""
        x_in = self.transport.get(sample_key, actor=self.actor)
        out = sm.stage_forward(self.params, x_in, self.spec, self.role)
        self._pending[sample_key] = x_in
        self.transport.put(out_key, out, actor=self.actor)
        self.work_log.append(WorkItem(tick, sample_key, out_key))
        return out

    def backward_last(self, sample_key: str, labels) -> tuple[float, Any]:
        """Last-stage miner: compute loss + grads, return (loss, g_z_in)."""
        z_in = self._pending.pop(sample_key)
        loss, g_params, g_z = sm.last_stage_loss_and_grads(
            self.params, z_in, labels, self.spec)
        self._apply(g_params)
        return float(loss), g_z

    def backward(self, sample_key: str, g_out) -> Any:
        """Mid/first miner: VJP through the recomputed stage forward."""
        x_in = self._pending.pop(sample_key)
        g_params, g_x = sm.stage_backward(self.params, x_in, g_out,
                                          self.spec, self.role)
        self._apply(g_params)
        return g_x

    def _apply(self, grads) -> None:
        self.params, self.opt_state = self.opt.update(
            grads, self.opt_state, self.params, self.inner_step)
        self.inner_step = self.inner_step + 1
        self.batches_done += 1
        if self.work_log:
            self.work_log[-1].did_backward = True

    # ------------------------------------------------------------------
    # weight exchange (flattened fp32 vector, per paper §5.1 sharding)
    # ------------------------------------------------------------------

    def weights_vector(self) -> np.ndarray:
        flat, _ = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), self.params))
        return np.asarray(flat)

    def run_reduce(self, executor, idx: int, tamper: float = 0.0) -> int:
        """Perform this miner's assigned butterfly reduce work through the
        store (``executor`` is a ``core.butterfly.ButterflyExecutor``; this
        miner is plan index ``idx``).  Every download/upload is charged to
        this miner's link.  ``tamper`` is the fault-injection hook (a
        deceptive reducer offsets its copies).  Returns shards reduced."""
        done = executor.run_reducer(idx, actor=self.actor, tamper=tamper)
        self.reduce_log.extend(
            ReduceWorkItem(a.shard, a.upload_keys, a.reduced_key)
            for a in done)
        return len(done)

    def load_weights_vector(self, vec: np.ndarray) -> None:
        flat, unravel = ravel_pytree(
            jax.tree.map(lambda x: x.astype(jnp.float32), self.params))
        new = unravel(jnp.asarray(vec, jnp.float32))
        self.params = jax.tree.map(lambda n, p: n.astype(p.dtype),
                                   new, self.params)

    def reset_epoch(self) -> None:
        self.batches_done = 0
        self.work_log = []
        self.reduce_log = []
        self._pending = {}

    def snapshot(self) -> dict:
        """State a validator copies at full sync to track this miner."""
        return {"params": jax.tree.map(jnp.copy, self.params),
                "opt_state": jax.tree.map(jnp.copy, self.opt_state),
                "inner_step": self.inner_step}
