"""The globally accessible database of paper §2 ('S3 bucket', Fig 6).

All miner/validator/orchestrator traffic flows through here, which is what
makes interactions auditable ('making it easy to trace the movement of
information').  In-process dict with:
  * content digests (tamper evidence for validators),
  * byte accounting per (namespace, direction) — the §5.3 transfer-analysis
    benchmark reads these counters,
  * optional wire codec applied on put (compressed sharing stage).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Optional

import jax
from jax.flatten_util import ravel_pytree
import numpy as np

from repro.core import compression


class StoreKeyError(KeyError):
    """Missing store key, with enough context to debug a routing bug:
    the key, who asked, and the nearest prefix that *does* exist (so an
    off-by-one epoch/tick/uid is visible at a glance)."""

    def __init__(self, key: str, actor: str = "?",
                 nearest_prefix: str = "", nearest_count: int = 0):
        self.key = key
        self.actor = actor
        self.nearest_prefix = nearest_prefix
        self.nearest_count = nearest_count
        if nearest_prefix:
            hint = (f"nearest existing prefix {nearest_prefix!r} "
                    f"({nearest_count} keys)")
        else:
            hint = "store is empty" if nearest_count == 0 else \
                f"no shared prefix ({nearest_count} keys in store)"
        super().__init__(
            f"store key not found: {key!r} (requested by {actor!r}; {hint})")

    def __str__(self) -> str:  # KeyError.__str__ repr()s the arg; undo that
        return self.args[0]


@dataclasses.dataclass
class StoreEntry:
    payload: Any
    nbytes: int
    digest: str
    meta: dict


def _nbytes(value: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        arr = np.asarray(leaf)
        total += arr.nbytes
    return total


def _digest(value: Any) -> str:
    import hashlib
    h = hashlib.blake2b(digest_size=12)
    for leaf in jax.tree_util.tree_leaves(value):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()


class StateStore:
    def __init__(self):
        self._data: dict[str, StoreEntry] = {}
        self.uploaded = defaultdict(int)      # namespace -> bytes
        self.downloaded = defaultdict(int)
        self.uploads_by_actor = defaultdict(int)
        self.downloads_by_actor = defaultdict(int)

    @staticmethod
    def _ns(key: str) -> str:
        return key.split("/", 1)[0]

    @staticmethod
    def _under(key: str, prefix: str) -> bool:
        """Segment-boundary prefix match: ``weights/ep1`` covers
        ``weights/ep1/...`` and the exact key, but *not* ``weights/ep10/...``
        (a raw ``startswith`` collided ep1 with ep10+ and s1 with s10+,
        so epoch GC and stage-scoped audit walks leaked across segments).
        A trailing-``/`` prefix keeps its literal meaning; the empty prefix
        covers everything."""
        if not prefix:
            return True
        if prefix.endswith("/"):
            return key.startswith(prefix)
        return key == prefix or key.startswith(prefix + "/")

    def put(self, key: str, value: Any, actor: str = "?",
            codec: Optional[str] = None,
            meta: Optional[dict] = None) -> StoreEntry:
        """Store ``value``; returns the full ``StoreEntry`` so callers that
        need the byte count (the simulated-network hot loop) don't pay a
        second lookup.  The entry carries the digest for tamper evidence."""
        if codec and codec != "none":
            flat, _ = ravel_pytree(value)
            value = compression.encode(flat, codec)
        nbytes = _nbytes(value)
        digest = _digest(value)
        entry = StoreEntry(value, nbytes, digest,
                           dict(meta or {}, codec=codec or "none"))
        self._data[key] = entry
        self.uploaded[self._ns(key)] += nbytes
        self.uploads_by_actor[actor] += nbytes
        return entry

    def _nearest_prefix(self, key: str) -> tuple[str, int]:
        """Longest '/'-segment prefix of ``key`` under which keys exist."""
        parts = key.split("/")
        for i in range(len(parts), 0, -1):
            p = "/".join(parts[:i])
            n = sum(1 for k in self._data if self._under(k, p))
            if n:
                return p, n
        return "", len(self._data)

    def _missing(self, key: str, actor: str) -> StoreKeyError:
        prefix, count = self._nearest_prefix(key)
        return StoreKeyError(key, actor, prefix, count)

    def get(self, key: str, actor: str = "?") -> Any:
        return self.fetch_entry(key, actor).payload

    def fetch_entry(self, key: str, actor: str = "?") -> StoreEntry:
        """Accounted read returning the full entry (payload + nbytes +
        digest) — one dict lookup for callers that also need the size."""
        entry = self._data.get(key)
        if entry is None:
            raise self._missing(key, actor)
        self.downloaded[self._ns(key)] += entry.nbytes
        self.downloads_by_actor[actor] += entry.nbytes
        return entry

    def get_entry(self, key: str) -> StoreEntry:
        entry = self._data.get(key)
        if entry is None:
            raise self._missing(key, "?")
        return entry

    def exists(self, key: str) -> bool:
        return key in self._data

    def delete_prefix(self, prefix: str) -> int:
        doomed = [k for k in self._data if self._under(k, prefix)]
        for k in doomed:
            del self._data[k]
        return len(doomed)

    def keys(self, prefix: str = "") -> list[str]:
        return sorted(k for k in self._data if self._under(k, prefix))

    def traffic_report(self) -> dict:
        return {
            "uploaded": dict(self.uploaded),
            "downloaded": dict(self.downloaded),
            "by_actor_up": dict(self.uploads_by_actor),
            "by_actor_down": dict(self.downloads_by_actor),
            "total_bytes": (sum(self.uploaded.values())
                            + sum(self.downloaded.values())),
        }
