"""Orchestrator — backward-compatible facade over ``repro.api.Swarm``.

The hub of the hub-and-spoke architecture (paper §2, §2.1) used to live
here as a ~320-line monolith; it is now built from the peer-protocol API:

  * typed messages + versioned ``KeySchema``   repro.api.messages / .keys
  * pluggable ``Transport``                    repro.api.transport
  * phase objects + ``EpochDriver``            repro.api.phases
  * the ``Swarm`` facade                       repro.api.swarm

This module keeps the seed constructor signature (``store=`` takes a raw
``StateStore``) and re-exports ``SwarmConfig``/``EpochStats`` so existing
tests, examples and benchmarks keep working unchanged.  New code should use
``Swarm.create(...)`` directly — see docs/API.md.
"""
from __future__ import annotations

from typing import Optional

from repro.api.config import EpochStats, SwarmConfig  # noqa: F401
from repro.api.swarm import Swarm
from repro.api.transport import InProcessTransport
from repro.configs.base import ModelConfig, TrainConfig
from repro.runtime.network import FaultModel
from repro.runtime.state_store import StateStore


class Orchestrator(Swarm):
    """Seed-compatible constructor: wraps a ``StateStore`` in the zero-
    latency ``InProcessTransport`` (bit-identical trajectories)."""

    def __init__(self, model_cfg: ModelConfig, swarm: SwarmConfig,
                 faults: Optional[FaultModel] = None,
                 store: Optional[StateStore] = None,
                 train_cfg: Optional[TrainConfig] = None):
        super().__init__(model_cfg, swarm, faults=faults,
                         transport=InProcessTransport(store=store),
                         train_cfg=train_cfg)
