"""Orchestrator (paper §2, §2.1): the hub of the hub-and-spoke architecture.

Drives the four-stage epoch timeline of Fig 2:
  1. *training*       — samples stream along CLASP-sampled pathways (one
                         miner per stage); forward codes + backward grads
                         transit the StateStore; miners update locally
                         (DiLoCo inner steps); SWARM-style rerouting around
                         dropped miners; stragglers finish fewer batches.
  2. *compressed sharing* — qualifying miners (B_m >= B_min, §2.1 quorum)
                         upload int8-compressed weights within their layer.
  3. *full sync*      — butterfly all-reduce per layer merges weights
                         (agreement matrix exposes tamperers), the DiLoCo
                         outer Nesterov step updates the per-stage anchor,
                         everyone (including joiners) downloads the anchor.
  4. *validation*     — validators replay tracked miners from their sync
                         snapshots and write scores to the incentive ledger.

Everything is seeded and deterministic: the same SwarmConfig reproduces the
same training trajectory, which is also what makes validator replay exact.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.flatten_util import ravel_pytree
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import butterfly, clasp, compression, diloco
from repro.core.incentives import IncentiveLedger
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.runtime import stage_model as sm
from repro.runtime.miner import Miner
from repro.runtime.network import FaultModel, MinerBehavior
from repro.runtime.state_store import StateStore
from repro.runtime.validator import Validator


@dataclasses.dataclass(frozen=True)
class SwarmConfig:
    n_stages: int = 3
    miners_per_stage: int = 3
    inner_steps: int = 8              # ticks per epoch (training stage)
    b_min: int = 4                    # BATCHES_BEFORE_MERGING
    quorum_frac: float = 0.5
    batch_size: int = 4
    seq_len: int = 32
    compress: bool = True
    bottleneck_dim: int = 16
    share_codec: str = "int8"         # compressed-sharing stage codec
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    gamma_hours: float = 10.0         # score decay
    sync_interval_hours: float = 0.5  # T_s
    validators: int = 1
    validate_max_items: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class EpochStats:
    epoch: int
    mean_loss: float
    b_eff: int
    batches: dict[int, int]
    merged_stages: int
    stalled_ticks: int
    agreement: dict[int, np.ndarray]      # stage -> (n,n) agreement matrix
    clasp: Optional[clasp.ClaspReport]
    validation: list
    emissions: dict[int, float]


class Orchestrator:
    def __init__(self, model_cfg: ModelConfig, swarm: SwarmConfig,
                 faults: Optional[FaultModel] = None,
                 store: Optional[StateStore] = None,
                 train_cfg: Optional[TrainConfig] = None):
        self.cfg = model_cfg
        self.swarm = swarm
        self.store = store or StateStore()
        self.faults = faults or FaultModel({}, seed=swarm.seed)
        self.spec = sm.SwarmModelSpec(model_cfg, swarm.n_stages,
                                      swarm.compress, swarm.bottleneck_dim)
        self.train_cfg = train_cfg or TrainConfig(lr=1e-3, warmup_steps=20)
        self.rng = np.random.RandomState(swarm.seed)
        self.ledger = IncentiveLedger(swarm.gamma_hours)
        self.corpus = SyntheticCorpus(DataConfig(
            vocab_size=model_cfg.vocab_size, seq_len=swarm.seq_len,
            batch_size=swarm.batch_size, seed=swarm.seed))
        self.global_tick = 0
        self.epoch = 0

        # per-stage anchors + DiLoCo outer state (the shared model)
        key = jax.random.key(swarm.seed)
        self.anchors: list[Any] = []
        self.outer: list[diloco.OuterState] = []
        for s in range(swarm.n_stages):
            p = sm.init_stage_params(jax.random.fold_in(key, s), self.spec, s)
            self.anchors.append(p)
            self.outer.append(diloco.outer_init(p))

        # register miners: uid = stage * miners_per_stage + slot
        self.miners: dict[int, Miner] = {}
        for s in range(swarm.n_stages):
            for slot in range(swarm.miners_per_stage):
                self.register_miner(stage=s)

        self.validators = [Validator(v, self.store, self.ledger)
                           for v in range(swarm.validators)]
        self.history: list[EpochStats] = []

    # ------------------------------------------------------------------

    def register_miner(self, stage: int) -> Miner:
        """Join at any time; actively participates after the next full sync

        (it is initialised from the anchor = 'copying existing miners'
        states', §2.2)."""
        uid = len(self.miners)
        params = jax.tree.map(jnp.copy, self.anchors[stage])
        m = Miner(uid, stage, self.spec, params, self.store, self.train_cfg)
        self.miners[uid] = m
        return m

    def stage_miners(self, stage: int) -> list[Miner]:
        return [m for m in self.miners.values() if m.stage == stage]

    # ------------------------------------------------------------------
    # epoch stages
    # ------------------------------------------------------------------

    def _available(self, m: Miner, tick: int) -> bool:
        b = self.faults.behavior(m.uid)
        if self.faults.is_dropped(m.uid):
            return False
        period = max(int(round(b.straggle_factor)), 1)
        return tick % period == 0

    def _training_stage(self) -> tuple[list[clasp.PathwayRecord], dict, int]:
        records: list[clasp.PathwayRecord] = []
        labels_for: dict[str, Any] = {}
        stalled = 0
        S = self.swarm
        for tick in range(S.inner_steps):
            batch = self.corpus.batch(self.global_tick)
            self.global_tick += 1
            # SWARM routing: sample one available miner per stage, reroute
            pathway: list[Miner] = []
            ok = True
            for s in range(S.n_stages):
                avail = [m for m in self.stage_miners(s)
                         if self._available(m, tick)]
                if not avail:
                    ok = False
                    break
                pathway.append(avail[self.rng.randint(len(avail))])
            if not ok:
                stalled += 1           # a whole layer offline: pipeline stall
                continue

            base = f"activations/ep{self.epoch}/t{tick}"
            tok_key = f"{base}/tokens"
            self.store.put(tok_key, jnp.asarray(batch["tokens"]),
                           actor="orchestrator")
            # ---------------- forward chain ----------------
            in_key = tok_key
            last_in_key = tok_key
            for s, miner in enumerate(pathway):
                out_key = f"{base}/s{s}/m{miner.uid}"
                if s == S.n_stages - 1:
                    last_in_key = in_key
                out = miner.forward(tick, in_key, out_key)
                # an adversarial miner uploads a corrupted activation in
                # place of its honest output — validators catch the mismatch
                # on replay, CLASP catches the downstream loss inflation
                b = self.faults.behavior(miner.uid)
                if s < S.n_stages - 1 and (b.free_ride
                                           or b.tamper_activations > 0):
                    corrupted = self.faults.corrupt_activation(
                        miner.uid, np.asarray(out, np.float32))
                    self.store.put(out_key,
                                   jnp.asarray(corrupted).astype(out.dtype),
                                   actor=miner.actor)
                in_key = out_key
            last = pathway[-1]
            labels = jnp.asarray(batch["labels"])
            labels_for[last_in_key] = labels

            # ---------------- backward chain ----------------
            loss, g = last.backward_last(last_in_key, labels)
            records.append(clasp.PathwayRecord(
                tuple(m.uid for m in pathway), loss))
            for s in range(S.n_stages - 2, -1, -1):
                miner = pathway[s]
                item = miner.work_log[-1]
                self.store.put(item.out_key + "/grad", g, actor="orchestrator")
                g = miner.backward(item.sample_key, g)
        return records, labels_for, stalled

    def _merge_stage(self) -> tuple[int, dict[int, np.ndarray], int]:
        """Compressed sharing + butterfly full sync + DiLoCo outer step."""
        S = self.swarm
        batches = {m.uid: m.batches_done for m in self.miners.values()}
        if not diloco.should_merge(batches, S.b_min, S.quorum_frac):
            return 0, {}, diloco.effective_batch(batches, S.b_min)
        merged_stages = 0
        agreement: dict[int, np.ndarray] = {}
        for s in range(S.n_stages):
            miners = self.stage_miners(s)
            qual = [m for m in miners if m.batches_done >= S.b_min]
            if len(qual) < 2:
                continue
            # --- weight upload (compressed sharing uses the share codec) ---
            uploads: dict[int, np.ndarray] = {}
            uid_order = [m.uid for m in qual]
            for idx, m in enumerate(qual):
                vec = m.weights_vector()
                vec = self.faults.corrupt_weights(m.uid, vec)
                payload = compression.encode(jnp.asarray(vec), S.share_codec)
                self.store.put(f"weights/ep{self.epoch}/s{s}/m{m.uid}",
                               payload, actor=m.actor)
                uploads[idx] = np.asarray(
                    compression.decode(payload, vec.shape[0]))
            # --- butterfly all-reduce within the layer ---
            plan = butterfly.make_plan(len(qual), uploads[0].shape[0],
                                       seed=S.seed + self.epoch * 131 + s)
            # a weight-tampering miner also reduces dishonestly: its merged
            # shard copies deviate, which is what the agreement matrix
            # exposes (paper Fig 7a)
            tamper = {idx: self.faults.behavior(m.uid).tamper_weights
                      for idx, m in enumerate(qual)
                      if self.faults.behavior(m.uid).tamper_weights > 0}
            copies = butterfly.reduce_with_copies(plan, uploads,
                                                  tamper=tamper or None)
            agreement[s] = butterfly.agreement_matrix(plan, copies)
            merged, valid, _ = butterfly.reduce_shards(plan, uploads)
            # --- DiLoCo outer step on the per-stage anchor ---
            flat_anchor, unravel = ravel_pytree(
                jax.tree.map(lambda x: x.astype(jnp.float32), self.anchors[s]))
            avg = unravel(jnp.asarray(merged))
            self.outer[s] = diloco.outer_update(
                self.outer[s], avg, outer_lr=S.outer_lr,
                outer_momentum=S.outer_momentum)
            self.anchors[s] = jax.tree.map(
                lambda a, p: a.astype(p.dtype), self.outer[s].anchor,
                self.anchors[s])
            # --- full sync: every miner (incl. stragglers/joiners) downloads
            anchor_vec, _ = ravel_pytree(
                jax.tree.map(lambda x: x.astype(jnp.float32), self.anchors[s]))
            self.store.put(f"weights/ep{self.epoch}/s{s}/merged",
                           np.asarray(anchor_vec), actor="orchestrator")
            for m in miners:
                vec = self.store.get(f"weights/ep{self.epoch}/s{s}/merged",
                                     actor=m.actor)
                m.load_weights_vector(vec)
            merged_stages += 1
        return merged_stages, agreement, diloco.effective_batch(batches, S.b_min)

    def _validation_stage(self, snapshots: dict[int, dict],
                          labels_for: dict) -> list:
        """Each validator tracks a random miner (§3: random assignment)."""
        results = []
        t_now = self.epoch * self.swarm.sync_interval_hours
        uids = sorted(self.miners.keys())
        for v in self.validators:
            uid = uids[self.rng.randint(len(uids))]
            m = self.miners[uid]
            res = v.validate_epoch(m, snapshots[uid], self.epoch, t_now,
                                   labels_for,
                                   max_items=self.swarm.validate_max_items)
            results.append(res)
        return results

    # ------------------------------------------------------------------

    def run_epoch(self) -> EpochStats:
        for m in self.miners.values():
            m.reset_epoch()
        snapshots = {uid: m.snapshot() for uid, m in self.miners.items()}

        records, labels_for, stalled = self._training_stage()
        results = self._validation_stage(snapshots, labels_for)
        merged, agreement, b_eff = self._merge_stage()

        n_miners = len(self.miners)
        layer_of = np.array([self.miners[u].stage
                             for u in sorted(self.miners.keys())])
        report = clasp.attribute(records, n_miners, layer_of) if records else None
        t_now = self.epoch * self.swarm.sync_interval_hours
        self.ledger.prune(t_now)
        emissions = self.ledger.emissions(
            t_now, miners=sorted(self.miners.keys()))

        stats = EpochStats(
            epoch=self.epoch,
            mean_loss=float(np.mean([r.loss for r in records])) if records
            else float("nan"),
            b_eff=b_eff,
            batches={m.uid: m.batches_done for m in self.miners.values()},
            merged_stages=merged,
            stalled_ticks=stalled,
            agreement=agreement,
            clasp=report,
            validation=results,
            emissions=emissions,
        )
        self.history.append(stats)
        self.epoch += 1
        # activations from this epoch are garbage-collected from the store
        self.store.delete_prefix(f"activations/ep{stats.epoch}")
        return stats

    def run(self, n_epochs: int) -> list[EpochStats]:
        return [self.run_epoch() for _ in range(n_epochs)]
