"""Per-stage model functions for the swarm runtime: each miner owns one

contiguous layer slice of a dense decoder LM (paper §2.2), with bottleneck
codes (§4) as the inter-stage wire format.

Roles:
  first: tokens --embed--> blocks --encode--> z
  mid:   z --decode--> blocks --encode--> z'
  last:  z --decode--> blocks --norm--> logits (loss computed by the miner:
         'those in the final layer compute the training loss')

Backward passes recompute the stage forward under ``jax.vjp`` from the
stored input — faithful to miners keeping activations locally while only
boundary activations transit the store.

``StageProgram`` packages the same layer slice as a *workload-agnostic*
program: the train plane (``forward``/``backward``/``loss_and_grads``) and
a serve plane (``prefill``/``decode_step``) that threads stage-local
KV-cache state through the identical slice, with the bottleneck boundary
codec (and optional int8 wire codec) applied uniformly at stage
entry/exit for both workloads.  Serving is a second program on the same
stage graph, not a parallel implementation (docs/SERVE.md).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import bottleneck as bn
from repro.kernels import ops
from repro.models import blocks as blk
from repro.models.layers import (
    embed,
    init_embeddings,
    logits as logits_fn,
    next_token_loss,
    norm_init,
    rmsnorm,
)

WIRE_DTYPE = jnp.bfloat16
SERVE_WIRE_CODECS = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class SwarmModelSpec:
    cfg: ModelConfig
    n_stages: int
    compress: bool = True
    bottleneck_dim: int = 16
    # virtual stages per device (interleaved pipeline schedules): the model
    # splits into n_stages * n_virtual chunks, chunk c living on device
    # c % n_stages as its (c // n_stages)-th slice.  The store-path swarm
    # runs stage-granular (n_virtual == 1); >1 describes the on-mesh
    # partition repro.core.pipeline executes, exposed here so both sides
    # agree on which layers a (stage, v) pair owns.
    n_virtual: int = 1

    @property
    def n_chunks(self) -> int:
        return self.n_stages * self.n_virtual

    @property
    def layers_per_stage(self) -> int:
        assert self.cfg.n_layers % self.n_stages == 0
        return self.cfg.n_layers // self.n_stages

    @property
    def layers_per_chunk(self) -> int:
        assert self.cfg.n_layers % self.n_chunks == 0
        return self.cfg.n_layers // self.n_chunks

    def chunk_index(self, stage: int, v: int = 0) -> int:
        """Global chunk id of device ``stage``'s ``v``-th virtual slice —
        the interleaved layout (chunk c = v * P + stage), so consecutive
        chunks live on consecutive devices."""
        assert 0 <= stage < self.n_stages and 0 <= v < self.n_virtual
        return v * self.n_stages + stage

    def chunk_layers(self, stage: int, v: int = 0) -> range:
        """Global layer indices the (stage, v) chunk owns."""
        c = self.chunk_index(stage, v)
        return range(c * self.layers_per_chunk,
                     (c + 1) * self.layers_per_chunk)

    def role(self, stage: int, v: int = 0) -> str:
        c = self.chunk_index(stage, v)
        if c == 0:
            return "first"
        return "last" if c == self.n_chunks - 1 else "mid"


def init_stage_params(key, spec: SwarmModelSpec, stage: int,
                      role: str | None = None) -> dict:
    """Stage parameters gated by boundary role.  ``role`` defaults to the
    pipeline role (``spec.role(stage)``); the serve plane passes "solo"
    for a one-stage program, which owns both boundary heads (embedding
    entry + logits exit) and no mid-chain codec."""
    cfg = spec.cfg
    ks = jax.random.split(key, 4)
    kind = blk.period_kinds(cfg)[0]
    layers = [blk.init_block(jax.random.fold_in(ks[0], l), kind, cfg)
              for l in range(spec.layers_per_stage)]
    p: dict = {"blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *layers)}
    d, db = cfg.d_model, spec.bottleneck_dim
    role = role if role is not None else spec.role(stage)
    if role in ("first", "solo"):
        p["embeds"] = {"embed": init_embeddings(ks[1], cfg)["embed"]}
    if role in ("mid", "last") and spec.compress:
        from repro.models.layers import dense_init
        p["w_up"] = dense_init(ks[2], db, d, scale=1.0 / np.sqrt(db))
        p["alpha_dec"] = jnp.asarray(0.5, jnp.float32)
    if role in ("first", "mid") and spec.compress:
        from repro.models.layers import dense_init
        p["enc_norm"] = norm_init(d)
        p["w_down"] = dense_init(ks[3], d, db)
    if role in ("last", "solo"):
        p["final_norm"] = norm_init(d)
        p["unembed"] = init_embeddings(
            jax.random.fold_in(ks[1], 7), cfg)["unembed"]
    return p


def _blocks_apply(p_blocks, x, cfg: ModelConfig):
    kind = blk.period_kinds(cfg)[0]
    B, S = x.shape[0], x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=pos)

    def body(h, lp):
        h, _, _ = blk.apply_block(kind, lp, h, ctx, None)
        return h, None

    x, _ = jax.lax.scan(body, x, p_blocks)
    return x


def _blocks_apply_cached(p_blocks, x, cfg: ModelConfig, cache):
    """Cached variant: threads one stacked per-layer block state (the
    stage-local KV cache) through the slice.  Positions are absolute —
    offset by each layer's cache length (all layers advance in lockstep,
    so the per-layer scalar is the request's decoded length)."""
    kind = blk.period_kinds(cfg)[0]
    B, S = x.shape[0], x.shape[1]

    def body(h, xs):
        lp, st = xs
        pos = jnp.broadcast_to(
            st.length + jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=pos)
        h, st2, _ = blk.apply_block(kind, lp, h, ctx, st)
        return h, st2

    x, cache = jax.lax.scan(body, x, (p_blocks, cache))
    return x, cache


def _stage_entry(params: dict, x_in, spec: SwarmModelSpec, role: str):
    """Boundary decode at stage entry: token embedding on the first
    stage, bottleneck decode (w_up, alpha) elsewhere.  Shared by the
    train and serve planes so the codec math cannot drift."""
    cfg = spec.cfg
    if role in ("first", "solo"):
        return embed({"embed": params["embeds"]["embed"]}, x_in, cfg, None)
    if spec.compress:
        x = (x_in.astype(jnp.float32) @ params["w_up"].astype(jnp.float32)
             ).astype(jnp.bfloat16)
        return params["alpha_dec"].astype(jnp.bfloat16) * x
    return x_in.astype(jnp.bfloat16)


def _stage_exit(params: dict, x, spec: SwarmModelSpec, role: str):
    """Boundary encode at stage exit: logits on the last stage,
    bottleneck encode (enc_norm, w_down) elsewhere.  Shared by both
    workload planes."""
    cfg = spec.cfg
    if role in ("last", "solo"):
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return logits_fn({"embed": params["unembed"]}, x, cfg, None)
    if spec.compress:
        xn = rmsnorm(x, params["enc_norm"], cfg.norm_eps)
        return (xn.astype(jnp.float32) @ params["w_down"].astype(jnp.float32)
                ).astype(WIRE_DTYPE)
    return x.astype(WIRE_DTYPE)


@partial(jax.jit, static_argnames=("spec", "role"))
def stage_forward(params: dict, x_in, spec: SwarmModelSpec, role: str):
    """x_in: tokens (first) or wire code z (mid/last).  Returns the stage

    output (wire code, or logits for the last stage)."""
    x = _stage_entry(params, x_in, spec, role)
    x = _blocks_apply(params["blocks"], x, spec.cfg)
    return _stage_exit(params, x, spec, role)


@partial(jax.jit, static_argnames=("spec", "role"))
def stage_decode_step(params: dict, x_in, cache, spec: SwarmModelSpec,
                      role: str):
    """Serve-plane stage step: the same layer slice and boundary codecs
    as ``stage_forward``, threading the stage-local KV cache.  ``x_in``
    is tokens (first stage) or a wire code, with S >= 1 — the one entry
    point serves both prefill (whole prompt) and decode (one token).
    Returns (stage output, updated cache)."""
    x = _stage_entry(params, x_in, spec, role)
    x, cache = _blocks_apply_cached(params["blocks"], x, spec.cfg, cache)
    return _stage_exit(params, x, spec, role), cache


def init_stage_cache(spec: SwarmModelSpec, stage: int, batch: int,
                     max_len: int, dtype=WIRE_DTYPE):
    """Stage-local KV cache: stacked per-layer block state for this
    stage's slice, shaped like the stacked params ``lax.scan`` consumes."""
    cfg = spec.cfg
    kind = blk.period_kinds(cfg)[0]
    assert kind.startswith("attn"), (
        f"serve plane needs KV-cache block states; got block kind {kind!r}")
    states = [blk.init_block_state(kind, cfg, batch, max_len, dtype)
              for _ in range(spec.layers_per_stage)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


@partial(jax.jit, static_argnames=("spec",))
def last_stage_loss_and_grads(params: dict, z_in, labels, spec: SwarmModelSpec):
    """Last miner computes the loss; returns (loss, g_params, g_z_in)."""
    def f(p, z):
        lgts = stage_forward(p, z, spec, "last")
        return next_token_loss(lgts, labels)

    loss, (g_params, g_z) = jax.value_and_grad(f, argnums=(0, 1))(params, z_in)
    return loss, g_params, g_z


@partial(jax.jit, static_argnames=("spec", "role"))
def stage_backward(params: dict, x_in, g_out, spec: SwarmModelSpec, role: str):
    """Recompute-forward VJP: returns (g_params, g_x_in).

    For the first stage g_x_in is None-like (tokens are integers)."""
    def f(p, x):
        return stage_forward(p, x, spec, role)

    if role == "first":
        g_params = jax.grad(
            lambda p: jnp.vdot(f(p, x_in).astype(jnp.float32),
                               g_out.astype(jnp.float32)))(params)
        return g_params, None
    _, vjp = jax.vjp(f, params, x_in)
    g_params, g_x = vjp(g_out.astype(WIRE_DTYPE) if spec.compress
                        else g_out.astype(WIRE_DTYPE))
    return g_params, g_x


# ---------------------------------------------------------------------------
# StageProgram: the workload-agnostic face of one stage
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageProgram:
    """One stage's layer slice as a workload-agnostic program.

    Named entry points over the same parameters and boundary codecs:

      train plane: ``forward`` / ``backward`` / ``loss_and_grads``
      serve plane: ``init_cache`` / ``prefill`` / ``decode_step``

    The serve entries thread stage-local KV-cache state (one stacked
    per-layer block state) through the identical slice; ``encode_wire``
    / ``decode_wire`` apply the optional int8 wire codec to boundary
    codes so every consumer (pipelined driver, sequential oracle, actor
    fleet) ships bit-identical activations.  The contract is documented
    in docs/SERVE.md.
    """
    spec: SwarmModelSpec
    stage: int
    wire_codec: str = "none"      # "none" | "int8" (SERVE_WIRE_CODECS)

    def __post_init__(self):
        assert self.wire_codec in SERVE_WIRE_CODECS, self.wire_codec
        assert 0 <= self.stage < self.spec.n_stages, self.stage

    @property
    def role(self) -> str:
        # a one-stage program is the whole model: embedding entry AND
        # logits exit, with no wire codec on either side
        if self.spec.n_chunks == 1:
            return "solo"
        return self.spec.role(self.stage)

    # ---- train plane ----
    def forward(self, params: dict, x_in):
        return stage_forward(params, x_in, self.spec, self.role)

    def backward(self, params: dict, x_in, g_out):
        return stage_backward(params, x_in, g_out, self.spec, self.role)

    def loss_and_grads(self, params: dict, z_in, labels):
        return last_stage_loss_and_grads(params, z_in, labels, self.spec)

    # ---- serve plane ----
    def init_cache(self, batch: int, max_len: int, dtype=WIRE_DTYPE):
        return init_stage_cache(self.spec, self.stage, batch, max_len, dtype)

    def prefill(self, params: dict, x_in, cache):
        """Run the whole prompt through the slice into a fresh cache."""
        return stage_decode_step(params, x_in, cache, self.spec, self.role)

    def decode_step(self, params: dict, x_in, cache):
        """Advance one token (S=1) through the slice."""
        return stage_decode_step(params, x_in, cache, self.spec, self.role)

    # ---- boundary wire codec (stage exit -> transport -> next entry) ----
    def encode_wire(self, code) -> dict:
        """Wire payload for this stage's output.  Mid-chain bottleneck
        codes optionally ship as the physical int8 (codes, scales) pair;
        last-stage logits always ship uncompressed."""
        if self.role in ("last", "solo") or self.wire_codec != "int8":
            return {"code": np.asarray(code)}
        q, s = ops.wire_encode(code)
        return {"q": np.asarray(q), "s": np.asarray(s)}

    @staticmethod
    def decode_wire(payload: dict):
        """Inverse of ``encode_wire`` — int8 pairs dequantize to exact
        f32 products (q * scale), uncompressed codes pass through."""
        if "code" in payload:
            return jnp.asarray(payload["code"])
        return ops.wire_decode(jnp.asarray(payload["q"]),
                               jnp.asarray(payload["s"]))


def sample_token(logits, *, temperature: float, key):
    """One sampling decision shared by every serve path: greedy argmax at
    temperature 0, categorical otherwise.  ``logits`` is (B, vocab);
    returns (B,) int32."""
    logits = jnp.asarray(logits, jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / jnp.float32(temperature), axis=-1).astype(jnp.int32)


def request_key(seed: int, req_id: int, index: int):
    """Deterministic per-(request, token) sampling key — identical for
    the pipelined driver and the sequential oracle at the same seed."""
    k = jax.random.fold_in(jax.random.PRNGKey(seed), req_id)
    return jax.random.fold_in(k, index)


def serve_stage_params(spec: SwarmModelSpec, seed: int, stage: int) -> dict:
    """Stage weights for serving, derived from ``(seed, stage)`` with the
    same fold-in convention the train swarm uses for stage anchors — so
    the sequential oracle, in-process ``StageServer``s and remote
    ``ServeActor`` fleets all hold identical params without weights ever
    crossing a process boundary.  A one-stage swarm serves the "solo"
    role (both boundary heads) rather than the pipeline's "first"."""
    role = "solo" if spec.n_chunks == 1 else spec.role(stage)
    return init_stage_params(
        jax.random.fold_in(jax.random.key(seed), stage), spec, stage,
        role=role)
