"""Per-stage model functions for the swarm runtime: each miner owns one

contiguous layer slice of a dense decoder LM (paper §2.2), with bottleneck
codes (§4) as the inter-stage wire format.

Roles:
  first: tokens --embed--> blocks --encode--> z
  mid:   z --decode--> blocks --encode--> z'
  last:  z --decode--> blocks --norm--> logits (loss computed by the miner:
         'those in the final layer compute the training loss')

Backward passes recompute the stage forward under ``jax.vjp`` from the
stored input — faithful to miners keeping activations locally while only
boundary activations transit the store.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import bottleneck as bn
from repro.models import blocks as blk
from repro.models.layers import (
    embed,
    init_embeddings,
    logits as logits_fn,
    next_token_loss,
    norm_init,
    rmsnorm,
)

WIRE_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class SwarmModelSpec:
    cfg: ModelConfig
    n_stages: int
    compress: bool = True
    bottleneck_dim: int = 16
    # virtual stages per device (interleaved pipeline schedules): the model
    # splits into n_stages * n_virtual chunks, chunk c living on device
    # c % n_stages as its (c // n_stages)-th slice.  The store-path swarm
    # runs stage-granular (n_virtual == 1); >1 describes the on-mesh
    # partition repro.core.pipeline executes, exposed here so both sides
    # agree on which layers a (stage, v) pair owns.
    n_virtual: int = 1

    @property
    def n_chunks(self) -> int:
        return self.n_stages * self.n_virtual

    @property
    def layers_per_stage(self) -> int:
        assert self.cfg.n_layers % self.n_stages == 0
        return self.cfg.n_layers // self.n_stages

    @property
    def layers_per_chunk(self) -> int:
        assert self.cfg.n_layers % self.n_chunks == 0
        return self.cfg.n_layers // self.n_chunks

    def chunk_index(self, stage: int, v: int = 0) -> int:
        """Global chunk id of device ``stage``'s ``v``-th virtual slice —
        the interleaved layout (chunk c = v * P + stage), so consecutive
        chunks live on consecutive devices."""
        assert 0 <= stage < self.n_stages and 0 <= v < self.n_virtual
        return v * self.n_stages + stage

    def chunk_layers(self, stage: int, v: int = 0) -> range:
        """Global layer indices the (stage, v) chunk owns."""
        c = self.chunk_index(stage, v)
        return range(c * self.layers_per_chunk,
                     (c + 1) * self.layers_per_chunk)

    def role(self, stage: int, v: int = 0) -> str:
        c = self.chunk_index(stage, v)
        if c == 0:
            return "first"
        return "last" if c == self.n_chunks - 1 else "mid"


def init_stage_params(key, spec: SwarmModelSpec, stage: int) -> dict:
    cfg = spec.cfg
    ks = jax.random.split(key, 4)
    kind = blk.period_kinds(cfg)[0]
    layers = [blk.init_block(jax.random.fold_in(ks[0], l), kind, cfg)
              for l in range(spec.layers_per_stage)]
    p: dict = {"blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *layers)}
    d, db = cfg.d_model, spec.bottleneck_dim
    role = spec.role(stage)
    if role == "first":
        p["embeds"] = {"embed": init_embeddings(ks[1], cfg)["embed"]}
    if role != "first" and spec.compress:
        from repro.models.layers import dense_init
        p["w_up"] = dense_init(ks[2], db, d, scale=1.0 / np.sqrt(db))
        p["alpha_dec"] = jnp.asarray(0.5, jnp.float32)
    if role != "last" and spec.compress:
        from repro.models.layers import dense_init
        p["enc_norm"] = norm_init(d)
        p["w_down"] = dense_init(ks[3], d, db)
    if role == "last":
        p["final_norm"] = norm_init(d)
        p["unembed"] = init_embeddings(
            jax.random.fold_in(ks[1], 7), cfg)["unembed"]
    return p


def _blocks_apply(p_blocks, x, cfg: ModelConfig):
    kind = blk.period_kinds(cfg)[0]
    B, S = x.shape[0], x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx = blk.BlockCtx(cfg=cfg, ma=None, positions=pos)

    def body(h, lp):
        h, _, _ = blk.apply_block(kind, lp, h, ctx, None)
        return h, None

    x, _ = jax.lax.scan(body, x, p_blocks)
    return x


@partial(jax.jit, static_argnames=("spec", "role"))
def stage_forward(params: dict, x_in, spec: SwarmModelSpec, role: str):
    """x_in: tokens (first) or wire code z (mid/last).  Returns the stage

    output (wire code, or logits for the last stage)."""
    cfg = spec.cfg
    if role == "first":
        x = embed({"embed": params["embeds"]["embed"]}, x_in, cfg, None)
    else:
        if spec.compress:
            x = (x_in.astype(jnp.float32) @ params["w_up"].astype(jnp.float32)
                 ).astype(jnp.bfloat16)
            x = params["alpha_dec"].astype(jnp.bfloat16) * x
        else:
            x = x_in.astype(jnp.bfloat16)
    x = _blocks_apply(params["blocks"], x, cfg)
    if role == "last":
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return logits_fn({"embed": params["unembed"]}, x, cfg, None)
    if spec.compress:
        xn = rmsnorm(x, params["enc_norm"], cfg.norm_eps)
        return (xn.astype(jnp.float32) @ params["w_down"].astype(jnp.float32)
                ).astype(WIRE_DTYPE)
    return x.astype(WIRE_DTYPE)


@partial(jax.jit, static_argnames=("spec",))
def last_stage_loss_and_grads(params: dict, z_in, labels, spec: SwarmModelSpec):
    """Last miner computes the loss; returns (loss, g_params, g_z_in)."""
    def f(p, z):
        lgts = stage_forward(p, z, spec, "last")
        return next_token_loss(lgts, labels)

    loss, (g_params, g_z) = jax.value_and_grad(f, argnums=(0, 1))(params, z_in)
    return loss, g_params, g_z


@partial(jax.jit, static_argnames=("spec", "role"))
def stage_backward(params: dict, x_in, g_out, spec: SwarmModelSpec, role: str):
    """Recompute-forward VJP: returns (g_params, g_x_in).

    For the first stage g_x_in is None-like (tokens are integers)."""
    def f(p, x):
        return stage_forward(p, x, spec, role)

    if role == "first":
        g_params = jax.grad(
            lambda p: jnp.vdot(f(p, x_in).astype(jnp.float32),
                               g_out.astype(jnp.float32)))(params)
        return g_params, None
    _, vjp = jax.vjp(f, params, x_in)
    g_params, g_x = vjp(g_out.astype(WIRE_DTYPE) if spec.compress
                        else g_out.astype(WIRE_DTYPE))
    return g_params, g_x
