"""Fault / straggler / adversary injection (seeded, deterministic).

Models the paper's operating environment: heterogeneous, unreliable,
adversarial.  Each miner gets a ``MinerBehavior``; the orchestrator consults
``FaultModel`` every time it routes work:

  * drop: miner offline this tick (SWARM reroute: resample the pathway)
  * straggle: miner takes ``straggle_factor`` x the base tick — it finishes
    fewer batches, exercising the B_min/B_eff threshold logic
  * tamper_activations: adversarial — adds noise to forward outputs
    (caught by validators' cosine check + CLASP loss attribution)
  * tamper_weights: uploads corrupted weights at merge (caught by the
    butterfly agreement matrix)
  * free_ride: skips compute, emits zeros (caught by CLASP: pathways through
    it have catastrophically high loss)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class MinerBehavior:
    drop_prob: float = 0.0
    straggle_factor: float = 1.0         # >1: slower hardware
    tamper_activations: float = 0.0      # noise std added to fwd outputs
    tamper_weights: float = 0.0          # noise std added to weight uploads
    free_ride: bool = False

    @property
    def honest(self) -> bool:
        return (self.tamper_activations == 0 and self.tamper_weights == 0
                and not self.free_ride)


class FaultModel:
    def __init__(self, behaviors: dict[int, MinerBehavior], seed: int = 0):
        self.behaviors = behaviors
        self.rng = np.random.RandomState(seed)

    def behavior(self, miner: int) -> MinerBehavior:
        return self.behaviors.get(miner, MinerBehavior())

    def is_dropped(self, miner: int) -> bool:
        return self.rng.rand() < self.behavior(miner).drop_prob

    def work_ticks(self, miner: int, base: int) -> int:
        """Batches a miner finishes in a window of ``base`` ticks."""
        f = self.behavior(miner).straggle_factor
        return max(int(round(base / max(f, 1e-6))), 0)

    def corrupt_activation(self, miner: int, x: np.ndarray) -> np.ndarray:
        b = self.behavior(miner)
        if b.free_ride:
            return np.zeros_like(x)
        if b.tamper_activations > 0:
            return x + self.rng.randn(*x.shape).astype(x.dtype) * b.tamper_activations
        return x

    def corrupt_weights(self, miner: int, vec: np.ndarray) -> np.ndarray:
        b = self.behavior(miner)
        if b.tamper_weights > 0:
            return vec + self.rng.randn(*vec.shape).astype(vec.dtype) * b.tamper_weights
        return vec
