"""Validator (paper §2.3, §3): computational-reproducibility auditing.

At full sync the validator copies a target miner's state; during the epoch
it re-runs the miner's logged work *in order* (forward from the same store
inputs, backward with the same gradients), comparing its own outputs to the
miner's uploads by cosine similarity.  Deviation below threshold => the
work is rejected; the epoch score S_m^n is the count of *validated*
backward passes.  Miners never know when they are tracked.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import cosine_similarity
from repro.core.incentives import IncentiveLedger
from repro.runtime import stage_model as sm
from repro.runtime.miner import Miner

if TYPE_CHECKING:
    from repro.api.transport import Transport

COSINE_THRESHOLD = 0.99


@dataclasses.dataclass
class ValidationResult:
    miner_uid: int
    epoch: int
    checked: int
    passed: int
    score: float                 # validated backward passes
    min_cosine: float

    @property
    def honest(self) -> bool:
        return self.checked == 0 or self.passed == self.checked


class Validator:
    def __init__(self, uid: int, transport: "Transport",
                 ledger: IncentiveLedger):
        self.uid = uid
        self.transport = transport
        self.ledger = ledger
        self.results: list[ValidationResult] = []

    @property
    def actor(self) -> str:
        return f"validator{self.uid}"

    def validate_epoch(self, miner: Miner, snapshot: dict, epoch: int,
                       t_now: float, labels_for: dict,
                       max_items: Optional[int] = None) -> ValidationResult:
        """Replay ``miner``'s logged epoch from ``snapshot`` (its full-sync

        state).  ``labels_for`` maps sample_key -> labels (the validator
        reads the same dataset shard).  Scores are assigned per §3."""
        params = snapshot["params"]
        opt_state = snapshot["opt_state"]
        inner_step = snapshot["inner_step"]
        opt = miner.opt
        spec, role = miner.spec, miner.role

        checked = passed = 0
        validated_backwards = 0.0
        min_cos = 1.0
        items = miner.work_log if max_items is None else miner.work_log[:max_items]
        for item in items:
            x_in = self.transport.get(item.sample_key, actor=self.actor)
            mine = sm.stage_forward(params, x_in, spec, role)
            theirs = self.transport.get(item.out_key, actor=self.actor)
            cos = float(cosine_similarity(jnp.asarray(mine, jnp.float32),
                                          jnp.asarray(theirs, jnp.float32)))
            checked += 1
            min_cos = min(min_cos, cos)
            ok = cos >= COSINE_THRESHOLD
            passed += int(ok)
            if not item.did_backward:
                continue
            # replay the miner's local update so later items line up
            if role == "last":
                labels = labels_for[item.sample_key]
                _, g_params, _ = sm.last_stage_loss_and_grads(
                    params, x_in, labels, spec)
            else:
                g_out_key = self.transport.schema.gradient_for(item.out_key)
                if not self.transport.exists(g_out_key):
                    continue
                g_out = self.transport.get(g_out_key, actor=self.actor)
                if isinstance(g_out, dict) and g_out.get("codec"):
                    # int8 gradient wire (SwarmConfig.wire_codec): replay
                    # with the same dequantized codes the miner trained on
                    from repro.core import compression
                    g_out = jnp.reshape(compression.decode(g_out),
                                        g_out["shape"])
                g_params, _ = sm.stage_backward(params, x_in, g_out, spec, role)
            params, opt_state = opt.update(g_params, opt_state, params,
                                           inner_step)
            inner_step = inner_step + 1
            if ok:
                validated_backwards += 1.0

        result = ValidationResult(miner.uid, epoch, checked, passed,
                                  validated_backwards, min_cos)
        self.results.append(result)
        self.ledger.record(miner.uid, epoch, result.score, t_now)
        return result
