"""Validator (paper §2.3, §3): computational-reproducibility auditing.

At full sync the validator copies a target miner's state; during the epoch
it re-runs the miner's logged work *in order* (forward from the same store
inputs, backward with the same gradients), comparing its own outputs to the
miner's uploads by cosine similarity.  Deviation below threshold => the
work is rejected; the epoch score S_m^n is the count of *validated*
backward passes.  Miners never know when they are tracked.

Sharded sync (§5.1-5.3, KeySchema v2) adds two reduce-audit paths:

  * ``audit_reduce``  — trustless: rebuilds the Fig 7a agreement matrix
    purely from the store's redundant reduced copies (shard identity and
    reducer uids are in the keys), flagging any reducer out of consensus
    with its partners.  No miner state or plan needed.
  * ``replay_reduce`` — replays a tracked miner's ``reduce_log`` the same
    way forward/backward work is replayed: recompute the masked merge from
    the logged store inputs, compare to the uploaded reduced copy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import cosine_similarity
from repro.core import butterfly, compression
from repro.core.incentives import IncentiveLedger
from repro.kernels import ops
from repro.runtime import stage_model as sm
from repro.runtime.miner import Miner

if TYPE_CHECKING:
    from repro.api.transport import Transport

COSINE_THRESHOLD = 0.99


@dataclasses.dataclass
class ReduceAuditResult:
    """Store-side audit of one (epoch, stage) butterfly reduce."""
    epoch: int
    stage: int
    uids: list          # reducer uids seen in the store, sorted
    agreement: np.ndarray          # (len(uids), len(uids)), NaN = no shared shard
    flagged: list       # uids whose mean partner agreement < 0.5

    @property
    def clean(self) -> bool:
        return not self.flagged


@dataclasses.dataclass
class ValidationResult:
    miner_uid: int
    epoch: int
    checked: int
    passed: int
    score: float                 # validated backward passes
    min_cosine: float

    @property
    def honest(self) -> bool:
        return self.checked == 0 or self.passed == self.checked


class Validator:
    def __init__(self, uid: int, transport: "Transport",
                 ledger: IncentiveLedger):
        self.uid = uid
        self.transport = transport
        self.ledger = ledger
        self.results: list[ValidationResult] = []

    @property
    def actor(self) -> str:
        return f"validator{self.uid}"

    def validate_epoch(self, miner: Miner, snapshot: dict, epoch: int,
                       t_now: float, labels_for: dict,
                       max_items: Optional[int] = None) -> ValidationResult:
        """Replay ``miner``'s logged epoch from ``snapshot`` (its full-sync

        state).  ``labels_for`` maps sample_key -> labels (the validator
        reads the same dataset shard).  Scores are assigned per §3."""
        params = snapshot["params"]
        opt_state = snapshot["opt_state"]
        inner_step = snapshot["inner_step"]
        opt = miner.opt
        spec, role = miner.spec, miner.role

        checked = passed = 0
        validated_backwards = 0.0
        min_cos = 1.0
        items = miner.work_log if max_items is None else miner.work_log[:max_items]
        for item in items:
            x_in = self.transport.get(item.sample_key, actor=self.actor)
            mine = sm.stage_forward(params, x_in, spec, role)
            theirs = self.transport.get(item.out_key, actor=self.actor)
            cos = float(cosine_similarity(jnp.asarray(mine, jnp.float32),
                                          jnp.asarray(theirs, jnp.float32)))
            checked += 1
            min_cos = min(min_cos, cos)
            ok = cos >= COSINE_THRESHOLD
            passed += int(ok)
            if not item.did_backward:
                continue
            # replay the miner's local update so later items line up
            if role == "last":
                labels = labels_for[item.sample_key]
                _, g_params, _ = sm.last_stage_loss_and_grads(
                    params, x_in, labels, spec)
            else:
                g_out_key = self.transport.schema.gradient_for(item.out_key)
                if not self.transport.exists(g_out_key):
                    continue
                g_out = self.transport.get(g_out_key, actor=self.actor)
                if isinstance(g_out, dict) and g_out.get("codec"):
                    # int8 gradient wire (SwarmConfig.wire_codec): replay
                    # with the same dequantized codes the miner trained on
                    from repro.core import compression
                    g_out = jnp.reshape(compression.decode(g_out),
                                        g_out["shape"])
                g_params, _ = sm.stage_backward(params, x_in, g_out, spec, role)
            params, opt_state = opt.update(g_params, opt_state, params,
                                           inner_step)
            inner_step = inner_step + 1
            if ok:
                validated_backwards += 1.0

        result = ValidationResult(miner.uid, epoch, checked, passed,
                                  validated_backwards, min_cos)
        self.results.append(result)
        self.ledger.record(miner.uid, epoch, result.score, t_now)
        return result

    # ------------------------------------------------------------------
    # sharded-sync reduce audits (§5.2 agreement, from wire artifacts)
    # ------------------------------------------------------------------

    def audit_reduce(self, epoch: int, stage: int) -> ReduceAuditResult:
        """Flag tampering reducers from the store's redundant copies alone:
        every shard has two independent reduced copies, so a deceptive
        reducer disagrees with *all* of its partners (Fig 7a) — visible to
        anyone who can read the store, which is the §5 trustless claim."""
        uids, agree = butterfly.store_agreement(self.transport, epoch,
                                                stage, actor=self.actor)
        flagged = []
        for i, uid in enumerate(uids):
            others = agree[i][np.arange(len(uids)) != i]
            if others.size and np.nanmean(others) < 0.5:
                flagged.append(uid)
        return ReduceAuditResult(epoch, stage, uids, agree, flagged)

    def replay_reduce(self, miner: Miner) -> tuple[int, int, float]:
        """Replay ``miner``'s logged reduce work: recompute each masked
        merge from the same shard uploads and compare (cosine) to the
        reduced copy the miner put on the wire.  Returns (checked, passed,
        min_cosine) — the reduce-work analogue of ``validate_epoch``."""
        checked = passed = 0
        min_cos = 1.0
        for item in miner.reduce_log:
            blocks, valid = [], []
            for key in item.in_keys:
                if not self.transport.exists(key):
                    blocks.append(None)
                    valid.append(False)
                    continue
                payload = self.transport.get(key, actor=self.actor)
                blocks.append(np.asarray(compression.decode(payload)))
                valid.append(True)
            if not any(valid):
                # nothing to recompute from (inputs GC'd or fabricated):
                # the work is unverifiable — score it as failed, don't crash
                checked += 1
                min_cos = -1.0
                continue
            width = next(b.shape[0] for b in blocks if b is not None)
            stacked = np.stack([b if b is not None
                                else np.zeros(width, np.float32)
                                for b in blocks])
            mine = np.asarray(ops.shard_merge(
                jnp.asarray(stacked), jnp.asarray(np.array(valid))))
            theirs = np.asarray(compression.decode(
                self.transport.get(item.out_key, actor=self.actor)))
            cos = float(cosine_similarity(jnp.asarray(mine),
                                          jnp.asarray(theirs)))
            checked += 1
            min_cos = min(min_cos, cos)
            passed += int(cos >= COSINE_THRESHOLD)
        return checked, passed, min_cos
