"""Shared utilities: pytree helpers, dtype policy, deterministic RNG folding.

Everything in this module is dependency-free (jax + numpy only) and safe to
import from any layer of the stack.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Callable, Iterable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# shard_map compat
# ---------------------------------------------------------------------------

try:  # jax >= 0.4.35 exposes shard_map at top level
    _shard_map_impl = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map_unchecked(body, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across the jax API
    rename: ``check_vma`` (new) vs ``check_rep`` (<= 0.4.x).  All our
    bodies use ppermute/psum manually, so the check stays disabled."""
    try:
        return _shard_map_impl(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map_impl(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------


def tree_size(tree: PyTree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    """Total bytes across all leaves (respects per-leaf dtype)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y, leaf-wise."""
    return jax.tree.map(lambda a, b: alpha * a + b, x, y)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree_util.tree_reduce(jnp.add, leaves)


def tree_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(tree_dot(tree, tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree))
    )


def tree_flatten_to_vector(tree: PyTree) -> tuple[jax.Array, Callable[[jax.Array], PyTree]]:
    """Flatten a pytree into one fp32 vector plus an unflatten closure.

    Used by the butterfly all-reduce, which shards the *flattened* parameter
    space into |P| = N(N-1)/2 near-equal byte ranges (paper §5.1).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    vec = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(v: jax.Array) -> PyTree:
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            out.append(v[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(treedef, out)

    return vec, unflatten


def tree_paths(tree: PyTree) -> list[str]:
    """'/'-joined string path for every leaf, in tree_flatten order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        out.append("/".join(_path_str(p) for p in path))
    return out


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """tree_map where fn also receives the '/'-joined path string."""
    def wrapper(path, leaf):
        return fn("/".join(_path_str(p) for p in path), leaf)
    return jax.tree_util.tree_map_with_path(wrapper, tree)


# ---------------------------------------------------------------------------
# Deterministic hashing / RNG
# ---------------------------------------------------------------------------


def stable_hash(*parts: Any) -> int:
    """Deterministic 63-bit hash of a sequence of printable parts."""
    h = hashlib.blake2b("\x1f".join(str(p) for p in parts).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little") & 0x7FFFFFFFFFFFFFFF


def fold_key(key: jax.Array, *parts: Any) -> jax.Array:
    """Fold arbitrary identifiers into a PRNG key deterministically."""
    return jax.random.fold_in(key, stable_hash(*parts) % (2**31 - 1))


def content_digest(tree: PyTree) -> str:
    """Hex digest of the concrete values of a pytree (host-side)."""
    h = hashlib.blake2b(digest_size=16)
    for path, leaf in zip(tree_paths(tree), jax.tree_util.tree_leaves(tree)):
        h.update(path.encode())
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Math helpers
# ---------------------------------------------------------------------------


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, m: int) -> int:
    return cdiv(x, m) * m


def cosine_similarity(a: jax.Array, b: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Cosine similarity of two flattened tensors (validator agreement metric,

    paper §2.3: 'Forward and backwards passes are checked against the
    submitted miner activations using a cosine similarity')."""
    a = a.reshape(-1).astype(jnp.float32)
    b = b.reshape(-1).astype(jnp.float32)
    na = jnp.linalg.norm(a)
    nb = jnp.linalg.norm(b)
    cos = jnp.vdot(a, b) / jnp.maximum(na * nb, eps)
    # two (near-)zero tensors agree by convention (an honest miner fed a
    # zeroed activation by an upstream free-rider reproduces zeros exactly)
    return jnp.where((na < 1e-6) & (nb < 1e-6), 1.0, cos)


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy: params/compute/wire dtypes."""
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    wire_dtype: Any = jnp.bfloat16   # activations on the wire (paper: bf16 = 2x)
    logits_dtype: Any = jnp.float32  # losses always reduced in fp32
