"""Serving: the dense single-process path + the swarm decode pipeline.

Three entry points, one token stream (docs/SERVE.md):

  generate        dense ``Model`` prefill + decode with the paged-ish KV
                  cache — the single-process reference path (one jitted
                  ``decode_step`` reused for the prefill chunk and every
                  decode step; re-tracing is per-shape, so the two shapes
                  coexist in one compilation cache).
  swarm_generate  the sequential *oracle* for the stage-sharded serve
                  plane: each request runs alone through every
                  ``StageProgram`` in stage order — same stage params,
                  same boundary codec round-trips, same sampling keys as
                  the pipelined driver, with none of the pipelining.
  serve_swarm     the real thing: ``ServeDriver`` running the compiled
                  decode timetable with continuous batching over an
                  in-process store, a socket store, or a spawned
                  ``ServeActor`` fleet (``transport="actors"``).

Greedy parity contract: at the same seed, ``serve_swarm`` emits tokens
bit-identical to ``swarm_generate`` for every transport, stage count and
admission order (tests/test_serve.py pins it).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 4 --prompt-len 32 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --smoke --swarm --stages 2 \
      --lanes 2 --transport actors
"""
from __future__ import annotations

import argparse
import time
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.model import build_model


def generate(model, params, prompts: jax.Array, max_new: int,
             temperature: float = 0.0, seed: int = 0):
    """prompts (B, S) int32 -> (B, S+max_new) greedy/temp sampled tokens.

    Prefill populates the KV cache (cache written during one decode_step
    per prompt chunk); decode appends one token at a time.
    """
    B, S = prompts.shape
    state = model.init_decode_state(B, S + max_new)

    # one jitted callable for the prompt chunk *and* the token steps:
    # jit caches per input shape, so the (B, S) prefill trace and the
    # (B, 1) decode trace share the cache instead of each call paying a
    # fresh wrapper
    step_fn = jax.jit(model.decode_step)
    lgts, state = step_fn(params, state, {"tokens": prompts})
    tokens = prompts
    key = jax.random.key(seed)
    last = lgts[:, -1, :]
    for i in range(max_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        lgts, state = step_fn(params, state, {"tokens": nxt})
        last = lgts[:, -1, :]
    return tokens


# ---------------------------------------------------------------------------
# swarm serve plane: oracle + driver front-end (docs/SERVE.md)
# ---------------------------------------------------------------------------


def swarm_generate(spec, seed: int, requests: Iterable,
                   *, wire_codec: str = "none") -> dict:
    """Sequential oracle for the stage-sharded serve plane.

    Each request runs alone, token by token, through every stage in
    order: prefill the whole prompt at step 0, then one ``decode_step``
    per emitted token, crossing each stage boundary through the *same*
    ``encode_wire``/``decode_wire`` round-trip the store path uses and
    sampling with the same ``request_key(seed, req, index)`` fold.  The
    pipelined ``ServeDriver`` must match this stream bit-for-bit at
    temperature 0.  Returns ``{req: [token, ...]}``.
    """
    from repro.runtime import stage_model as sm

    P = spec.n_stages
    programs = [sm.StageProgram(spec, s, wire_codec) for s in range(P)]
    params = [sm.serve_stage_params(spec, seed, s) for s in range(P)]
    out: dict = {}
    for r in requests:
        prompt = np.asarray(r.prompt, np.int32).reshape(1, -1)
        caches = [programs[s].init_cache(1, prompt.shape[1] + r.max_new)
                  for s in range(P)]
        toks: list = []
        for i in range(r.max_new):
            h = jnp.asarray(prompt) if i == 0 \
                else jnp.asarray([[toks[-1]]], jnp.int32)
            for s in range(P):
                h, caches[s] = programs[s].decode_step(params[s], h,
                                                       caches[s])
                if s < P - 1:
                    h = programs[s].decode_wire(programs[s].encode_wire(h))
            logits = jnp.asarray(h[:, -1], jnp.float32)
            toks.append(int(np.asarray(sm.sample_token(
                logits, temperature=r.temperature,
                key=sm.request_key(seed, r.req, i)))[0]))
        out[r.req] = toks
    return out


def build_servers(spec, seed: int, *, n_lanes: int, max_len: int,
                  wire_codec: str = "none") -> list:
    """One ``StageServer`` per stage with params re-derived from the
    session seed — the same derivation ``ServeActor`` runs remotely."""
    from repro.api.phases import StageServer
    from repro.runtime import stage_model as sm

    return [StageServer(spec, s, sm.serve_stage_params(spec, seed, s),
                        n_lanes=n_lanes, max_len=max_len,
                        wire_codec=wire_codec)
            for s in range(spec.n_stages)]


def serve_swarm(spec, requests: list, *, n_lanes: int, max_len: int,
                transport: str = "inprocess",
                store_address: Optional[tuple] = None, seed: int = 0,
                wire_codec: str = "none", timeout: float = 120.0) -> dict:
    """Serve ``requests`` over the decode pipeline on the chosen
    transport; returns ``{req: RequestRecord}``.

    ``inprocess``  in-memory store, driver executes every timetable slot.
    ``socket``     real ``StoreServer`` (spawned here unless
                   ``store_address`` points at a running one), driver
                   still executes the slots — every payload crosses the
                   wire.
    ``actors``     one spawned ``ServeActor`` process per stage against
                   the socket store; the driver only publishes plans,
                   samples and collects.
    """
    from repro.api.keys import KeySchema
    from repro.api.phases import ServeDriver
    from repro.api.transport import InProcessTransport, SocketTransport

    schema = KeySchema(version=5)
    if transport == "inprocess":
        driver = ServeDriver(
            spec, InProcessTransport(schema=schema), n_lanes=n_lanes,
            max_len=max_len, seed=seed, wire_codec=wire_codec,
            timeout=timeout,
            servers=build_servers(spec, seed, n_lanes=n_lanes,
                                  max_len=max_len, wire_codec=wire_codec))
        return driver.run(requests)

    if transport not in ("socket", "actors"):
        raise ValueError(f"unknown serve transport {transport!r}")

    from repro.runtime.store_server import StoreServer

    server = None
    if store_address is None:
        server = StoreServer().start()
        store_address = server.address
    store_address = (str(store_address[0]), int(store_address[1]))
    tp = SocketTransport(store_address, schema=schema)
    supervisor = None
    try:
        if transport == "socket":
            servers = build_servers(spec, seed, n_lanes=n_lanes,
                                    max_len=max_len, wire_codec=wire_codec)
        else:
            servers = None
            supervisor = _spawn_serve_fleet(spec, store_address, seed,
                                            wire_codec)
        driver = ServeDriver(spec, tp, n_lanes=n_lanes, max_len=max_len,
                             servers=servers, seed=seed,
                             wire_codec=wire_codec, timeout=timeout)
        records = driver.run(requests)
        if supervisor is not None:
            driver.stop_fleet()
            supervisor.join_all()
        return records
    finally:
        if supervisor is not None:
            supervisor.terminate_all()
        tp.close()
        if server is not None:
            server.stop()


def _spawn_serve_fleet(spec, store_address: tuple, seed: int,
                       wire_codec: str):
    """One ``ServeActor`` process per stage.  The spec carries only the
    session's shape; params re-derive from the seed in the serve plan."""
    from repro.api.config import SwarmConfig
    from repro.configs.base import TrainConfig
    from repro.runtime.actor import ActorSpec, ActorSupervisor

    swarm_cfg = SwarmConfig(n_stages=spec.n_stages, compress=spec.compress,
                            bottleneck_dim=spec.bottleneck_dim,
                            wire_codec=wire_codec, seed=seed)
    sup = ActorSupervisor()
    sup.spawn([ActorSpec(kind="server", uid=s, stage=s, model_cfg=spec.cfg,
                         config=swarm_cfg, train_cfg=TrainConfig(),
                         store_address=store_address)
               for s in range(spec.n_stages)])
    return sup


def _summarize(records: dict, t0: float, t1: float) -> None:
    n_tok = sum(len(r.tokens) for r in records.values())
    ttfts = sorted(r.ttft for r in records.values() if r.ttft is not None)
    print(f"served {len(records)} requests, {n_tok} tokens in "
          f"{t1 - t0:.2f}s ({n_tok / (t1 - t0):.1f} tok/s), "
          f"median ttft {ttfts[len(ttfts) // 2] * 1e3:.1f}ms")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--swarm", action="store_true",
                    help="serve over the stage-sharded decode pipeline "
                         "instead of the dense single-process model")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--transport", default="inprocess",
                    choices=("inprocess", "socket", "actors"))
    ap.add_argument("--store-address", default=None, metavar="HOST:PORT",
                    help="already-running store server (socket/actors); "
                         "default spawns one in-process")
    ap.add_argument("--wire-codec", default="none",
                    choices=("none", "int8"))
    ap.add_argument("--no-parity-check", action="store_true",
                    help="skip the greedy-parity check against the "
                         "sequential oracle (swarm mode, temperature 0)")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.smoke_variant(cfg)

    if not args.swarm:
        model = build_model(cfg)
        params = model.init(jax.random.key(args.seed))
        prompts = jax.random.randint(
            jax.random.key(args.seed + 1),
            (args.requests, args.prompt_len), 3, cfg.model.vocab_size,
            jnp.int32)
        t0 = time.perf_counter()
        out = generate(model, params, prompts, args.max_new,
                       args.temperature, args.seed)
        dt = time.perf_counter() - t0
        new_tokens = args.requests * args.max_new
        print(f"served {args.requests} requests, {new_tokens} new tokens "
              f"in {dt:.2f}s ({new_tokens/dt:.1f} tok/s)")
        print("sample completion token ids:",
              np.asarray(out[0, -args.max_new:]))
        return out

    from repro.api.phases import ServeRequest
    from repro.runtime import stage_model as sm

    assert cfg.model.n_layers % args.stages == 0, \
        "--stages must divide the model's layer count"
    spec = sm.SwarmModelSpec(cfg.model, args.stages)
    prompts = jax.random.randint(
        jax.random.key(args.seed + 1),
        (args.requests, args.prompt_len), 3, cfg.model.vocab_size,
        jnp.int32)
    requests = [ServeRequest(req=i, prompt=np.asarray(prompts[i]),
                             max_new=args.max_new,
                             temperature=args.temperature)
                for i in range(args.requests)]
    store_address = None
    if args.store_address:
        host, _, port = args.store_address.rpartition(":")
        store_address = (host, int(port))
    t0 = time.perf_counter()
    records = serve_swarm(
        spec, requests, n_lanes=args.lanes,
        max_len=args.prompt_len + args.max_new,
        transport=args.transport, store_address=store_address,
        seed=args.seed, wire_codec=args.wire_codec)
    t1 = time.perf_counter()
    _summarize(records, t0, t1)
    if args.temperature <= 0 and not args.no_parity_check:
        oracle = swarm_generate(spec, args.seed, requests,
                                wire_codec=args.wire_codec)
        for i in sorted(records):
            assert records[i].tokens == oracle[i], \
                f"parity violation on request {i}"
        print(f"greedy parity vs sequential oracle: OK "
              f"({len(records)} requests, transport={args.transport})")
    return records


if __name__ == "__main__":
    main()
