"""Serving driver: batched prefill + decode with a paged-ish KV cache.

CPU-scale harness over ``Model.prefill_step`` / ``Model.decode_step`` (the
same functions the dry-run lowers for the production mesh).  Implements the
minimal production serving loop: request queue -> prefill batch -> decode
rounds with greedy/temperature sampling -> detokenised responses.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.model import build_model


def generate(model, params, prompts: jax.Array, max_new: int,
             temperature: float = 0.0, seed: int = 0):
    """prompts (B, S) int32 -> (B, S+max_new) greedy/temp sampled tokens.

    Prefill populates the KV cache (cache written during one decode_step
    per prompt chunk); decode appends one token at a time.
    """
    B, S = prompts.shape
    state = model.init_decode_state(B, S + max_new)

    # prefill: run the prompt through decode_step in one chunk (the cache
    # variant of forward handles S>1 by appending the whole block)
    lgts, state = jax.jit(model.decode_step)(
        params, state, {"tokens": prompts})
    tokens = prompts
    key = jax.random.key(seed)
    step_fn = jax.jit(model.decode_step)
    last = lgts[:, -1, :]
    for i in range(max_new):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        nxt = nxt.astype(jnp.int32)[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        lgts, state = step_fn(params, state, {"tokens": nxt})
        last = lgts[:, -1, :]
    return tokens


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.smoke_variant(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))

    prompts = jax.random.randint(
        jax.random.key(args.seed + 1),
        (args.requests, args.prompt_len), 3, cfg.model.vocab_size, jnp.int32)
    t0 = time.time()
    out = generate(model, params, prompts, args.max_new, args.temperature,
                   args.seed)
    dt = time.time() - t0
    new_tokens = args.requests * args.max_new
    print(f"served {args.requests} requests, {new_tokens} new tokens in "
          f"{dt:.2f}s ({new_tokens/dt:.1f} tok/s)")
    print("sample completion token ids:", np.asarray(out[0, -args.max_new:]))
    return out


if __name__ == "__main__":
    main()
