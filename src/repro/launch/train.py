"""Training driver: single-host end-to-end loop with fault tolerance.

Runs any arch (full or --smoke reduced config) on the synthetic corpus with:
  * checkpoint/restart (atomic + async, integrity-verified; --resume picks
    up the latest step, including the data cursor),
  * optional preemption simulation (--kill-at-step N exits mid-run; rerun
    with --resume to prove recovery),
  * metrics log (loss/grad-norm/steps-per-sec) to stdout + jsonl.

On a real pod the same ``Model.train_step`` lowers under the production
mesh (see dryrun.py); this driver is the CPU-scale harness used by the
examples and integration tests.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --batch-size 8 --seq-len 128 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import build_model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at-step", type=int, default=None,
                    help="simulate preemption: hard-exit at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.smoke_variant(cfg)
    model = build_model(cfg)

    corpus = SyntheticCorpus(DataConfig(
        vocab_size=cfg.model.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch_size, seed=args.seed))

    state = model.init_train_state(jax.random.key(args.seed))
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume and ckpt.latest_step() is not None:
            state, meta = ckpt.restore(state)
            start_step = int(meta["step"])
            print(f"resumed from step {start_step} "
                  f"(data cursor restored with it)")

    step_fn = jax.jit(lambda s, b: model.train_step(s, b))
    metrics_log = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(step).items()}
        if cfg.model.family == "vlm" and cfg.model.frontend_tokens:
            from repro.models import frontends
            batch["vision_embeds"] = frontends.vision_patch_embeds(
                jax.random.fold_in(jax.random.key(7), step),
                args.batch_size, cfg.model.frontend_tokens, cfg.model.d_model)
        if cfg.model.family == "audio":
            from repro.models import frontends
            F = frontends.audio_frames_for_seq(args.seq_len)
            batch["frames"] = frontends.audio_frame_embeds(
                jax.random.fold_in(jax.random.key(8), step),
                args.batch_size, F, cfg.model.d_model)
        state, metrics = step_fn(state, batch)

        if args.kill_at_step is not None and step == args.kill_at_step:
            print(f"simulated preemption at step {step}", flush=True)
            os._exit(17)

        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, {"arch": args.arch})
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=step + 1,
                     sps=round((step + 1 - start_step) / (time.time() - t0), 3))
            metrics_log.append(m)
            print(json.dumps(m), flush=True)

    if ckpt:
        ckpt.save(args.steps, state, {"arch": args.arch})
        ckpt.wait()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            for m in metrics_log:
                f.write(json.dumps(m) + "\n")
    return metrics_log[-1] if metrics_log else {}


if __name__ == "__main__":
    main()
