"""Training driver: single-host end-to-end loop with fault tolerance.

Runs any arch (full or --smoke reduced config) on the synthetic corpus with:
  * checkpoint/restart (atomic + async, integrity-verified; --resume picks
    up the latest step, including the data cursor),
  * optional preemption simulation (--kill-at-step N exits mid-run; rerun
    with --resume to prove recovery),
  * metrics log (loss/grad-norm/steps-per-sec) to stdout + jsonl.

On a real pod the same ``Model.train_step`` lowers under the production
mesh (see dryrun.py); this driver is the CPU-scale harness used by the
examples and integration tests.

``--strategy pipeline`` drives the ``repro.core.pipeline`` engine instead:
stages shard over the devices' ``model`` axis (forced host devices work —
set XLA_FLAGS=--xla_force_host_platform_device_count=N *before* launch),
with the schedule (``gpipe``/``1f1b``/``interleaved``/``zerobubble``),
virtual-stage count and wire codec (``none``/``int8``) selectable per
docs/PERF.md.  The first metrics record carries the static
schedule accounting (wire bytes per hop, bubble fraction, stash bytes).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --batch-size 8 --seq-len 128 --ckpt-dir /tmp/ckpt --resume
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --strategy pipeline --pipeline-schedule 1f1b --wire-codec int8 \
      --steps 40 --batch-size 8 --seq-len 32
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.pipeline import SCHEDULES
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import build_model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at-step", type=int, default=None,
                    help="simulate preemption: hard-exit at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    # --strategy pipeline knobs (repro.core.pipeline engine)
    ap.add_argument("--strategy", default="tensor",
                    choices=["tensor", "pipeline"])
    ap.add_argument("--pipeline-stages", type=int, default=None,
                    help="stage count (default: all visible devices)")
    ap.add_argument("--pipeline-microbatches", type=int, default=None)
    ap.add_argument("--pipeline-schedule", default="gpipe",
                    choices=list(SCHEDULES))
    ap.add_argument("--pipeline-virtual-stages", type=int, default=1,
                    help="virtual stages (model chunks) per device; >1 "
                         "requires --pipeline-schedule interleaved")
    ap.add_argument("--n-layers", type=int, default=None,
                    help="override layer count (must split evenly into "
                         "stages x virtual stages)")
    ap.add_argument("--wire-codec", default="none", choices=["none", "int8"])
    ap.add_argument("--bottleneck-dim", type=int, default=None)
    ap.add_argument("--no-compress", action="store_true",
                    help="stream full-width activations, not codes")
    ap.add_argument("--lr", type=float, default=0.1,
                    help="SGD lr for the pipeline strategy loop")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.smoke_variant(cfg)
    if args.strategy == "pipeline":
        return _pipeline_main(args, cfg)
    model = build_model(cfg)

    corpus = SyntheticCorpus(DataConfig(
        vocab_size=cfg.model.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch_size, seed=args.seed))

    state = model.init_train_state(jax.random.key(args.seed))
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume and ckpt.latest_step() is not None:
            state, meta = ckpt.restore(state)
            start_step = int(meta["step"])
            print(f"resumed from step {start_step} "
                  f"(data cursor restored with it)")

    step_fn = jax.jit(lambda s, b: model.train_step(s, b))
    metrics_log = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(step).items()}
        if cfg.model.family == "vlm" and cfg.model.frontend_tokens:
            from repro.models import frontends
            batch["vision_embeds"] = frontends.vision_patch_embeds(
                jax.random.fold_in(jax.random.key(7), step),
                args.batch_size, cfg.model.frontend_tokens, cfg.model.d_model)
        if cfg.model.family == "audio":
            from repro.models import frontends
            F = frontends.audio_frames_for_seq(args.seq_len)
            batch["frames"] = frontends.audio_frame_embeds(
                jax.random.fold_in(jax.random.key(8), step),
                args.batch_size, F, cfg.model.d_model)
        state, metrics = step_fn(state, batch)

        if args.kill_at_step is not None and step == args.kill_at_step:
            print(f"simulated preemption at step {step}", flush=True)
            os._exit(17)

        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, {"arch": args.arch})
        if (step + 1) % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=step + 1,
                     sps=round((step + 1 - start_step) / (time.time() - t0), 3))
            metrics_log.append(m)
            print(json.dumps(m), flush=True)

    if ckpt:
        ckpt.save(args.steps, state, {"arch": args.arch})
        ckpt.wait()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            for m in metrics_log:
                f.write(json.dumps(m) + "\n")
    return metrics_log[-1] if metrics_log else {}


def _pipeline_main(args, cfg) -> dict:
    """Pipelined training loop: schedule + wire codec selectable, SGD on
    the stage-stacked param tree, static schedule stats in the first
    metrics record (benchmarks/bench_pipeline.py parses these)."""
    from repro.core.pipeline import (
        PipelineSpec,
        init_pipeline_params,
        pipeline_loss_and_grads,
        schedule_stats,
    )
    assert not (args.ckpt_dir or args.resume
                or args.kill_at_step is not None), \
        "--strategy pipeline does not support checkpoint/preemption flags yet"
    mcfg = cfg.model
    if args.n_layers:
        import dataclasses
        mcfg = dataclasses.replace(mcfg, n_layers=args.n_layers)
    n_dev = jax.device_count()
    n_stages = args.pipeline_stages or n_dev
    n_chunks = n_stages * args.pipeline_virtual_stages
    assert n_dev % n_stages == 0, (n_dev, n_stages)
    assert mcfg.n_layers % n_chunks == 0, \
        f"{mcfg.n_layers} layers cannot split into {n_chunks} chunks"
    data_shards = n_dev // n_stages
    spec = PipelineSpec(
        n_stages=n_stages,
        n_microbatches=(args.pipeline_microbatches
                        or min(cfg.parallel.pipeline_microbatches,
                               args.batch_size)),
        compress=not args.no_compress,
        bottleneck_dim=(args.bottleneck_dim
                        or max(mcfg.bottleneck.bottleneck_dim // 2, 8)),
        schedule=args.pipeline_schedule,
        wire_codec=args.wire_codec,
        virtual_stages=args.pipeline_virtual_stages,
    )
    assert args.batch_size % (spec.n_microbatches * data_shards) == 0, \
        (args.batch_size, spec.n_microbatches, data_shards)
    mesh = jax.make_mesh((data_shards, n_stages), ("data", "model"))
    corpus = SyntheticCorpus(DataConfig(
        vocab_size=mcfg.vocab_size, seq_len=args.seq_len,
        batch_size=args.batch_size, seed=args.seed))
    params = init_pipeline_params(jax.random.key(args.seed), mcfg, spec)
    stats = schedule_stats(mcfg, spec, args.batch_size, args.seq_len,
                           data_shards=data_shards)

    @jax.jit
    def step_fn(params, batch):
        loss, grads = pipeline_loss_and_grads(params, batch, mcfg, spec,
                                              mesh)
        new_params = jax.tree.map(
            lambda p, g: (p - args.lr * g.astype(jnp.float32)
                          ).astype(p.dtype), params, grads)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return new_params, {"loss": loss, "grad_norm": gnorm}

    metrics_log = [dict(stats, step=0)]
    print(json.dumps(metrics_log[0]), flush=True)
    t0 = time.time()
    step_seconds = []
    with mesh:
        for step in range(args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in corpus.batch(step).items()}
            ts = time.time()
            params, metrics = step_fn(params, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            step_seconds.append(time.time() - ts)
            if (step + 1) % args.log_every == 0 or step == args.steps - 1:
                m = dict(metrics, step=step + 1,
                         sps=round((step + 1) / (time.time() - t0), 3))
                metrics_log.append(m)
                print(json.dumps(m), flush=True)
    # median post-warmup step time — the bench's us_per_step
    tail = sorted(step_seconds[1:]) or step_seconds
    if tail:
        metrics_log[-1]["us_per_step"] = round(
            tail[len(tail) // 2] * 1e6, 1)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            for m in metrics_log:
                f.write(json.dumps(m) + "\n")
    print(json.dumps({"final": metrics_log[-1]}), flush=True)
    return metrics_log[-1]


if __name__ == "__main__":
    main()
