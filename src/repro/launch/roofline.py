"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh) we derive, from the post-SPMD per-device module:

  compute term    = HLO_FLOPs_global    / (chips * 197e12)
  memory term     = HLO_bytes_global    / (chips * 819e9)
  collective term = collective_bytes_gl / (chips * 50e9)

where *_global = per-device value (what ``cost_analysis`` / the HLO text
report after SPMD partitioning) x chips, so the formulas reduce to honest
per-device times.  collective_bytes is not in cost_analysis: we parse the
optimized HLO and sum the output-operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (methodology
note: output size is the received volume per device; for all-reduce the
on-wire volume is ~2x output in a ring — we report the raw sum and keep the
convention fixed across all cells so comparisons are apples-to-apples).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives the useful-compute
yardstick; MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy overhead.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every tensor literal in an HLO type signature."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output sizes of collective ops in (post-SPMD, per-device) HLO."""
    bytes_by_kind = {k: 0 for k in COLLECTIVE_OPS}
    count_by_kind = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        out_sig, op = m.groups()
        kind = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-"):   # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):                    # avoid double counting
            continue
        bytes_by_kind[kind] += _shape_bytes(out_sig)
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities straight from the artifacts
    device_flops: float
    device_bytes: float
    device_collective_bytes: float
    collectives: CollectiveStats
    # derived roofline terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float           # 6*N(_active)*D for this step's tokens
    useful_fraction: float       # MODEL_FLOPS / HLO_FLOPs_global
    memory_per_device: Optional[dict] = None
    # flash-kernel substitution: attention-interior HBM traffic measured in
    # the HLO; on TPU these tensors stay in the Pallas kernel's VMEM
    attn_interior_bytes: float = 0.0
    t_memory_kernelized: float = 0.0

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["collectives"] = {
            "bytes": self.collectives.bytes_by_kind,
            "count": self.collectives.count_by_kind,
        }
        return d


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_stats: Optional[dict] = None,
            score_dims: Optional[tuple] = None) -> RooflineReport:
    # trip-count-aware analysis (xla's cost_analysis counts loop bodies once;
    # see hlo_cost.py) — cost_analysis values are kept as a cross-check
    from repro.launch import hlo_cost
    hc = hlo_cost.analyze_module(hlo_text, score_dims=score_dims)
    flops = float(hc.flops)
    dev_bytes = float(hc.bytes)
    coll = CollectiveStats(dict(hc.coll_by_kind), dict(hc.coll_count))

    t_compute = (flops * chips) / (chips * PEAK_FLOPS_BF16)
    t_memory = (dev_bytes * chips) / (chips * HBM_BW)
    t_collective = (coll.total_bytes * chips) / (chips * ICI_LINK_BW)
    t_memory_kernelized = ((dev_bytes - hc.attn_interior_bytes) * chips
                           ) / (chips * HBM_BW)
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        device_flops=flops, device_bytes=dev_bytes,
        device_collective_bytes=float(coll.total_bytes),
        collectives=coll, t_compute=t_compute, t_memory=t_memory,
        t_collective=t_collective, bottleneck=bottleneck,
        model_flops=model_flops, useful_fraction=useful,
        memory_per_device=memory_stats,
        attn_interior_bytes=float(hc.attn_interior_bytes),
        t_memory_kernelized=t_memory_kernelized,
    )


def model_flops_for(cfg, shape, train: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode counts one token/seq."""
    n = cfg.model.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens            # forward only
    tokens = shape.global_batch            # one new token per sequence
    return 2.0 * n * tokens
