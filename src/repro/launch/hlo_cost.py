"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts each while-loop body ONCE, so any module
with scan-over-layers / grad-accumulation under-reports FLOPs, bytes and
collective traffic by the trip count (verified empirically: a 2-layer and a
4-layer scanned model report the same flops).  This module re-derives the
three roofline inputs from ``compiled.as_text()`` (post-SPMD, per-device):

  * computations are parsed into op lists with output/operand types,
  * a call graph (while body/cond x known_trip_count, fusion `calls=`,
    conditional branches) propagates multipliers down from ENTRY,
  * per-op costs:  dot -> 2 * |out| * k_contracted flops;
                   elementwise/reduce/fusion-root -> |out| flops;
                   every op -> operand+output bytes (fusion counted at the
                   fusion boundary, matching XLA's bytes-accessed);
                   collectives -> output bytes, bucketed by kind.

Numbers are per-device (the SPMD module is per-device); callers multiply by
chip count for global figures.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_TENSOR_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
# out type is either a (tuple, ...) — no nested parens in HLO types — or a
# single whitespace-free literal; /*index=N*/ comments are stripped upstream
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]))")
_COMMENT_RE = re.compile(r"/\*.*?\*/")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "floor",
    "compare", "select", "convert", "reduce", "reduce-window", "clamp",
    "cosine", "sine", "logistic", "and", "or", "xor", "not", "remainder",
    "exponential-minus-one", "log-plus-one", "atan2", "round-nearest-even",
    "erf", "cbrt", "sign", "shift-left", "shift-right-logical",
    "shift-right-arithmetic",
}

ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "transpose", "slice", "reverse", "concatenate", "pad",
    "dynamic-slice", "dynamic-update-slice", "copy", "copy-start",
    "copy-done", "gather", "scatter", "rng", "rng-bit-generator", "domain",
    "optimization-barrier", "custom-call", "infeed", "outfeed",
    "while", "conditional", "call", "fusion", "sort", "convolution", "dot",
    "get-dimension-size", "bitcast-convert", "all-reduce-done",
    "all-gather-done", "collective-permute-done", "async-done", "send",
    "recv", "send-done", "recv-done",
}


def _shape_numel_bytes(sig: str) -> tuple[int, int]:
    numel = 0
    nbytes = 0
    for dtype, dims in _TENSOR_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return numel, nbytes


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_sig: str
    rest: str               # everything after the '(' of operands


@dataclasses.dataclass
class Computation:
    name: str
    types: dict             # value name -> type signature
    ops: list               # list[OpInfo]


def parse_module(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()
        h = _HEADER_RE.match(line)
        if h and ("->" in line):
            is_entry, name, params = h.groups()
            cur = Computation(name, {}, [])
            comps[name] = cur
            if is_entry:
                entry = name
            for pname, ptype in _PARAM_RE.findall(params):
                cur.types[pname] = ptype
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_sig, opcode, rest = m.groups()
        cur.types[name] = out_sig
        cur.ops.append(OpInfo(name, opcode, out_sig, rest))
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    """Operand value names from the text following the opening paren."""
    # cut at the matching close paren of the operand list
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                rest = rest[:i]
                break
    return re.findall(r"%([\w.\-]+)", rest)


def _dot_flops(op: OpInfo, types: dict) -> float:
    out_numel, _ = _shape_numel_bytes(op.out_sig)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _operand_names(op.rest)
    if not operands or m is None:
        return 2.0 * out_numel
    lhs_sig = types.get(operands[0], "")
    tensors = _TENSOR_RE.findall(lhs_sig)
    if not tensors:
        return 2.0 * out_numel
    dims = [int(d) for d in tensors[0][1].split(",")] if tensors[0][1] else []
    k = 1
    for ci in m.group(1).split(","):
        if ci != "" and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * out_numel * k


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    # HBM traffic of "attention-interior" tensors: ops whose outputs carry a
    # (Sq, Skv) score/probability geometry.  On the TPU target these tensors
    # live inside the Pallas flash kernel's VMEM and never reach HBM, so
    # kernel-substituted memory = bytes - attn_interior_bytes.
    attn_interior_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.attn_interior_bytes += other.attn_interior_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] += v * mult


def _collective_kind(opcode: str) -> Optional[str]:
    base = opcode[:-6] if opcode.endswith("-start") else opcode
    for k in COLLECTIVE_KINDS:
        if base == k:
            return k
    return None


def _is_attn_interior(sig: str, score_dims) -> bool:
    """True if every tensor in the signature ends with the (Sq, Skv) score

    geometry (with Sq possibly microbatched/sharded: we match the LAST dim
    == Skv and the 2nd-to-last >= 128 with Skv/last-dim score shape)."""
    if score_dims is None:
        return False
    sq, skv = score_dims
    tensors = _TENSOR_RE.findall(sig)
    if not tensors:
        return False
    for _dtype, dims in tensors:
        d = [int(x) for x in dims.split(",")] if dims else []
        # scores are (B, [KH, G|H], Sq, Skv) — rank >= 4 excludes (B, S,
        # d_model) activations for archs where d_model == seq_len (glm4)
        if len(d) < 4 or d[-1] != skv or d[-2] not in (sq, skv):
            return False
    return True


def analyze_module(text: str, score_dims=None) -> Cost:
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
        if entry is None:
            return Cost()
    memo: dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()          # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = Cost()
        for op in comp.ops:
            out_numel, out_bytes = _shape_numel_bytes(op.out_sig)
            opcode = op.opcode
            # ---- called computations ----
            if opcode == "while":
                m = re.search(r'known_trip_count[^0-9]*(\d+)', op.rest)
                trips = float(m.group(1)) if m else 1.0
                for attr in ("body", "condition"):
                    cm = re.search(attr + r"=%?([\w.\-]+)", op.rest)
                    if cm:
                        total.add(comp_cost(cm.group(1)), trips)
                continue
            if opcode == "fusion":
                cm = re.search(r"calls=%?([\w.\-]+)", op.rest)
                inner = comp_cost(cm.group(1)) if cm else Cost()
                # flops from the fused body; bytes at the fusion boundary
                op_bytes = out_bytes
                interior = (out_bytes
                            if _is_attn_interior(op.out_sig, score_dims)
                            else 0.0)
                for o in _operand_names(op.rest):
                    sig_o = comp.types.get(o, "")
                    _, b = _shape_numel_bytes(sig_o)
                    op_bytes += b
                    if _is_attn_interior(sig_o, score_dims):
                        interior += b
                c = Cost(flops=inner.flops, bytes=op_bytes,
                         collective_bytes=inner.collective_bytes,
                         attn_interior_bytes=interior,
                         coll_by_kind=inner.coll_by_kind,
                         coll_count=inner.coll_count)
                total.add(c)
                continue
            if opcode in ("call", "async-start"):
                cm = re.search(r"(?:to_apply|calls|called_computation)"
                               r"=%?([\w.\-]+)", op.rest)
                if cm:
                    total.add(comp_cost(cm.group(1)))
                continue
            if opcode == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"true_computation=%?([\w.\-]+)|"
                    r"false_computation=%?([\w.\-]+))", op.rest)
                names: list[str] = []
                for grp in branches:
                    for g in grp:
                        if g:
                            names.extend(re.findall(r"%?([\w.\-]+)", g))
                costs = [comp_cost(n) for n in names if n in comps]
                if costs:
                    # one branch executes; take the max-flops branch
                    total.add(max(costs, key=lambda c: c.flops))
                continue

            # ---- leaf ops ----
            op_bytes = out_bytes
            interior_bytes = out_bytes if _is_attn_interior(
                op.out_sig, score_dims) else 0.0
            for o in _operand_names(op.rest):
                sig_o = comp.types.get(o, "")
                _, b = _shape_numel_bytes(sig_o)
                op_bytes += b
                if _is_attn_interior(sig_o, score_dims):
                    interior_bytes += b

            kind = _collective_kind(opcode)
            if kind is not None:
                c = Cost(bytes=op_bytes, collective_bytes=out_bytes)
                c.coll_by_kind[kind] += out_bytes
                c.coll_count[kind] += 1
                total.add(c)
                continue
            interior = interior_bytes
            if opcode == "dot":
                total.add(Cost(flops=_dot_flops(op, comp.types),
                               bytes=op_bytes, attn_interior_bytes=interior))
                continue
            if opcode == "reduce":
                total.add(Cost(flops=float(out_numel), bytes=op_bytes,
                               attn_interior_bytes=interior))
                continue
            if opcode in ELEMENTWISE_FLOP_OPS:
                total.add(Cost(flops=float(out_numel), bytes=op_bytes,
                               attn_interior_bytes=interior))
                continue
            if opcode in ZERO_COST_OPS:
                # moves data but no flops; count bytes for real movers only
                if opcode in ("copy", "gather", "scatter", "concatenate",
                              "dynamic-slice", "dynamic-update-slice", "pad",
                              "sort", "reshape", "transpose", "broadcast",
                              "slice"):
                    total.add(Cost(bytes=op_bytes,
                                   attn_interior_bytes=interior))
                continue
            # unknown op: count as elementwise
            total.add(Cost(flops=float(out_numel), bytes=op_bytes,
                           attn_interior_bytes=interior))
        memo[name] = total
        return total

    return comp_cost(entry)
