"""Production mesh construction.

Single pod:  (16, 16) = 256 chips, axes (data, model).
Multi-pod:   (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis carries only the DiLoCo outer sync (butterfly merge over DCN); inner
train steps sync over (data, model) within a pod.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for in-process multi-device tests (8 host devices)."""
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


# TPU v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_LINK_BW = 50e9              # bytes/s per link
