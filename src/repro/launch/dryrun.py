import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE two lines above must execute before any other import (jax locks the
device count at first init).  This module proves the distribution config is
coherent without hardware: ``jax.jit(step).lower(**specs).compile()`` must
succeed for the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh for
every assigned architecture and input shape, and the compiled artifact
feeds the §Roofline analysis (memory_analysis / cost_analysis / HLO
collective parsing).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k [--multi-pod] [--strategy tensor|pipeline] \
      [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_all.json
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import SHAPES, applicable_shapes
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    batch_specs,
    decode_state_spec_tree,
    named,
    train_state_specs,
)
from repro.models.model import build_model
from repro.sharding.partition import make_mesh_axes, param_specs


def _shape_structs(tree, spec_tree, mesh):
    """Attach NamedShardings to ShapeDtypeStructs."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def attach(sds, spec):
        sh = NamedSharding(mesh, spec) if isinstance(spec, P) else spec
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh)

    return jax.tree.map(attach, tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def run_cell(arch_id: str, shape_name: str, multi_pod: bool = False,
             strategy: str = "tensor", verbose: bool = True) -> dict:
    """Lower + compile one cell; returns the roofline record."""
    cfg = configs.get(arch_id)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.model.sub_quadratic:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(DESIGN.md shape rules)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg)
    ma = make_mesh_axes(mesh, cfg.model, cfg.parallel)
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    t0 = time.time()

    with mesh:
        if strategy == "pipeline":
            record = _lower_pipeline(cfg, model, shape, mesh, ma)
        elif shape.kind == "train":
            record = _lower_train(cfg, model, shape, mesh, ma)
        elif shape.kind == "prefill":
            record = _lower_prefill(cfg, model, shape, mesh, ma)
        else:
            record = _lower_decode(cfg, model, shape, mesh, ma)

    compiled, extra = record
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    mem_stats["total_bytes"] = (mem_stats["argument_bytes"]
                                + mem_stats["temp_bytes"]
                                + mem_stats["code_bytes"])
    score_dims = (shape.seq_len, shape.seq_len) if cfg.model.uses_attention \
        else None
    report = rl.analyze(
        arch_id, shape_name, mesh_name, chips, cost, hlo,
        rl.model_flops_for(cfg, shape, shape.kind == "train"), mem_stats,
        score_dims=score_dims)
    out = report.asdict()
    out.update(status="ok", compile_seconds=round(time.time() - t0, 1),
               strategy=strategy, **extra)
    if verbose:
        print(f"[{arch_id} x {shape_name} x {mesh_name} x {strategy}] "
              f"compiled in {out['compile_seconds']}s | "
              f"mem/device {mem_stats['total_bytes']/2**30:.2f} GiB | "
              f"t_comp {report.t_compute:.4f}s t_mem {report.t_memory:.4f}s "
              f"t_coll {report.t_collective:.4f}s -> {report.bottleneck}")
    return out


def _lower_train(cfg, model, shape, mesh, ma):
    state_shapes = model.abstract_train_state()
    state_specs = train_state_specs(model, ma)
    b_specs = batch_specs(model, shape, ma)
    batch_shapes = model.input_specs(shape)

    state_in = _shape_structs(state_shapes, state_specs, mesh)
    batch_in = _shape_structs(batch_shapes, b_specs, mesh)

    def step(state, batch):
        return model.train_step(state, batch, ma)

    state_sh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                            state_specs,
                            is_leaf=lambda x: isinstance(
                                x, jax.sharding.PartitionSpec))
    lowered = jax.jit(step, out_shardings=(state_sh, None),
                      donate_argnums=(0,)).lower(state_in, batch_in)
    return lowered.compile(), {}


def _lower_prefill(cfg, model, shape, mesh, ma):
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = param_specs(params_shapes, ma)
    params_in = _shape_structs(params_shapes, p_specs, mesh)
    b_specs = batch_specs(model, shape, ma)
    batch_in = _shape_structs(model.input_specs(shape), b_specs, mesh)

    def step(params, batch):
        return model.prefill_step(params, batch, ma)

    lowered = jax.jit(step).lower(params_in, batch_in)
    return lowered.compile(), {}


def _lower_decode(cfg, model, shape, mesh, ma):
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = param_specs(params_shapes, ma)
    params_in = _shape_structs(params_shapes, p_specs, mesh)

    state_shapes = model.decode_state_specs(shape)
    st_specs = decode_state_spec_tree(model, shape, ma)
    state_in = _shape_structs(state_shapes, st_specs, mesh)

    b_specs = batch_specs(model, shape, ma)
    batch_in = _shape_structs(model.input_specs(shape), b_specs, mesh)

    def step(params, dec_state, batch):
        return model.decode_step(params, dec_state, batch, ma)

    st_sh = jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                         st_specs,
                         is_leaf=lambda x: isinstance(
                             x, jax.sharding.PartitionSpec))
    lowered = jax.jit(step, out_shardings=(None, st_sh),
                      donate_argnums=(1,)).lower(params_in, state_in, batch_in)
    return lowered.compile(), {}


def _lower_pipeline(cfg, model, shape, mesh, ma):
    """Paper-faithful pipeline strategy (dense stacks; §Perf cell)."""
    from repro.core.pipeline import (
        PipelineSpec,
        init_pipeline_params,
        pipeline_loss,
        pipeline_loss_and_grads,
        pipeline_loss_fused,
    )
    assert shape.kind == "train", "pipeline strategy lowers train_step"
    n_stages = mesh.shape["model"]
    compress = os.environ.get("REPRO_PIPELINE_COMPRESS", "1") == "1"
    spec = PipelineSpec(
        n_stages=n_stages,
        n_microbatches=int(os.environ.get(
            "REPRO_PIPELINE_MICROBATCHES",
            str(cfg.parallel.pipeline_microbatches))),
        compress=compress,
        bottleneck_dim=max(cfg.model.bottleneck.bottleneck_dim, 32),
        schedule=os.environ.get("REPRO_PIPELINE_SCHEDULE", "gpipe"),
        wire_codec=os.environ.get("REPRO_PIPELINE_WIRE_CODEC", "none"),
    )
    params_shapes = jax.eval_shape(
        lambda k: init_pipeline_params(k, cfg.model, spec), jax.random.key(0))
    from repro.common import tree_map_with_path_str
    from jax.sharding import PartitionSpec as P

    def spec_for(path, leaf):
        if path.startswith("stages/"):
            return P("model")
        if "embed" in path:
            return P(ma.model, ma.data if ma.fsdp else None)
        return P()

    p_specs = tree_map_with_path_str(spec_for, params_shapes)
    params_in = _shape_structs(params_shapes, p_specs, mesh)
    batch_shapes = model.input_specs(shape)
    b_specs = batch_specs(model, shape, ma)
    batch_in = _shape_structs(batch_shapes, b_specs, mesh)

    fused = os.environ.get("REPRO_PIPELINE_FUSED", "1") == "1"

    if spec.schedule == "1f1b" or fused:
        # the dispatcher pairs each schedule with its grad path (autodiff
        # for GPipe, the explicit-backward slot loop for 1F1B)
        def step(params, batch):
            _, grads = pipeline_loss_and_grads(params, batch, cfg.model,
                                               spec, mesh,
                                               batch_axes=ma.batch)
            return grads
    else:
        def step(params, batch):
            return jax.grad(lambda p, b: pipeline_loss(
                p, b, cfg.model, spec, mesh, batch_axes=ma.batch))(
                    params, batch)

    lowered = jax.jit(step).lower(params_in, batch_in)
    return lowered.compile(), {
        "pipeline": {"n_stages": spec.n_stages,
                     "n_microbatches": spec.n_microbatches,
                     "compress": spec.compress,
                     "bottleneck_dim": spec.bottleneck_dim,
                     "schedule": spec.schedule,
                     "wire_codec": spec.wire_codec}}


def run_outer_merge(arch_id: str) -> dict:
    """Lower + compile the DiLoCo outer merge (paper full-sync stage) on the

    multi-pod mesh: butterfly-redundant reduce-scatter + agreement check +
    all-gather of the parameter delta over the ``pod`` axis, plus the outer
    Nesterov step.  Its collective bytes are the per-sync DCN cost that the
    paper's App. A stability analysis trades against gamma; recorded in
    EXPERIMENTS.md §Dry-run.
    """
    from repro.core import diloco
    cfg = configs.get(arch_id)
    mesh = make_production_mesh(multi_pod=True)
    chips = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg)
    ma = make_mesh_axes(mesh, cfg.model, cfg.parallel)
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = param_specs(params_shapes, ma)
    params_in = _shape_structs(params_shapes, p_specs, mesh)
    outer_shapes = jax.eval_shape(diloco.outer_init, params_shapes)
    # anchor/momentum shard like params (momentum is fp32)
    outer_specs = diloco.OuterState(
        anchor=p_specs, momentum=p_specs,
        outer_step=jax.sharding.PartitionSpec())
    outer_in = _shape_structs(outer_shapes, outer_specs, mesh)

    def step(params, outer):
        return diloco.outer_merge_step(params, outer, mesh, axis="pod",
                                       param_specs=p_specs)

    t0 = time.time()
    with mesh:
        compiled = jax.jit(step).lower(params_in, outer_in).compile()
    from repro.launch import hlo_cost
    hc = hlo_cost.analyze_module(compiled.as_text())
    rec = {
        "arch": arch_id, "kind": "diloco_outer_merge", "mesh": "multi_pod",
        "status": "ok", "chips": chips,
        "compile_seconds": round(time.time() - t0, 1),
        "device_collective_bytes": float(hc.collective_bytes),
        "collectives": {"bytes": dict(hc.coll_by_kind),
                        "count": dict(hc.coll_count)},
        "t_collective_dcn": float(hc.collective_bytes) / 50e9,
    }
    print(f"[{arch_id} x outer_merge x multi_pod] compiled in "
          f"{rec['compile_seconds']}s | coll {hc.collective_bytes/1e9:.2f} "
          f"GB/device | t_dcn {rec['t_collective_dcn']:.3f}s")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x applicable shape) on both meshes")
    ap.add_argument("--strategy", default="tensor",
                    choices=["tensor", "pipeline", "outer-merge"])
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in configs.all_arch_ids():
            cfg = configs.get(arch)
            for shape in applicable_shapes(cfg.model):
                cells.append((arch, shape.name, False))
                cells.append((arch, shape.name, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    results = []
    for arch, shape, mp in cells:
        try:
            if args.strategy == "outer-merge":
                results.append(run_outer_merge(arch))
                continue
            results.append(run_cell(arch, shape, mp, args.strategy))
        except Exception as e:  # noqa: BLE001 — record per-cell failures
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape,
                            "mesh": "multi_pod" if mp else "single_pod",
                            "status": "error", "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print(f"wrote {len(results)} records to {args.out}")
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"dry-run: {len(results)} cells, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
