"""Assemble the full in/out sharding trees for each dry-run step kind."""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import Model, TrainState
from repro.sharding.partition import MeshAxes, param_specs


def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def train_state_specs(model: Model, ma: MeshAxes) -> TrainState:
    params_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_specs = param_specs(params_shapes, ma)
    opt_specs = model.optimizer.state_specs(p_specs, params_shapes)
    return TrainState(params=p_specs, opt_state=opt_specs, step=P())


def batch_specs(model: Model, shape: ShapeConfig, ma: MeshAxes) -> dict:
    specs = model.input_specs(shape)
    b = ma.batch
    total = int(np.prod([ma.mesh.shape[a] for a in b]))
    bspec = b if shape.global_batch % total == 0 else (
        b[0] if shape.global_batch % ma.mesh.shape[b[0]] == 0 else None)

    out = {}
    for k, v in specs.items():
        dims = [bspec] + [None] * (len(v.shape) - 1)
        out[k] = P(*dims)
    return out


def decode_state_spec_tree(model: Model, shape: ShapeConfig, ma: MeshAxes):
    from repro.models import encdec, transformer
    if model.mcfg.is_encoder_decoder:
        return encdec.decode_state_specs(model.mcfg, ma, shape.global_batch)
    return transformer.decode_state_specs(model.mcfg, ma, shape.global_batch)
