"""serde-coverage: every ``*Msg`` dataclass has a wire-format registration.

``repro.api.serde`` carries an explicit message registry (the
``_register(messages.XxxMsg)`` block): a typed envelope can only cross
the socket if its type is registered for ``encode_message``/
``decode_message``.  Registration is deliberately *explicit* — no
``__subclasses__`` magic — precisely so this rule (and a human reading
serde.py) can see coverage statically.

The rule cross-checks the two files by AST:

  * every class ``XxxMsg`` defined in ``repro/api/messages.py`` must
    appear as a ``_register(...)`` argument in ``repro/api/serde.py``
    (adding a new message type without wire coverage fails the lint —
    and the registry-driven round-trip test in tests/test_serde.py);
  * every registered name must still exist in messages.py (a stale
    registration after a rename/delete also fails).
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.framework import Finding, Project, Rule

MESSAGES_MODULE = "repro.api.messages"
SERDE_MODULE = "repro.api.serde"
REGISTER_FN = "_register"


def message_class_names(tree: ast.AST) -> dict[str, int]:
    """``*Msg`` classes defined at module level -> line number."""
    out = {}
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, ast.ClassDef) and node.name.endswith("Msg"):
            out[node.name] = node.lineno
    return out


def registered_names(tree: ast.AST) -> dict[str, int]:
    """Arguments of ``_register(...)`` calls -> line number.  Accepts the
    bare name or an attribute path (``messages.ActivationMsg``)."""
    out = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == REGISTER_FN and node.args):
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Attribute):
            out[arg.attr] = node.lineno
        elif isinstance(arg, ast.Name):
            out[arg.id] = node.lineno
    return out


class SerdeCoverageRule(Rule):
    name = "serde-coverage"
    description = ("every *Msg dataclass in api/messages.py is registered "
                   "in api/serde.py's message registry")

    def check_project(self, project: Project) -> Iterable[Finding]:
        messages = project.find(MESSAGES_MODULE)
        serde = project.find(SERDE_MODULE)
        if messages is None or serde is None:
            # scanning a subtree that holds one but not both is a config
            # error worth surfacing, not silently passing
            if messages is not None or serde is not None:
                present = messages or serde
                missing = (SERDE_MODULE if messages is not None
                           else MESSAGES_MODULE)
                yield Finding(self.name, present.rel, 1,
                              f"cannot cross-check: {missing} not in scan "
                              f"scope")
            return
        defined = message_class_names(messages.tree)
        registered = registered_names(serde.tree)
        for cls, line in sorted(defined.items()):
            if cls not in registered:
                yield Finding(
                    self.name, messages.rel, line,
                    f"{cls} has no _register(...) entry in api/serde.py — "
                    f"it cannot cross the socket transport")
        for cls, line in sorted(registered.items()):
            if cls not in defined:
                yield Finding(
                    self.name, serde.rel, line,
                    f"_register({cls}) is stale: no such *Msg class in "
                    f"api/messages.py")
