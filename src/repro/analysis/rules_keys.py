"""key-literal: store keys are minted by ``repro.api.keys`` and nowhere else.

The seed scattered key f-strings across orchestrator/miner/validator;
PR 1 centralized them into the versioned ``KeySchema``, whose acceptance
grep (``grep -rn '"activations/' src/repro`` hits only keys.py) this rule
turns into a commit gate that also sees f-string *fragments* — the form
the seed actually used (``f"weights/ep{epoch}/..."``), which a plain grep
for the quoted prefix can miss.

A literal counts as key-shaped when its static text contains any of the
``KEY_SHAPES`` markers.  Docstrings are exempt (keys in documentation are
explanation, not minting); ``repro/api/keys.py`` is the one allowed
minting site.  Tests and examples are out of scope by convention — the
CLI scans ``src/`` — because fixtures legitimately spell keys out to pin
the schema's on-the-wire layout.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.framework import Finding, ModuleSource, Rule

# this file necessarily spells the markers out — the one sanctioned use
# swarmlint: disable-file=key-literal

# the store namespaces (including the v5 serve plane), plus the v2 shard
# segment (an f-string like f"...shard{k}..." renders as "shard{}" in
# static text, so "shard{" also catches the interpolated form)
KEY_SHAPES = ("activations/", "weights/", "scores/", "control/", "serve/",
              "shard{")

# the single sanctioned minting site (repo-relative suffix match, so the
# rule works from any scan root)
MINT_MODULES = ("repro/api/keys.py",)


def _static_text(node: ast.AST) -> Iterator[str]:
    """The statically known text of a string expression: the value of a
    plain literal, or the constant fragments of an f-string joined with
    ``{}`` placeholders (``f"weights/ep{e}"`` -> ``"weights/ep{}"``)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    elif isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value)
            else:
                parts.append("{}")
        yield "".join(parts)


class KeyLiteralRule(Rule):
    name = "key-literal"
    description = ("store-key-shaped string literals/f-strings outside "
                   "repro/api/keys.py (use KeySchema helpers)")

    def check_module(self, module: ModuleSource) -> Iterable[Finding]:
        if module.rel.endswith(MINT_MODULES):
            return
        # constant fragments inside an f-string are themselves Constant
        # nodes; report the JoinedStr once, not each fragment again
        in_joined = {
            id(v) for n in ast.walk(module.tree)
            if isinstance(n, ast.JoinedStr) for v in n.values}
        for node in ast.walk(module.tree):
            if not isinstance(node, (ast.Constant, ast.JoinedStr)):
                continue
            if id(node) in in_joined:
                continue
            if module.is_docstring(node):
                continue
            for text in _static_text(node):
                hit = next((s for s in KEY_SHAPES if s in text), None)
                if hit:
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        f"key-shaped literal {text!r} (marker {hit!r}): "
                        f"mint store keys via repro.api.keys.KeySchema")
                    break
