"""schedule-registry: pipeline schedule names come from the compiler.

PR 9 made ``repro.core.pipeline.SCHEDULES`` the single registry of
compiled schedules (the ``Timetable`` builder validates every member).
A stringly-typed schedule elsewhere — ``schedule="zb-h1"`` in a config,
``cfg.pipeline_schedule == "1f1b "`` in a branch — would silently miss
the compiler's validation and either assert deep inside shard_map or,
worse, fall through an if/else chain to the wrong executor.  This rule
makes the registry authoritative: any string literal used as a
``schedule=``/``pipeline_schedule=`` value, default, or comparison
operand outside ``repro/core/pipeline.py`` must be a registry member.

The registry is read from the *scanned* pipeline module's AST (the
``SCHEDULES = (...)`` tuple), not imported — swarmlint never imports
jax.  Scan roots that exclude ``repro.core.pipeline`` yield no findings
(nothing to check against).
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator, Optional

from repro.analysis.framework import Finding, Project, Rule

REGISTRY_MODULE = "repro.core.pipeline"
REGISTRY_NAME = "SCHEDULES"
# names whose string values this rule treats as schedule identifiers
SCHEDULE_NAMES = ("schedule", "pipeline_schedule")


def _registry_values(tree: ast.AST) -> Optional[frozenset]:
    """The string members of the module-level ``SCHEDULES = (...)``."""
    for node in ast.walk(tree):
        value = None
        if isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == REGISTRY_NAME
                   for t in node.targets):
                value = node.value
        elif isinstance(node, ast.AnnAssign):
            if (isinstance(node.target, ast.Name)
                    and node.target.id == REGISTRY_NAME):
                value = node.value
        if isinstance(value, (ast.Tuple, ast.List)):
            vals = [e.value for e in value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)]
            if vals:
                return frozenset(vals)
    return None


def _is_schedule_ref(node: ast.AST) -> bool:
    """Does this expression name a schedule field/variable?"""
    if isinstance(node, ast.Name):
        return node.id in SCHEDULE_NAMES
    if isinstance(node, ast.Attribute):
        return node.attr in SCHEDULE_NAMES
    return False


def _str_consts(node: ast.AST) -> Iterator[ast.Constant]:
    """String constants in an expression, descending into tuples/lists
    (``x.schedule in ("gpipe", "1f1b")`` compares against each member)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for e in node.elts:
            yield from _str_consts(e)


class ScheduleRegistryRule(Rule):
    name = "schedule-registry"
    description = ("schedule string literals outside repro/core/pipeline.py "
                   "must name members of the compiler registry (SCHEDULES)")

    def check_project(self, project: Project) -> Iterable[Finding]:
        reg_mod = project.find(REGISTRY_MODULE)
        if reg_mod is None:
            return
        registry = _registry_values(reg_mod.tree)
        if registry is None:
            yield Finding(
                self.name, reg_mod.rel, 1,
                f"{REGISTRY_NAME} tuple of string literals not found in "
                f"{REGISTRY_MODULE} — the schedule registry must stay "
                f"statically readable")
            return
        for m in project.modules:
            if m.module == REGISTRY_MODULE:
                continue
            for node in ast.walk(m.tree):
                yield from self._check_node(m, node, registry)

    def _check_node(self, module, node: ast.AST,
                    registry: frozenset) -> Iterator[Finding]:
        candidates: list[ast.Constant] = []
        if isinstance(node, ast.Call):
            # Swarm-/PipelineSpec-style constructor keywords:
            #   PipelineSpec(..., schedule="1f1b")
            for kw in node.keywords:
                if kw.arg in SCHEDULE_NAMES:
                    candidates.extend(_str_consts(kw.value))
        elif isinstance(node, (ast.Assign, ast.AnnAssign)):
            # defaults/field declarations: pipeline_schedule: str = "gpipe"
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            if any(_is_schedule_ref(t) for t in targets) and node.value:
                candidates.extend(_str_consts(node.value))
        elif isinstance(node, ast.Compare):
            # cfg.schedule == "1f1b" / spec.schedule in ("gpipe", "1f1b")
            sides = [node.left, *node.comparators]
            if any(_is_schedule_ref(s) for s in sides):
                for s in sides:
                    candidates.extend(_str_consts(s))
        for const in candidates:
            if const.value not in registry:
                yield Finding(
                    self.name, module.rel, const.lineno,
                    f"schedule literal {const.value!r} is not in "
                    f"{REGISTRY_MODULE}.{REGISTRY_NAME} "
                    f"{tuple(sorted(registry))}")
