"""TraceWatch: count XLA trace/compile events per labeled region.

The 1F1B schedule was suspected of re-tracing per slot (ROADMAP item 3 /
the −26% CPU gap vs GPipe).  Timing can't distinguish "retraced" from
"just slow", but jax can: ``jax.monitoring`` fires a
``/jax/core/compile/...`` event-duration callback every time something
is traced, lowered or compiled — and stays silent on jit cache hits.
``TraceWatch`` turns that into an assertable invariant:

    with TraceWatch() as watch:
        with watch.region("warmup"):
            step(state)                  # traces: fine, it's the first call
        with watch.region("steady"):
            for _ in range(5):
                step(state)
    watch.assert_no_trace("steady")      # raises RetraceError on retrace

Counts are per *event*, so a single retraced jit typically shows several
events (trace + MLIR lowering + backend compile per executable); the
assertion only cares whether the count is zero.  Regions may be entered
repeatedly; counts accumulate under the same label.

Listeners are process-global in jax, so ``TraceWatch`` is a context
manager that unregisters on exit (via the private-but-stable
``jax._src.monitoring`` hook; ``clear_event_listeners`` would nuke other
listeners).  Events raised outside any active region are accumulated
under the ``(unlabeled)`` pseudo-region rather than dropped.
"""
from __future__ import annotations

import contextlib
from collections import Counter
from typing import Iterator, Optional

UNLABELED = "(unlabeled)"

# event-name prefix that marks tracing/lowering/compilation work
TRACE_EVENT_PREFIX = "/jax/core/compile/"


class RetraceError(AssertionError):
    """A region that must be trace-free saw trace/compile events."""


class TraceWatch:
    def __init__(self) -> None:
        self.counts: Counter = Counter()          # label -> event count
        self.events: Counter = Counter()          # (label, event) -> count
        self._label: Optional[str] = None
        self._registered = False

    # -- listener plumbing -------------------------------------------------
    def _callback(self, event: str, duration: float, **kwargs) -> None:
        if event.startswith(TRACE_EVENT_PREFIX):
            label = self._label if self._label is not None else UNLABELED
            self.counts[label] += 1
            self.events[(label, event)] += 1

    def __enter__(self) -> "TraceWatch":
        import jax.monitoring
        jax.monitoring.register_event_duration_secs_listener(self._callback)
        self._registered = True
        return self

    def __exit__(self, *exc) -> None:
        if self._registered:
            from jax._src import monitoring as _monitoring
            _monitoring._unregister_event_duration_listener_by_callback(
                self._callback)
            self._registered = False

    # -- regions -----------------------------------------------------------
    @contextlib.contextmanager
    def region(self, label: str) -> Iterator[None]:
        """Attribute trace events raised inside the block to ``label``.
        Regions don't nest (the inner label wins until it exits)."""
        prev, self._label = self._label, label
        try:
            yield
        finally:
            self._label = prev

    # -- queries -----------------------------------------------------------
    def traces(self, label: str) -> int:
        return self.counts.get(label, 0)

    def report(self) -> dict:
        """``{label: event_count}`` for every region seen (diffable)."""
        return dict(sorted(self.counts.items()))

    def assert_no_trace(self, label: str) -> None:
        n = self.traces(label)
        if n:
            detail = ", ".join(
                f"{event.rsplit('/', 1)[-1]}×{cnt}"
                for (lbl, event), cnt in sorted(self.events.items())
                if lbl == label)
            raise RetraceError(
                f"region {label!r} must be trace-free but saw {n} "
                f"trace/compile event(s): {detail} — a jit cache miss in "
                f"steady state (shape/dtype drift or an uncached closure)")
