"""repro.analysis — "swarmlint": static invariant checks + runtime sanitizers.

Static side (``python -m repro.analysis``): AST rules over ``src/`` that
gate every commit via scripts/smoke.sh — see ``docs/ANALYSIS.md`` for the
rule catalog and suppression syntax.

Runtime side: ``TraceWatch`` (XLA retrace counter for labeled regions,
``analysis/retrace.py``) and ``CheckedStore`` (KeySchema/digest sanitizer
for the state store, ``analysis/checked_store.py``, enabled suite-wide by
``REPRO_CHECKED_STORE=1``).

This package is imported by the test suite and the CLI only; nothing in
the training path depends on it, and it must not import jax at module
level (the sanitizers import lazily) so the lint stays cheap.
"""
from __future__ import annotations

from repro.analysis.framework import (
    Finding, ModuleSource, Project, Rule, load_paths, run_rules,
)
from repro.analysis.rules_actor import ActorRuntimeRule
from repro.analysis.rules_keys import KeyLiteralRule
from repro.analysis.rules_protocol import ProtocolConformanceRule
from repro.analysis.rules_safety import NoPickleEvalRule, SpawnSafetyRule
from repro.analysis.rules_scenario import ScenarioConformanceRule
from repro.analysis.rules_schedule import ScheduleRegistryRule
from repro.analysis.rules_serde import SerdeCoverageRule

ALL_RULES = (
    KeyLiteralRule,
    SerdeCoverageRule,
    ProtocolConformanceRule,
    ActorRuntimeRule,
    NoPickleEvalRule,
    SpawnSafetyRule,
    ScenarioConformanceRule,
    ScheduleRegistryRule,
)

__all__ = [
    "ALL_RULES",
    "ActorRuntimeRule",
    "Finding",
    "KeyLiteralRule",
    "ModuleSource",
    "NoPickleEvalRule",
    "Project",
    "ProtocolConformanceRule",
    "Rule",
    "ScenarioConformanceRule",
    "ScheduleRegistryRule",
    "SerdeCoverageRule",
    "SpawnSafetyRule",
    "load_paths",
    "run_rules",
]
