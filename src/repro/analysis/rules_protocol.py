"""protocol-conformance: bound classes implement the *full* protocol.

``typing.Protocol`` only checks structurally at ``isinstance`` time — and
``runtime_checkable`` checks *names*, not signatures, and only when
somebody happens to call ``isinstance``.  A transport that forgets
``link_report`` or a phase without ``name`` drifts silently until a
scenario hits the missing method mid-epoch.  This rule closes the gap
statically.

Binding model (how a class is known to implement a protocol):

  * name suffix — ``class SocketTransport`` binds to ``Transport``,
    ``class ValidationPhase`` binds to ``Phase``;
  * marker comment on the ``class`` line for classes whose role their
    name doesn't spell: ``class OverlappedTrainingSharing:  # swarmlint:
    implements=Phase``.

The protocol surface is parsed from the ``Protocol`` class body itself
(method defs + annotated attributes), so extending a protocol
automatically extends the conformance check.  Inheritance is resolved
within the scan scope (``SimulatedNetworkTransport`` satisfies the
surface through ``InProcessTransport``); attribute requirements are met
by a class-level assignment/annotation or a ``self.<attr> = ...`` in any
method.  Classes inheriting from an unknown (out-of-scope) base are
skipped — their surface cannot be seen statically.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional

from repro.analysis.framework import Finding, ModuleSource, Project, Rule

# protocol name -> module that defines the Protocol class
PROTOCOLS = {
    "Transport": "repro.api.transport",
    "Phase": "repro.api.phases",
    "Actor": "repro.runtime.actor",
}

_IMPLEMENTS = re.compile(r"#\s*swarmlint:\s*implements=(\w+)")


def protocol_surface(tree: ast.AST, proto_name: str
                     ) -> tuple[set, set]:
    """(methods, attrs) a Protocol class body declares."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == proto_name:
            methods, attrs = set(), set()
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    if not item.name.startswith("_"):
                        methods.add(item.name)
                elif isinstance(item, ast.AnnAssign) and isinstance(
                        item.target, ast.Name):
                    attrs.add(item.target.id)
            return methods, attrs
    raise LookupError(f"Protocol class {proto_name} not found")


class _ClassInfo:
    def __init__(self, node: ast.ClassDef, module: ModuleSource):
        self.node = node
        self.module = module
        self.name = node.name
        self.bases = [b.attr if isinstance(b, ast.Attribute)
                      else b.id if isinstance(b, ast.Name) else None
                      for b in node.bases]
        self.methods = {item.name for item in node.body
                        if isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))}
        self.attrs = self._own_attrs(node)

    @staticmethod
    def _own_attrs(node: ast.ClassDef) -> set:
        attrs = set()
        for item in node.body:
            if isinstance(item, ast.Assign):
                attrs.update(t.id for t in item.targets
                             if isinstance(t, ast.Name))
            elif isinstance(item, ast.AnnAssign) and isinstance(
                    item.target, ast.Name):
                attrs.add(item.target.id)
        # self.<attr> = ... anywhere in the class's methods
        for sub in ast.walk(node):
            if isinstance(sub, ast.Assign):
                for t in sub.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        attrs.add(t.attr)
        return attrs


def _bound_protocol(info: _ClassInfo) -> Optional[str]:
    """Which protocol (if any) this class claims to implement."""
    header = info.module.lines[info.node.lineno - 1] \
        if info.node.lineno <= len(info.module.lines) else ""
    m = _IMPLEMENTS.search(header)
    if m:
        return m.group(1)
    for proto in PROTOCOLS:
        if info.name != proto and info.name.endswith(proto):
            return proto
    return None


class ProtocolConformanceRule(Rule):
    name = "protocol-conformance"
    description = ("classes bound as Transport/Phase define the full "
                   "protocol surface (methods + attributes)")

    def check_project(self, project: Project) -> Iterable[Finding]:
        surfaces = {}
        for proto, mod_name in PROTOCOLS.items():
            mod = project.find(mod_name)
            if mod is not None:
                try:
                    surfaces[proto] = protocol_surface(mod.tree, proto)
                except LookupError:
                    yield Finding(self.name, mod.rel, 1,
                                  f"Protocol class {proto} not found in "
                                  f"{mod_name}")
        if not surfaces:
            return

        classes: dict[str, _ClassInfo] = {}
        for m in project.modules:
            for node in ast.iter_child_nodes(m.tree):
                if isinstance(node, ast.ClassDef):
                    classes.setdefault(node.name, _ClassInfo(node, m))

        def full_surface(info: _ClassInfo, seen: frozenset
                         ) -> Optional[tuple[set, set]]:
            """Methods/attrs incl. inherited; None if a base is unknown."""
            methods, attrs = set(info.methods), set(info.attrs)
            for base in info.bases:
                if base in (None, "object", "Protocol") \
                        or base in PROTOCOLS:
                    continue
                if base not in classes or base in seen:
                    return None
                up = full_surface(classes[base], seen | {base})
                if up is None:
                    return None
                methods |= up[0]
                attrs |= up[1]
            return methods, attrs

        for cls_name in sorted(classes):
            info = classes[cls_name]
            proto = _bound_protocol(info)
            if proto is None or proto not in surfaces:
                continue
            got = full_surface(info, frozenset({cls_name}))
            if got is None:
                continue        # out-of-scope base: cannot judge statically
            methods, attrs = got
            want_m, want_a = surfaces[proto]
            missing = sorted(want_m - methods) + \
                [f"{a} (attribute)" for a in sorted(want_a - attrs)]
            if missing:
                yield Finding(
                    self.name, info.module.rel, info.node.lineno,
                    f"{cls_name} is bound as {proto} but lacks: "
                    f"{', '.join(missing)}")
