"""CheckedStore: a KeySchema/digest sanitizer over ``StateStore``.

The store is the trust boundary of the whole swarm (paper §2: *all*
traffic transits it), so the tier-1 suite can run with every store
operation sanitized.  ``StoreSanitizer.install()`` class-patches
``StateStore.put`` / ``fetch_entry`` / ``get_entry`` — one choke point
that covers the in-process transports, the simulated network *and* the
socket store server, which all bottom out in the same class — and checks:

  key shape      every key whose first segment is a store namespace
                 (``activations``/``weights``/``scores``) must parse under
                 the active ``KeySchema`` — a malformed key is a bug at the
                 producer, fatal immediately (``CheckedStoreError``) rather
                 than a ``StoreKeyError`` at some consumer minutes later.
                 Keys outside the namespaces (ad-hoc test keys) pass.

  write-after-publish
                 a ``put`` to an existing key with a *different* digest.
                 Fatal for weights/scores — the honest runtime never
                 rewrites those, it GCs by prefix and re-puts.  Recorded
                 (not fatal) for activations: the fault model deliberately
                 re-publishes corrupted activations over honest ones
                 (``TrainingPhase`` under ``FaultModel``), and catching
                 that is the *validators'* job — the sanitizer only keeps
                 the audit trail.  Idempotent re-puts (same digest) pass.

  read-before-write
                 a fetch of a never-written key.  The store already raises
                 ``StoreKeyError``; the sanitizer records the event (who
                 asked for what) before re-raising, so a flaky ordering
                 bug leaves evidence even when the exception is swallowed
                 by retry logic upstream.

Enabled suite-wide by ``REPRO_CHECKED_STORE=1`` (see tests/conftest.py);
smoke.sh runs the store/transport shards under the flag.  State is
derived from the live ``store._data``, so server ``reset`` and epoch GC
(delete + re-put) behave naturally.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.api import keys as _keys

_NAMESPACES = (_keys.NS_ACTIVATIONS, _keys.NS_WEIGHTS, _keys.NS_SCORES,
               _keys.NS_CONTROL)


class CheckedStoreError(AssertionError):
    """A store invariant the honest runtime must never break."""


@dataclasses.dataclass(frozen=True)
class Violation:
    kind: str         # "write-after-publish" | "read-before-write"
    key: str
    actor: str
    detail: str


class StoreSanitizer:
    """Install with ``with StoreSanitizer():`` or ``.install()``; while
    active, every ``StateStore`` in the process is checked."""

    def __init__(self, schema: Optional["_keys.KeySchema"] = None):
        # v4 parses every v1/v2/v3 key plus the chaos plan-revision
        # plane, so it is the right default whatever the producers mint
        self.schema = schema or _keys.KeySchema(version=4)
        self.records: list[Violation] = []
        self._originals = None

    # -- checks ------------------------------------------------------------
    def _validate_key(self, key: str, actor: str) -> None:
        if key.split("/", 1)[0] in _NAMESPACES:
            try:
                self.schema.parse(key)
            except ValueError as exc:
                raise CheckedStoreError(
                    f"checked-store: actor {actor!r} used a malformed "
                    f"store key: {exc}") from None

    def report(self) -> dict:
        out: dict = {}
        for v in self.records:
            out[v.kind] = out.get(v.kind, 0) + 1
        return out

    # -- patching ----------------------------------------------------------
    def install(self) -> "StoreSanitizer":
        if self._originals is not None:
            raise RuntimeError("StoreSanitizer already installed")
        from repro.runtime.state_store import StateStore, StoreKeyError

        orig_put = StateStore.put
        orig_fetch_entry = StateStore.fetch_entry
        orig_get_entry = StateStore.get_entry
        sanitizer = self

        def put(store, key, value, actor="?", codec=None, meta=None):
            sanitizer._validate_key(key, actor)
            prior = store._data.get(key)
            entry = orig_put(store, key, value, actor=actor,
                             codec=codec, meta=meta)
            if prior is not None and prior.digest != entry.digest:
                violation = Violation(
                    "write-after-publish", key, actor,
                    f"digest {prior.digest} -> {entry.digest}")
                if key.split("/", 1)[0] == _keys.NS_ACTIVATIONS:
                    # adversarial re-publish is part of the fault model;
                    # validators, not the store, must catch it
                    sanitizer.records.append(violation)
                else:
                    raise CheckedStoreError(
                        f"checked-store: write-after-publish by "
                        f"{actor!r} on {key!r} ({violation.detail}); "
                        f"honest writers GC by prefix and re-put, they "
                        f"never rewrite a published key")
            return entry

        def fetch_entry(store, key, actor="?"):
            sanitizer._validate_key(key, actor)
            try:
                return orig_fetch_entry(store, key, actor)
            except StoreKeyError as exc:
                sanitizer.records.append(Violation(
                    "read-before-write", key, actor,
                    f"nearest prefix {exc.nearest_prefix!r}"))
                raise

        def get_entry(store, key):
            try:
                return orig_get_entry(store, key)
            except StoreKeyError as exc:
                sanitizer.records.append(Violation(
                    "read-before-write", key, "?",
                    f"nearest prefix {exc.nearest_prefix!r}"))
                raise

        StateStore.put = put
        StateStore.fetch_entry = fetch_entry
        StateStore.get_entry = get_entry
        self._originals = (orig_put, orig_fetch_entry, orig_get_entry)
        return self

    def uninstall(self) -> None:
        if self._originals is None:
            return
        from repro.runtime.state_store import StateStore
        (StateStore.put, StateStore.fetch_entry,
         StateStore.get_entry) = self._originals
        self._originals = None

    def __enter__(self) -> "StoreSanitizer":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()
