"""swarmlint core: rule base class, finding model, suppression, the walker.

IOTA's correctness rests on a handful of *cross-cutting* invariants —
every store key is minted by the one versioned ``KeySchema``, every wire
message round-trips through ``api/serde.py``, every ``Transport``/``Phase``
implements its full protocol — and PR 5 showed these break silently (the
``startswith`` prefix bug shipped in the seed and survived four PRs).
This package makes them machine-checked: each invariant is a small
``Rule`` over parsed ASTs, run by ``python -m repro.analysis`` and gated
in ``scripts/smoke.sh`` and the test suite (``tests/test_analysis.py``).

Rules see two granularities:

  * ``check_module(module)``  — per-file checks (key literals, pickle/eval);
  * ``check_project(project)``— cross-file checks (serde coverage, protocol
    conformance, spawn-import closures).

Suppression mirrors the usual linter contract, scoped per rule:

  * line:  ``x = "weights/oops"  # swarmlint: disable=key-literal``
  * file:  ``# swarmlint: disable-file=key-literal`` anywhere at column 0

``disable=all`` silences every rule for that line/file.  Suppressions are
deliberately loud in review (they name the rule) — the linter is a commit
gate, so an unexplained blanket disable should not survive review.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Iterator, Optional

_DISABLE_LINE = re.compile(r"#\s*swarmlint:\s*disable=([\w,\-]+)")
_DISABLE_FILE = re.compile(r"^#\s*swarmlint:\s*disable-file=([\w,\-]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, pointing at a file:line a human can jump to."""
    rule: str
    path: str          # repo-relative where possible (stable in test output)
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class ModuleSource:
    """One parsed source file: text, lines, AST, dotted module name, and
    the docstring-constant set (rules that scan string literals must not
    fire on documentation — keys in docstrings are explanation, not
    minting)."""

    def __init__(self, path: str, rel: str, text: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.module = self._dotted_name(self.rel)
        self.docstring_nodes = frozenset(
            id(node) for node in self._docstring_constants(self.tree))

    @staticmethod
    def _dotted_name(rel: str) -> str:
        """`src/repro/api/keys.py` -> `repro.api.keys` (best effort: the
        path segments after the last `src/`, else the whole relative path)."""
        parts = rel.split("/")
        if "src" in parts:
            parts = parts[len(parts) - parts[::-1].index("src"):]
        if parts and parts[-1].endswith(".py"):
            parts[-1] = parts[-1][:-3]
        if parts and parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(p for p in parts if p)

    @staticmethod
    def _docstring_constants(tree: ast.AST) -> Iterator[ast.Constant]:
        for node in ast.walk(tree):
            if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                body = node.body
                if (body and isinstance(body[0], ast.Expr)
                        and isinstance(body[0].value, ast.Constant)
                        and isinstance(body[0].value.value, str)):
                    yield body[0].value

    def is_docstring(self, node: ast.AST) -> bool:
        return id(node) in self.docstring_nodes

    def suppressed_rules_for_line(self, line: int) -> frozenset:
        """Rule names disabled on a 1-indexed source line."""
        if 1 <= line <= len(self.lines):
            m = _DISABLE_LINE.search(self.lines[line - 1])
            if m:
                return frozenset(m.group(1).split(","))
        return frozenset()

    @property
    def file_suppressed_rules(self) -> frozenset:
        names: set = set()
        for raw in self.lines:
            m = _DISABLE_FILE.match(raw)
            if m:
                names.update(m.group(1).split(","))
        return frozenset(names)


class Project:
    """The scanned file set, indexed by dotted module name for the
    cross-file rules (import-closure walks, registry cross-checks)."""

    def __init__(self, modules: Iterable[ModuleSource]):
        self.modules = list(modules)
        self.by_name = {m.module: m for m in self.modules if m.module}

    def find(self, dotted: str) -> Optional[ModuleSource]:
        return self.by_name.get(dotted)


class Rule:
    """One invariant.  Subclasses set ``name``/``description`` and override
    at least one of the two hooks; findings they yield are filtered through
    the suppression comments centrally, so rules never re-implement it."""

    name = "rule"
    description = ""

    def check_module(self, module: ModuleSource) -> Iterable[Finding]:
        return ()

    def check_project(self, project: Project) -> Iterable[Finding]:
        return ()


def load_paths(paths: Iterable[str], root: Optional[str] = None
               ) -> list[ModuleSource]:
    """Collect ``.py`` files under each path (file or directory), skipping
    caches and hidden dirs.  ``root`` anchors the repo-relative names."""
    root = os.path.abspath(root or os.getcwd())
    seen: dict[str, ModuleSource] = {}
    for path in paths:
        ap = os.path.abspath(path)
        files: list[str] = []
        if os.path.isfile(ap):
            files = [ap]
        else:
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if not d.startswith(".") and d != "__pycache__")
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
        for f in files:
            if f in seen:
                continue
            with open(f, encoding="utf-8") as fh:
                text = fh.read()
            rel = os.path.relpath(f, root)
            seen[f] = ModuleSource(f, rel, text)
    return list(seen.values())


def run_rules(modules: Iterable[ModuleSource],
              rules: Iterable[Rule]) -> list[Finding]:
    """All findings from all rules, suppression comments applied, sorted
    by (path, line, rule) so output is diffable."""
    project = Project(modules)
    raw: list[Finding] = []
    for rule in rules:
        for m in project.modules:
            raw.extend(rule.check_module(m))
        raw.extend(rule.check_project(project))

    by_path = {m.path: m for m in project.modules}
    by_rel = {m.rel: m for m in project.modules}
    kept = []
    for f in raw:
        src = by_path.get(f.path) or by_rel.get(f.path)
        if src is not None:
            file_off = src.file_suppressed_rules
            line_off = src.suppressed_rules_for_line(f.line)
            if ({f.rule, "all"} & (file_off | line_off)):
                continue
        kept.append(f)
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))
