"""``python -m repro.analysis [paths...]`` — run swarmlint as a commit gate.

Exit status is the contract: 0 means no findings, 1 means findings (one
per line, ``path:line: [rule] message``).  ``scripts/smoke.sh`` runs this
over ``src`` before the test shards, so a key literal outside
``api/keys.py`` or an unregistered ``*Msg`` fails the commit the same way
a red test does.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import ALL_RULES
from repro.analysis.framework import load_paths, run_rules


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="swarmlint: static invariant checks over the repro tree")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to scan (default: src)")
    parser.add_argument("--rule", action="append", default=None,
                        metavar="NAME",
                        help="run only the named rule (repeatable)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as a JSON array")
    args = parser.parse_args(argv)

    rules = [cls() for cls in ALL_RULES]
    if args.list_rules:
        for rule in rules:
            print(f"{rule.name:22s} {rule.description}")
        return 0
    if args.rule:
        known = {r.name for r in rules}
        unknown = sorted(set(args.rule) - known)
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in set(args.rule)]

    modules = load_paths(args.paths)
    findings = run_rules(modules, rules)

    if args.json:
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        if findings:
            print(f"swarmlint: {len(findings)} finding(s) in "
                  f"{len(modules)} file(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
