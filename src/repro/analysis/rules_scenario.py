"""swarmlint rule: chaos scenarios must be deterministic and schema-clean.

``repro/scenarios/`` is the fault-injection catalog (docs/CHAOS.md); its
determinism contract — same seed => same fault schedule => same
trajectory — only holds when every ``Scenario(...)`` pins its
``fault_seed`` explicitly at the construction site.  A scenario built
without one silently inherits whatever default the builder happens to
carry, and two "identical" bench runs stop being comparable.  The rule
also keeps the catalog off raw store-key literals: scenarios observe the
swarm through ``KeySchema``-minted watermarks (a hand-spelled key would
bypass the schema version gate and break silently on the next key-plane
bump).
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.framework import Finding, ModuleSource, Rule

SCENARIO_PACKAGE = "repro/scenarios/"

# this file necessarily spells the markers out, like rules_keys.py
# swarmlint: disable-file=key-literal

# the store namespaces a scenario might be tempted to spell out (the
# same markers as rules_keys.KEY_SHAPES)
KEY_MARKERS = ("activations/", "weights/", "scores/", "control/",
               "shard{")


def _static_text(node: ast.AST) -> Iterator[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value
    elif isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value)
            else:
                parts.append("{}")
        yield "".join(parts)


class ScenarioConformanceRule(Rule):
    name = "scenario-conformance"
    description = ("Scenario(...) constructions in repro/scenarios/ must "
                   "pin fault_seed and mint store keys via KeySchema")

    def check_module(self, module: ModuleSource) -> Iterable[Finding]:
        if SCENARIO_PACKAGE not in module.rel:
            return
        in_joined = {
            id(v) for n in ast.walk(module.tree)
            if isinstance(n, ast.JoinedStr) for v in n.values}
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                yield from self._check_construction(module, node)
                continue
            if not isinstance(node, (ast.Constant, ast.JoinedStr)):
                continue
            if id(node) in in_joined or module.is_docstring(node):
                continue
            for text in _static_text(node):
                hit = next((s for s in KEY_MARKERS if s in text), None)
                if hit:
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        f"key-shaped literal {text!r} in a scenario "
                        f"module: observe the swarm via KeySchema-minted "
                        f"watermarks")
                    break

    def _check_construction(self, module: ModuleSource,
                            node: ast.Call) -> Iterable[Finding]:
        fn = node.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None)
        if name != "Scenario":
            return
        kwargs = {kw.arg for kw in node.keywords}
        # a positional 2nd argument also counts (name, fault_seed, ...)
        if "fault_seed" in kwargs or len(node.args) >= 2:
            return
        yield Finding(
            self.name, module.rel, node.lineno,
            "Scenario(...) without an explicit fault_seed: the "
            "determinism contract (docs/CHAOS.md) needs every scenario "
            "to pin its fault schedule seed at the construction site")
