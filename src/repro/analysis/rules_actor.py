"""actor-runtime: actor implementations stay spawn-able and wire-typed.

The concurrent runtime (``repro.runtime.actor``) has three standing
hazards a reviewer cannot see locally:

  * a ``*Actor`` class that is *not* an ``ActorProcess`` subclass looks
    like an actor, passes the ``Actor`` protocol surface (that part is
    the ``protocol-conformance`` rule, via the ``PROTOCOLS`` entry), but
    lacks the process body — spawn entry, health endpoint, clean
    shutdown — and dies the first time a supervisor spawns it;
  * actor classes defined in a module *outside* the spawn import closure
    (``rules_safety.SPAWN_ROOTS``) escape the spawn-safety lint: their
    import-time device work would wedge every spawned child unchecked;
  * a ``*Msg`` envelope referenced by actor code but missing from the
    serde registry only fails at runtime, on a socket, in a child
    process — the worst place to learn about it.

Suffix binding mirrors ``protocol-conformance``: every module-level
class named ``*Actor`` (except the ``Actor`` protocol itself) is held to
the contract; a deliberate exception can opt out with ``# swarmlint:
disable-line=actor-runtime`` on the ``class`` line.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.framework import Finding, Project, Rule
from repro.analysis.rules_safety import SPAWN_ROOTS, spawn_import_closure
from repro.analysis.rules_serde import SERDE_MODULE, registered_names

ACTOR_BASE = "ActorProcess"
PROTOCOL_CLASS = "Actor"


def _actor_classes(tree: ast.Module) -> Iterable[ast.ClassDef]:
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, ast.ClassDef) \
                and node.name.endswith("Actor") \
                and node.name != PROTOCOL_CLASS:
            yield node


def _base_names(node: ast.ClassDef) -> list:
    return [b.attr if isinstance(b, ast.Attribute)
            else b.id if isinstance(b, ast.Name) else None
            for b in node.bases]


def _msg_references(tree: ast.Module) -> Iterable[tuple[str, int]]:
    """(name, line) for every ``*Msg`` identifier the module mentions."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id.endswith("Msg"):
            yield node.id, node.lineno
        elif isinstance(node, ast.Attribute) and node.attr.endswith("Msg"):
            yield node.attr, node.lineno


class ActorRuntimeRule(Rule):
    name = "actor-runtime"
    description = ("*Actor classes subclass ActorProcess, live inside the "
                   "spawn import closure, and only reference serde-"
                   "registered *Msg envelopes")

    def check_project(self, project: Project) -> Iterable[Finding]:
        # class table across the scan scope (inheritance resolution)
        classes: dict[str, ast.ClassDef] = {}
        module_of: dict[str, str] = {}
        for m in project.modules:
            for node in ast.iter_child_nodes(m.tree):
                if isinstance(node, ast.ClassDef):
                    classes.setdefault(node.name, node)
                    module_of.setdefault(node.name, m.module)

        def reaches_base(name: str, seen: frozenset) -> Optional[bool]:
            """True/False: subclasses ActorProcess; None: unknown base."""
            if name == ACTOR_BASE:
                return True
            node = classes.get(name)
            if node is None:
                return None
            verdicts = []
            for base in _base_names(node):
                if base is None or base in seen:
                    continue
                verdicts.append(reaches_base(base, seen | {base}))
            if any(v is True for v in verdicts):
                return True
            if any(v is None for v in verdicts):
                return None
            return False

        closure = spawn_import_closure(project)
        serde_mod = project.find(SERDE_MODULE)
        registry = set(registered_names(serde_mod.tree)) \
            if serde_mod is not None else None

        for m in project.modules:
            actor_nodes = list(_actor_classes(m.tree))
            for node in actor_nodes:
                verdict = reaches_base(node.name,
                                       frozenset({node.name}))
                if verdict is False:
                    yield Finding(
                        self.name, m.rel, node.lineno,
                        f"{node.name} is named as an actor but does not "
                        f"subclass {ACTOR_BASE}: it has no spawn entry, "
                        f"health endpoint or shutdown protocol; inherit "
                        f"from {ACTOR_BASE} (repro.runtime.actor)")
                elif verdict is True and m.module not in closure:
                    yield Finding(
                        self.name, m.rel, node.lineno,
                        f"{node.name} is defined outside the spawn import "
                        f"closure of {SPAWN_ROOTS}: spawned children "
                        f"re-import it unchecked by the spawn-safety "
                        f"lint; add {m.module!r} to rules_safety."
                        f"SPAWN_ROOTS")
            if not actor_nodes or registry is None:
                continue
            for name, line in _msg_references(m.tree):
                if name not in registry:
                    yield Finding(
                        self.name, m.rel, line,
                        f"actor module references {name} which has no "
                        f"_register(...) entry in api/serde.py: the "
                        f"envelope cannot cross the socket")
