"""no-pickle / no-eval and spawn-safety: hazards that live at import time.

no-pickle-eval
    ``src/`` ships a hand-rolled tagged binary format (``api/serde.py``)
    precisely so the store server never unpickles peer bytes.  This rule
    keeps it that way: importing ``pickle``/``dill``/``shelve``/``marshal``
    or calling bare ``eval``/``exec`` anywhere under ``src/`` is a finding.
    (``cloudpickle`` inside jax is jax's business; *our* modules stay out.)

spawn-safety
    ``spawn_store_server`` launches the store server with the ``spawn``
    start method: the child re-imports ``repro.runtime.store_server`` and,
    transitively, everything that module pulls in at top level — including
    package ``__init__`` chains (``from repro.api import serde`` executes
    ``repro/api/__init__.py`` wholesale).  Module-level JAX *device* work
    in that closure (``jnp.array(...)``, ``jax.devices()``) initializes a
    second XLA backend per child: slow at best, wedged at worst when the
    parent holds the platform.  The rule walks the static import closure
    from the spawn roots and flags module-level calls into jnp /
    jax.random / the device API.  Lazily imported modules (imports inside
    functions) are outside the closure by construction — that is the
    sanctioned fix, and how ``runtime/__init__.py`` already avoids it.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.framework import Finding, ModuleSource, Project, Rule

FORBIDDEN_IMPORTS = ("pickle", "cPickle", "dill", "shelve", "marshal")

# entry points that run in freshly spawned interpreters
SPAWN_ROOTS = ("repro.runtime.store_server", "repro.runtime.actor")

# module-level calls with these dotted prefixes allocate buffers / touch
# the backend at import time
DEVICE_PREFIXES = ("jnp.", "jax.numpy.", "jax.random.")
DEVICE_CALLS = (
    "jax.devices", "jax.local_devices", "jax.device_count",
    "jax.local_device_count", "jax.device_put", "jax.default_backend",
    "jax.make_mesh",
)


class NoPickleEvalRule(Rule):
    name = "no-pickle-eval"
    description = ("no pickle-family imports and no bare eval/exec in src/ "
                   "(the wire format is api/serde.py)")

    def check_module(self, module: ModuleSource) -> Iterable[Finding]:
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    root = alias.name.split(".")[0]
                    if root in FORBIDDEN_IMPORTS:
                        yield Finding(
                            self.name, module.rel, node.lineno,
                            f"import of {alias.name!r}: peer bytes go "
                            f"through api/serde.py, never pickle")
            elif isinstance(node, ast.ImportFrom):
                root = (node.module or "").split(".")[0]
                if root in FORBIDDEN_IMPORTS:
                    yield Finding(
                        self.name, module.rel, node.lineno,
                        f"import from {node.module!r}: peer bytes go "
                        f"through api/serde.py, never pickle")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id in ("eval", "exec")):
                yield Finding(
                    self.name, module.rel, node.lineno,
                    f"call to bare {node.func.id}(): not allowed in src/")


def _dotted_call_path(func: ast.AST) -> str:
    """``jax.random.PRNGKey`` for an Attribute chain, ``jnp`` for a Name,
    '' when the callee root is not a plain name (subscripts, calls)."""
    parts: list[str] = []
    node = func
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _import_time_calls(tree: ast.Module) -> Iterator[ast.Call]:
    """Call nodes that execute when the module is imported: everything
    except function/lambda bodies (class bodies *do* run at import;
    decorators and default-argument expressions run at def time)."""

    def visit(node: ast.AST) -> Iterator[ast.Call]:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                yield from walk_expr(dec)
            for default in (node.args.defaults + node.args.kw_defaults):
                if default is not None:
                    yield from walk_expr(default)
            return
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, ast.Call):
            yield node
        for child in ast.iter_child_nodes(node):
            yield from visit(child)

    def walk_expr(node: ast.AST) -> Iterator[ast.Call]:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                yield sub

    for stmt in tree.body:
        yield from visit(stmt)


def module_level_device_calls(module: ModuleSource
                              ) -> Iterator[tuple[int, str]]:
    """(line, dotted-callee) for import-time calls into the device API."""
    for call in _import_time_calls(module.tree):
        path = _dotted_call_path(call.func)
        if not path:
            continue
        if (path.startswith(DEVICE_PREFIXES) or path in DEVICE_CALLS
                or path in ("jnp", "jax.numpy")):
            yield call.lineno, path


def spawn_import_closure(project: Project) -> dict[str, ModuleSource]:
    """Static import closure (within scan scope) of the spawn roots,
    following module-level imports only and including the package
    ``__init__`` chain each import executes."""
    closure: dict[str, ModuleSource] = {}
    queue: list[str] = []

    def enqueue(dotted: str) -> None:
        # importing a.b.c executes a/__init__ and a.b/__init__ too
        parts = dotted.split(".")
        for i in range(1, len(parts) + 1):
            name = ".".join(parts[:i])
            if name not in closure and project.find(name) is not None:
                queue.append(name)

    def module_level_imports(tree: ast.Module) -> Iterator[ast.stmt]:
        # imports under top-level if/try run at import time too; imports
        # inside defs are lazy and deliberately out of the closure
        def visit(node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
                return
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                yield node
            for child in ast.iter_child_nodes(node):
                yield from visit(child)
        for stmt in tree.body:
            yield from visit(stmt)

    for root in SPAWN_ROOTS:
        enqueue(root)           # a root import executes its package chain
    while queue:
        dotted = queue.pop()
        if dotted in closure:
            continue
        mod = project.find(dotted)
        if mod is None:
            continue
        closure[dotted] = mod
        for node in module_level_imports(mod.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    enqueue(alias.name)
            else:
                base = node.module or ""
                if node.level:      # relative: resolve against this module
                    pkg = dotted.split(".")
                    # for a module, level 1 is its own package; __init__
                    # modules are already package-named by ModuleSource
                    if not mod.rel.endswith("__init__.py"):
                        pkg = pkg[:-1]
                    pkg = pkg[:len(pkg) - (node.level - 1)]
                    base = ".".join(pkg + ([base] if base else []))
                if base:
                    enqueue(base)
                    for alias in node.names:
                        enqueue(f"{base}.{alias.name}")
    return closure


class SpawnSafetyRule(Rule):
    name = "spawn-safety"
    description = ("no module-level JAX device work in the import closure "
                   "of spawn_store_server children")

    def check_project(self, project: Project) -> Iterable[Finding]:
        closure = spawn_import_closure(project)
        for dotted in sorted(closure):
            mod = closure[dotted]
            for line, path in module_level_device_calls(mod):
                yield Finding(
                    self.name, mod.rel, line,
                    f"module-level {path}(...) runs in every spawned store "
                    f"server child (imported via {dotted}); make it lazy")
