"""Cached-decode attention Pallas TPU kernel (the serve plane's hot loop).

The flash kernel blocks over (q, kv) for train/prefill shapes; decode is
the opposite regime — one (or a few prefill) query rows against a long KV
cache *buffer* whose valid prefix length is dynamic (``kv_len`` = cache
length + the rows being appended this step).  Grid is (batch, q_head,
kv_block) with the kv axis innermost/sequential, so the online-softmax
running state (m, l, acc) lives in VMEM scratch across kv steps and the
(small) output block is written once on the last step.  Cache blocks past
the valid prefix are skipped entirely (``pl.when`` on the dynamic bound);
inside a live block both the causal mask (``kpos <= q_offset + row``) and
the prefix mask (``kpos < kv_len``) apply, exactly ``ref.attention``'s
semantics with ``causal=True`` and a ``kv_len``.

``kv_len``/``q_offset`` are traced per-batch scalars (they ride the KV
cache state through jit), shipped to the kernel as one (B, 2) int32 SMEM
operand — scalars steer control flow, so they must live in SMEM, not VMEM.

Inference-only: no ``custom_vjp`` — the serve plane never differentiates,
and ``ops.flash_attention`` routes autodiff-bearing shapes (no cache) to
the flash/ref paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.common import cdiv

NEG_INF = -1e30


def _decode_kernel(meta_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, bq: int, bkv: int, n_kv: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = meta_ref[0, 0]
    q_off = meta_ref[0, 1]
    kv_lo = j * bkv

    # blocks entirely past the valid prefix contribute nothing
    @pl.when(kv_lo < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        qpos = q_off + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        live = (kpos <= qpos) & (kpos < kv_len)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, *, q_offset, kv_len, softmax_scale=None,
                     interpret=False, bkv=512):
    """GQA attention over a KV cache buffer: q (B, Sq, H, D) against
    k/v (B, S_max, KH, D) with per-batch valid length ``kv_len`` (B,) and
    absolute first-row position ``q_offset`` (scalar or (B,)).  Matches
    ``ref.attention(..., causal=True, q_offset=..., kv_len=...)``."""
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    G = H // KH
    scale = float(softmax_scale if softmax_scale is not None
                  else 1.0 / np.sqrt(D))
    meta = jnp.stack([
        jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,)),
        jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,)),
    ], axis=1)                                               # (B, 2) int32

    qt = q.transpose(0, 2, 1, 3)                             # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)                             # (B, KH, Skv, D)
    vt = v.transpose(0, 2, 1, 3)
    bkv = min(bkv, Skv)
    n_kv = cdiv(Skv, bkv)
    grid = (B, H, n_kv)

    try:
        from jax.experimental.pallas import tpu as pltpu
        smem = pl.BlockSpec((1, 2), lambda b, h, j: (b, 0),
                            memory_space=pltpu.SMEM)
        scratch = [pltpu.VMEM((Sq,), jnp.float32),
                   pltpu.VMEM((Sq,), jnp.float32),
                   pltpu.VMEM((Sq, D), jnp.float32)]
        cp_cls = getattr(pltpu, "CompilerParams", None) \
            or getattr(pltpu, "TPUCompilerParams", None)
        compiler_params = cp_cls(
            dimension_semantics=("parallel", "parallel",
                                 "arbitrary")) if cp_cls else None
    except ImportError:  # pragma: no cover
        from repro.kernels import ref
        return ref.attention(q, k, v, causal=True, q_offset=q_offset,
                             kv_len=kv_len, softmax_scale=scale)

    kwargs = {}
    if compiler_params is not None and not interpret:
        kwargs["compiler_params"] = compiler_params

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bq=Sq, bkv=bkv,
                          n_kv=n_kv),
        grid=grid,
        in_specs=[
            smem,
            pl.BlockSpec((1, 1, Sq, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Sq, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(meta, qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
