"""Masked shard-mean Pallas kernel — the butterfly reduce inner loop.

A reducer averages one weight shard across all N miners' uploads, skipping
miners whose upload is missing/invalid (paper §5.2 failure handling).  The
kernel tiles the shard into VMEM panels and computes the masked mean in one
pass: sum over the miner axis with a fp32 validity mask, divided by the
valid count.  Not differentiated (merge runs outside the autodiff graph).

Callers go through the ``kernels.ops.shard_merge`` dispatch (compiled here
on TPU, ``ref.shard_merge`` oracle on CPU, ``REPRO_FORCE_PALLAS_INTERPRET=1``
honored); the ``interpret`` flag below exists for the kernel equivalence
suite only, like every other kernel module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import cdiv

COLS_PER_STEP = 16384        # 16 miners x 16k fp32 = 1 MiB per panel


def _merge_kernel(shards_ref, valid_ref, o_ref):
    shards = shards_ref[...].astype(jnp.float32)         # (M, cols)
    valid = valid_ref[...].astype(jnp.float32)           # (M,)
    num = jnp.einsum("mc,m->c", shards, valid)
    den = jnp.maximum(jnp.sum(valid), 1.0)
    o_ref[...] = num / den


def shard_merge(shards, valid, interpret: bool = False):
    M, L = shards.shape
    cols = min(COLS_PER_STEP, L)
    return pl.pallas_call(
        _merge_kernel,
        grid=(cdiv(L, cols),),
        in_specs=[pl.BlockSpec((M, cols), lambda i: (0, i)),
                  pl.BlockSpec((M,), lambda i: (0,))],
        out_specs=pl.BlockSpec((cols,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=interpret,
    )(shards, valid.astype(jnp.float32))
