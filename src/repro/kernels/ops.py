"""Public jit'd entry points for the Pallas kernels.

Dispatch policy:
  * On TPU backends the Pallas kernels run compiled.
  * Everywhere else (this CPU container, unit tests) we run the pure-jnp
    reference oracle — unless ``REPRO_FORCE_PALLAS_INTERPRET=1``, which runs
    the actual kernel bodies under ``interpret=True`` (used by kernel tests).

Models call ONLY these wrappers, never the kernels directly.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return True
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # backend not initialised yet
        return False


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                    softmax_scale=None):
    """GQA attention; Pallas flash kernel on TPU, oracle elsewhere.

    The backward pass always differentiates the reference formulation (the
    kernel is wrapped in ``jax.custom_vjp`` whose bwd re-runs the oracle's
    VJP) — forward speed is where the kernel matters for train/prefill.

    Off-TPU long sequences use the streaming jnp formulation
    (``ref.attention_chunked``) so the compiled graph never materializes the
    S^2 probability matrix — §Perf change #1, adopted globally after
    confirmation on the llama3.2-1b train_4k cell (EXPERIMENTS.md §Perf).
    """
    if _use_pallas() and kv_len is None and q.shape[1] > 1:
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset,
            softmax_scale=softmax_scale, interpret=_interpret())
    if _use_pallas() and kv_len is not None and causal:
        # cached decode/prefill: dynamic valid-prefix length, tiny q block —
        # the kv-streaming kernel (inference-only; no vjp, see its module)
        from repro.kernels import decode_attention as da
        return da.decode_attention(
            q, k, v, q_offset=q_offset, kv_len=kv_len,
            softmax_scale=softmax_scale, interpret=_interpret())
    # §Perf finding (EXPERIMENTS.md): expressing the flash schedule as jnp
    # scans INCREASES HLO-level traffic (block tensors + carries still round
    # -trip HBM in the compiled graph; only a real kernel boundary keeps
    # them in VMEM).  The chunked path is therefore opt-in for experiments;
    # the roofline instead reports the kernel substitution via the measured
    # attention-interior bytes (launch/hlo_cost.py).
    if (kv_len is None and q.shape[1] >= 1024
            and os.environ.get("REPRO_CHUNKED_ATTN") == "1"):
        return ref.attention_chunked(
            q, k, v, causal=causal, q_offset=q_offset,
            softmax_scale=softmax_scale)
    return ref.attention(q, k, v, causal=causal, q_offset=q_offset,
                         kv_len=kv_len, softmax_scale=softmax_scale)


# ---------------------------------------------------------------------------
# Bottleneck encode / decode (paper §4 compression hot-spot)
# ---------------------------------------------------------------------------


def bottleneck_encode(x, gamma, w_down, *, eps=1e-5, wire_dtype=jnp.bfloat16):
    if _use_pallas():
        from repro.kernels import bottleneck_fused as bf
        return bf.bottleneck_encode(x, gamma, w_down, eps=eps,
                                    wire_dtype=wire_dtype,
                                    interpret=_interpret())
    return ref.bottleneck_encode(x, gamma, w_down, eps=eps, wire_dtype=wire_dtype)


def bottleneck_decode(z, w_up, residual, alpha, *, out_dtype=jnp.bfloat16):
    if _use_pallas():
        from repro.kernels import bottleneck_fused as bf
        return bf.bottleneck_decode(z, w_up, residual, alpha,
                                    out_dtype=out_dtype, interpret=_interpret())
    return ref.bottleneck_decode(z, w_up, residual, alpha, out_dtype=out_dtype)


def bottleneck_decode_gated(z, w_up, alpha, *, out_dtype=jnp.bfloat16):
    """Pipeline stage-entry decode: alpha * (z @ W_up), fused on TPU."""
    if _use_pallas():
        from repro.kernels import bottleneck_fused as bf
        return bf.bottleneck_decode_gated(z, w_up, alpha,
                                          out_dtype=out_dtype,
                                          interpret=_interpret())
    return ref.bottleneck_decode_gated(z, w_up, alpha, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# int8 stream codec
# ---------------------------------------------------------------------------


def quantize_int8(x, block: int = 256):
    if _use_pallas():
        from repro.kernels import quant_stream as qs
        return qs.quantize_int8(x, block=block, interpret=_interpret())
    return ref.quantize_int8(x, block=block)


def dequantize_int8(q, scales, block: int = 256):
    if _use_pallas():
        from repro.kernels import quant_stream as qs
        return qs.dequantize_int8(q, scales, block=block, interpret=_interpret())
    return ref.dequantize_int8(q, scales, block=block)


@jax.custom_vjp
def _ref_wire_roundtrip(z):
    return ref.int8_wire_roundtrip(z)


def _ref_wire_fwd(z):
    return _ref_wire_roundtrip(z), None


def _ref_wire_bwd(_, g):
    # backward wire codes quantize symmetrically (straight-through)
    return (ref.int8_wire_roundtrip(g),)


_ref_wire_roundtrip.defvjp(_ref_wire_fwd, _ref_wire_bwd)


def int8_wire_roundtrip(z):
    """Differentiable int8 fake-quant of the pipeline wire (see
    quant_stream.int8_wire_roundtrip); kernel on TPU, oracle elsewhere —
    both quantize the cotangent on the way back."""
    if _use_pallas():
        from repro.kernels import quant_stream as qs
        return qs.int8_wire_roundtrip(z, interpret=_interpret())
    return _ref_wire_roundtrip(z)


def wire_encode(z):
    """Quantize a wire-code tensor into the physically shipped/stashed
    (int8 codes, fp32 scales) pair.  ``wire_decode(*wire_encode(z))`` is
    bit-identical to ``int8_wire_roundtrip(z)`` in f32 — both compose the
    same quantize/dequantize with the same wire block — so the slot
    executor can keep the compressed pair in its stash rings without
    changing numerics.  Not differentiated (the executor quantizes outside
    its vjps, exactly where the old roundtrip sat)."""
    if _use_pallas():
        from repro.kernels import quant_stream as qs
        q, s, _ = qs.quantize_wire(z, interpret=_interpret())
        return q, s
    blk = ref.wire_code_block(z.size, z.shape[-1])
    q, s = ref.quantize_int8(z.astype(jnp.float32).reshape(-1), block=blk)
    return q.reshape(z.shape), s


def wire_decode(q, scales):
    """Exact f32 dequantization of a ``wire_encode`` pair (q * scale)."""
    blk = ref.wire_code_block(q.size, q.shape[-1])
    if _use_pallas():
        from repro.kernels import quant_stream as qs
        return qs.dequantize_wire(q, scales, blk, interpret=_interpret())
    return ref.dequantize_int8(
        q.reshape(-1), scales, block=blk).reshape(q.shape)


# ---------------------------------------------------------------------------
# Butterfly shard merge
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _shard_merge_fn(use_pallas: bool, interpret: bool):
    if use_pallas:
        from repro.kernels import shard_merge as sm
        return jax.jit(functools.partial(sm.shard_merge,
                                         interpret=interpret))
    return jax.jit(ref.shard_merge)


def shard_merge(shards, valid):
    """Masked shard mean — the butterfly reduce inner loop.  Jit-cached:
    the store-and-forward executor calls this once per shard, and a plan's
    near-equal bounds produce at most two distinct shard widths, so every
    reduce after the first two hits the compile cache."""
    if _use_pallas():
        return _shard_merge_fn(True, _interpret())(shards, valid)
    return _shard_merge_fn(False, False)(shards, valid)


# ---------------------------------------------------------------------------
# Mamba selective scan (§Perf cell B kernel)
# ---------------------------------------------------------------------------


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _mamba_scan_fn(interpret: bool):
    from repro.kernels import mamba_scan as ms

    @jax.custom_vjp
    def f(delta, x, b_ssm, c_ssm, a):
        return ms.mamba_scan(delta, x, b_ssm, c_ssm, a, interpret=interpret)

    def fwd(delta, x, b_ssm, c_ssm, a):
        return f(delta, x, b_ssm, c_ssm, a), (delta, x, b_ssm, c_ssm, a)

    def bwd(res, g):
        _, vjp = jax.vjp(ms.mamba_scan_ref, *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def mamba_scan(delta, x, b_ssm, c_ssm, a):
    """Selective-scan y_t = C_t . h_t; Pallas kernel on TPU (h stays in

    VMEM — the §Perf cell B fix for the scan-carry HBM traffic), reference
    lax.scan elsewhere."""
    if _use_pallas():
        return _mamba_scan_fn(_interpret())(delta, x, b_ssm, c_ssm, a)
    from repro.kernels import mamba_scan as ms
    return ms.mamba_scan_ref(delta, x, b_ssm, c_ssm, a)
