"""Selective-scan (Mamba) Pallas TPU kernel — §Perf cell B.

The jamba-v0.1-52b train_4k cell is memory-bound on the sequential SSM
scan: in the compiled HLO the (B, d_inner, d_state) carry h round-trips HBM
every timestep (~34 GB/layer/microbatch).  This kernel keeps h resident in
VMEM scratch and streams the per-timestep inputs once:

  grid = (B, d_inner/bd, S/bs)   — the S dimension iterates sequentially
  scratch: h (bd, d_state) fp32  — persists across S blocks
  per step t:  dA = exp(delta_t (x) A);  h = dA * h + (delta_t * x_t) (x) B_t
               y_t = h . C_t + D * x_t

HBM traffic drops to one read of (delta, x, B, C) + one write of y:
~8 bytes/element/timestep vs ~2 * d_state * 4 for the carry round-trip —
a ~16x reduction of the dominant term (EXPERIMENTS.md §Perf cell B).

Validated in interpret mode against the ref scan (tests/test_kernels.py);
backward via custom_vjp over the reference formulation in ops.py style.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import cdiv

DEFAULT_BD = 256          # d_inner block
DEFAULT_BS = 512          # sequence block


def _mamba_kernel(delta_ref, x_ref, b_ref, c_ref, a_ref, y_ref, h_scr, *,
                  bs: int, bd: int, ds: int):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)                   # (bd, ds)
    delta = delta_ref[...].reshape(bs, bd).astype(jnp.float32)   # VMEM block
    x = x_ref[...].reshape(bs, bd).astype(jnp.float32)
    b = b_ref[...].reshape(bs, ds).astype(jnp.float32)
    c = c_ref[...].reshape(bs, ds).astype(jnp.float32)

    def step(t, carry):
        h, y = carry
        delta_t = jax.lax.dynamic_index_in_dim(delta, t, 0, keepdims=False)
        x_t = jax.lax.dynamic_index_in_dim(x, t, 0, keepdims=False)
        b_t = jax.lax.dynamic_index_in_dim(b, t, 0, keepdims=False)
        c_t = jax.lax.dynamic_index_in_dim(c, t, 0, keepdims=False)
        dA = jnp.exp(delta_t[:, None] * a)                # (bd, ds)
        h = dA * h + (delta_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)           # (bd,)
        y = jax.lax.dynamic_update_index_in_dim(y, y_t, t, 0)
        return h, y

    y0 = jnp.zeros((bs, delta.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, bs, step, (h_scr[...], y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)


def mamba_scan(delta, x, b_ssm, c_ssm, a, *, interpret: bool = False,
               bd: int = DEFAULT_BD, bs: int = DEFAULT_BS):
    """delta/x (B, S, d_in) f32; b_ssm/c_ssm (B, S, ds) f32; a (d_in, ds).

    Returns y (B, S, d_in) f32 with y_t = C_t . h_t (caller adds D*x and
    gating).  Forward-only; wrap with a custom_vjp against the ref scan for
    training (see ops.mamba_scan).
    """
    B, S, d_in = delta.shape
    ds = b_ssm.shape[-1]
    bd_ = min(bd, d_in)
    bs_ = min(bs, S)
    grid = (B, cdiv(d_in, bd_), cdiv(S, bs_))

    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((bd_, ds), jnp.float32)]
        kwargs = {}
        cp_cls = getattr(pltpu, "CompilerParams", None) \
            or getattr(pltpu, "TPUCompilerParams", None)
        if not interpret and cp_cls:
            kwargs["compiler_params"] = cp_cls(
                dimension_semantics=("parallel", "parallel", "arbitrary"))
    except ImportError:  # pragma: no cover
        scratch, kwargs = [], {}

    return pl.pallas_call(
        functools.partial(_mamba_kernel, bs=bs_, bd=bd_, ds=ds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs_, bd_), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, bs_, bd_), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, bs_, ds), lambda i, j, s: (i, s, 0)),
            pl.BlockSpec((1, bs_, ds), lambda i, j, s: (i, s, 0)),
            pl.BlockSpec((bd_, ds), lambda i, j, s: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs_, bd_), lambda i, j, s: (i, s, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, d_in), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(delta, x, b_ssm, c_ssm, a)


def mamba_scan_ref(delta, x, b_ssm, c_ssm, a):
    """Pure-jnp oracle (the same recurrence models/mamba.py runs).

    Uses the remat-chunked scan (scan_utils) so the CPU/compiled path keeps
    the bounded carry-storage behaviour the model had before the kernel was
    introduced — a plain lax.scan saves per-step residuals for backward and
    quadruples the jamba train memory term (§Perf cell B measurement)."""
    B, S, d_in = delta.shape

    def step(h, ins):
        delta_t, x_t, b_t, c_t = ins
        dA = jnp.exp(delta_t[..., None] * a[None])
        h = dA * h + (delta_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    from repro.models.scan_utils import chunked_scan, pick_chunk
    h0 = jnp.zeros((B, d_in, a.shape[-1]), jnp.float32)
    _, ys = chunked_scan(
        step, h0,
        (delta.transpose(1, 0, 2), x.transpose(1, 0, 2),
         b_ssm.transpose(1, 0, 2), c_ssm.transpose(1, 0, 2)),
        chunk=pick_chunk(S))
    return ys.transpose(1, 0, 2)
