"""Blockwise flash attention (forward) Pallas TPU kernel.

Grid layout (batch, q_head, q_block, kv_block); the kv_block axis is the
innermost, sequentially-iterated ("arbitrary") dimension, so the VMEM
scratch carrying the online-softmax running state (m, l, acc) persists
across kv steps and the output block is written once on the last step.
GQA folds into the K/V index maps (q head h reads kv head h // group).

VMEM budget per step at the default tiling (bq = bkv = 512, D = 128):
q/k/v blocks 3 * 512*128*2B = 384 KiB + fp32 acc 512*128*4B = 256 KiB —
comfortably inside the ~16 MiB/core budget, with the MXU seeing
(512x128)@(128x512) contractions (both dims 128-aligned).

Causality is enforced with an in-block mask; fully-masked kv blocks are
skipped via ``pl.when`` (the q_offset shift supports decode-style calls).
Backward runs through ``jax.custom_vjp`` against the jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.common import cdiv
from repro.kernels import ref

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_offset: int,
                  bq: int, bkv: int, n_kv: int):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * bq + q_offset               # absolute position of q row 0
    kv_lo = ikv * bkv
    # skip kv blocks strictly above the causal diagonal
    run = (kv_lo <= q_lo + bq - 1) if causal else True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                          # (bq, bkv)
        if causal:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _flash_call(q, k, v, *, causal, q_offset, scale, interpret,
                bq=512, bkv=512):
    """q (B, H, Sq, D), k/v (B, KH, Skv, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    _, KH, Skv, _ = k.shape
    G = H // KH
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    n_kv = cdiv(Skv, bkv)
    grid = (B, H, cdiv(Sq, bq), n_kv)

    try:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((bq,), jnp.float32),
                   pltpu.VMEM((bq,), jnp.float32),
                   pltpu.VMEM((bq, D), jnp.float32)]
        # CompilerParams (new jax) vs TPUCompilerParams (<= 0.4.x)
        cp_cls = getattr(pltpu, "CompilerParams", None) \
            or getattr(pltpu, "TPUCompilerParams", None)
        compiler_params = cp_cls(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")) if cp_cls else None
    except ImportError:  # pragma: no cover
        scratch, compiler_params = [], None

    kwargs = {}
    if compiler_params is not None and not interpret:
        kwargs["compiler_params"] = compiler_params

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, bq=bq, bkv=bkv, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(q, k, v)


@functools.lru_cache(maxsize=None)
def _flash_fn(causal: bool, q_offset: int, scale: float, interpret: bool):
    @jax.custom_vjp
    def f(q, k, v):
        # (B, S, H, D) -> (B, H, S, D) for contiguous blocking
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = _flash_call(qt, kt, vt, causal=causal, q_offset=q_offset,
                        scale=scale, interpret=interpret)
        return o.transpose(0, 2, 1, 3)

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        _, vjp = jax.vjp(
            lambda q, k, v: ref.attention(
                q, k, v, causal=causal, q_offset=q_offset,
                softmax_scale=scale), *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def flash_attention(q, k, v, *, causal=True, q_offset=0, softmax_scale=None,
                    interpret=False):
    """Drop-in for ref.attention (without kv_len masking): q (B,Sq,H,D)."""
    D = q.shape[-1]
    scale = float(softmax_scale if softmax_scale is not None
                  else 1.0 / np.sqrt(D))
    return _flash_fn(bool(causal), int(q_offset), scale, bool(interpret))(
        q, k, v)
