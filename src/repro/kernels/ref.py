"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth: kernel tests sweep shapes/dtypes and
``assert_allclose`` the Pallas output (interpret=True on CPU) against these.
They are also the default execution path on non-TPU backends, so the whole
framework runs end-to-end on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Attention (flash_attention kernel oracle)
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, KH, D)
    v: jax.Array,            # (B, Skv, KH, D)
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    softmax_scale: float | None = None,
    kv_len: jax.Array | None = None,   # (B,) valid kv length (decode w/ cache)
) -> jax.Array:
    """Grouped-query attention with optional causal mask & KV-length mask.

    ``q_offset`` is the absolute position of q[:, 0] (decode: cache length).
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    assert H % KH == 0, (H, KH)
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # (B, KH, G, Sq, D) x (B, KH, Skv, D) -> (B, KH, G, Sq, Skv)
    qf = qf.reshape(B, Sq, KH, G, D).transpose(0, 2, 3, 1, 4)
    kf = kf.transpose(0, 2, 1, 3)
    vf = vf.transpose(0, 2, 1, 3)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf)

    mask = jnp.zeros((B, 1, 1, Sq, Skv), jnp.float32)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Skv)[None, :]
        mask = mask + jnp.where(kpos <= qpos, 0.0, -jnp.inf)[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(Skv)[None, :] < kv_len[:, None]       # (B, Skv)
        mask = mask + jnp.where(valid, 0.0, -jnp.inf)[:, None, None, None, :]
    logits = logits + mask
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def attention_chunked(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Skv, KH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    softmax_scale: float | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Streaming (flash-algorithm) attention in pure jnp: online softmax over

    kv chunks inside a scan over q chunks.  Numerically identical to
    ``attention`` but the compiled graph never materializes the (Sq, Skv)
    probability matrix — per-step traffic is one (q_chunk, kv_chunk) block.
    This is the §Perf memory-term optimization for train/prefill shapes (the
    Pallas flash kernel implements the same schedule on TPU; expressing it
    in jnp makes the saving visible to the CPU dry-run's compiled HLO).
    """
    B, Sq, H, D = q.shape
    _, Skv, KH, _ = k.shape
    G = H // KH
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(D)
    if Sq % q_chunk != 0 or Skv % kv_chunk != 0 or Sq < 2 * q_chunk:
        return attention(q, k, v, causal=causal, q_offset=q_offset,
                         softmax_scale=softmax_scale)

    nq, nkv = Sq // q_chunk, Skv // kv_chunk
    # (B, KH, G, Sq, D) layout, q pre-scaled
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KH, G, D)
    qf = qf.transpose(0, 2, 3, 1, 4).reshape(B, KH, G, nq, q_chunk, D)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        B, KH, nkv, kv_chunk, D)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(
        B, KH, nkv, kv_chunk, D)

    def q_block(iq):
        qb = qf[:, :, :, iq]                          # (B,KH,G,cq,D)
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ikv):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kf, ikv, 2, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vf, ikv, 2, keepdims=False)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qb, kb)
            if causal:
                kpos = ikv * kv_chunk + jnp.arange(kv_chunk)
                mask = kpos[None, :] <= qpos[:, None]
                s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # fully-masked rows keep m = -inf; guard the exp
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, q_chunk), -jnp.inf)
        l0 = jnp.zeros((B, KH, G, q_chunk))
        a0 = jnp.zeros((B, KH, G, q_chunk, D))
        # causal: kv blocks strictly above the diagonal contribute nothing —
        # bound the scan length when q_offset is static
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nkv))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    _, out = jax.lax.scan(lambda _, iq: (None, q_block(iq)), None,
                          jnp.arange(nq))
    # (nq, B, KH, G, cq, D) -> (B, Sq, H, D)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, KH, G, Sq, D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Bottleneck fused encode/decode (paper §4) oracles
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm: fp32 variance reduction, compute-dtype application.

    §Perf change (EXPERIMENTS.md, cell C iteration 2): the variance reduces
    in fp32, but the rsqrt scale — a (rows, 1) tensor — applies in x.dtype,
    so no full-width fp32 product is written back.  (Iteration 3 tried a
    bf16 self-contraction with fp32 accumulation instead of the square/mean
    reduce; REFUTED on the CPU backend, which wraps bf16 dots in fp32
    converts — reverted to this formulation.)"""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * gamma.astype(x.dtype)


def bottleneck_encode(
    x: jax.Array,            # (..., d_model) residual-stream activation
    gamma: jax.Array,        # (d_model,) RMSNorm gain
    w_down: jax.Array,       # (d_model, d_bottleneck)
    *,
    eps: float = 1e-5,
    wire_dtype=jnp.bfloat16,
) -> jax.Array:
    """Fused RMSNorm -> down-projection -> wire-dtype cast.

    This is the compression hot-spot: the full-width activation is read from
    HBM exactly once and the (64-128x smaller) bottleneck code is written out.
    """
    h = rmsnorm(x, gamma, eps).astype(jnp.float32)
    z = h @ w_down.astype(jnp.float32)
    return z.astype(wire_dtype)


def bottleneck_decode(
    z: jax.Array,            # (..., d_bottleneck) wire code
    w_up: jax.Array,         # (d_bottleneck, d_model)
    residual: jax.Array,     # (..., d_model) partial residual (Fig 4)
    alpha: jax.Array,        # scalar: learned partial-residual mix-in weight
    *,
    out_dtype=jnp.bfloat16,
) -> jax.Array:
    """Fused up-projection + partial-residual mix: y = z @ w_up + alpha * r."""
    y = z.astype(jnp.float32) @ w_up.astype(jnp.float32)
    return (y + alpha.astype(jnp.float32) * residual.astype(jnp.float32)).astype(out_dtype)


def bottleneck_decode_gated(
    z: jax.Array,            # (..., d_bottleneck) wire code
    w_up: jax.Array,         # (d_bottleneck, d_model)
    alpha: jax.Array,        # scalar: learned decode gate
    *,
    out_dtype=jnp.bfloat16,
) -> jax.Array:
    """Pipeline-boundary decode (stage entry, no residual crosses the wire):

    y = alpha * (z @ w_up).  The fused kernel writes the full-width output
    exactly once instead of a matmul write + a separate scale pass."""
    y = z.astype(jnp.float32) @ w_up.astype(jnp.float32)
    return (alpha.astype(jnp.float32) * y).astype(out_dtype)


# ---------------------------------------------------------------------------
# int8 blockwise stream codec (compressed sharing, paper §2 stage 2) oracles
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array, block: int = 256) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8 quantization of a flat fp vector.

    Returns (q: int8 (n,), scales: f32 (n//block,)).  n must divide by block.
    """
    (n,) = x.shape
    assert n % block == 0, (n, block)
    xb = x.astype(jnp.float32).reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale[:, 0]


def dequantize_int8(q: jax.Array, scales: jax.Array, block: int = 256) -> jax.Array:
    (n,) = q.shape
    qb = q.astype(jnp.float32).reshape(n // block, block)
    return (qb * scales[:, None]).reshape(n)


def wire_code_block(n: int, last_dim: int) -> int:
    """Quantization block for an n-element wire-code tensor: the standard
    256-element block when it divides, else one scale per code row (the
    trailing bottleneck dim always divides)."""
    return 256 if n % 256 == 0 else last_dim


def int8_wire_roundtrip(z: jax.Array, block: int | None = None) -> jax.Array:
    """Oracle for the int8 pipeline wire: what the receiving stage sees after
    quantize -> (wire) -> dequantize of a bottleneck-code tensor."""
    n = z.size
    blk = block or wire_code_block(n, z.shape[-1])
    q, s = quantize_int8(z.astype(jnp.float32).reshape(-1), block=blk)
    return dequantize_int8(q, s, block=blk).reshape(z.shape).astype(z.dtype)


# ---------------------------------------------------------------------------
# Butterfly shard-merge (paper §5.2) oracle
# ---------------------------------------------------------------------------


def shard_merge(
    shards: jax.Array,       # (n_miners, shard_len) same shard from every miner
    valid: jax.Array,        # (n_miners,) bool — miner uploaded successfully
) -> jax.Array:
    """Masked mean over miner copies of one shard (element-wise arithmetic

    mean; paper says 'geometric mean' but its formulas and the redundancy
    math all treat the reduction as a plain average — we use the arithmetic
    mean and note the discrepancy in DESIGN.md)."""
    vf = valid.astype(jnp.float32)
    num = jnp.einsum("ms,m->s", shards.astype(jnp.float32), vf)
    den = jnp.maximum(jnp.sum(vf), 1.0)
    return num / den
