"""Fused bottleneck encode/decode Pallas TPU kernels (paper §4 hot-spot).

Why fuse: at every pipeline-stage boundary the full-width residual-stream
activation (rows x d_model, d_model up to 7168) must be RMSNorm-ed,
projected to the bottleneck width and cast to the wire dtype.  Unfused that
is three HBM round-trips of the full-width tensor; fused it is exactly one
read of x and one write of the (64-128x smaller) code.  The matmul inner
dims are MXU-aligned (d_model multiples of 128 for every assigned arch;
the bottleneck dim pads to the 128 lane width inside the MXU).

Tiling: rows are processed in ``block_rows`` chunks held in VMEM together
with the full (d_model x d_b) projection — d_b <= 128 keeps the weight
resident (7168x128 fp32 = 3.5 MiB), so the only streaming traffic is x.

Backward: ``jax.custom_vjp`` re-differentiates the pure-jnp oracle — the
kernels are forward-path; autodiff correctness is anchored to ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import cdiv
from repro.kernels import ref

DEFAULT_BLOCK_ROWS = 256


# ---------------------------------------------------------------------------
# encode: rows x d_model --RMSNorm @ W_down, cast--> rows x d_b
# ---------------------------------------------------------------------------


def _encode_kernel(x_ref, gamma_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps) * gamma_ref[...].astype(jnp.float32)
    z = xn @ w_ref[...].astype(jnp.float32)               # (br, db)
    o_ref[...] = z.astype(o_ref.dtype)


def _encode_call(x2d, gamma, w_down, eps, wire_dtype, interpret,
                 block_rows=DEFAULT_BLOCK_ROWS):
    R, d = x2d.shape
    db = w_down.shape[1]
    br = min(block_rows, R)
    grid = (cdiv(R, br),)
    return pl.pallas_call(
        functools.partial(_encode_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, db), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, db), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, db), wire_dtype),
        interpret=interpret,
    )(x2d, gamma, w_down)


@functools.lru_cache(maxsize=None)
def _encode_fn(eps: float, wire_dtype_name: str, interpret: bool):
    wire_dtype = jnp.dtype(wire_dtype_name)

    @jax.custom_vjp
    def f(x2d, gamma, w_down):
        return _encode_call(x2d, gamma, w_down, eps, wire_dtype, interpret)

    def fwd(x2d, gamma, w_down):
        return f(x2d, gamma, w_down), (x2d, gamma, w_down)

    def bwd(res, g):
        _, vjp = jax.vjp(
            lambda x, ga, w: ref.bottleneck_encode(
                x, ga, w, eps=eps, wire_dtype=wire_dtype), *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def bottleneck_encode(x, gamma, w_down, *, eps=1e-5, wire_dtype=jnp.bfloat16,
                      interpret=False):
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2d = x.reshape(-1, d)
    z = _encode_fn(float(eps), jnp.dtype(wire_dtype).name, bool(interpret))(
        x2d, gamma, w_down)
    return z.reshape(*lead, w_down.shape[1])


# ---------------------------------------------------------------------------
# decode: rows x d_b --@ W_up + alpha * residual--> rows x d_model
# ---------------------------------------------------------------------------


def _decode_kernel(z_ref, w_ref, r_ref, alpha_ref, o_ref):
    z = z_ref[...].astype(jnp.float32)
    y = z @ w_ref[...].astype(jnp.float32)
    y = y + alpha_ref[0].astype(jnp.float32) * r_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _decode_call(z2d, w_up, r2d, alpha, out_dtype, interpret,
                 block_rows=DEFAULT_BLOCK_ROWS):
    R, db = z2d.shape
    d = w_up.shape[1]
    br = min(block_rows, R)
    grid = (cdiv(R, br),)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, db), lambda i: (i, 0)),
            pl.BlockSpec((db, d), lambda i: (0, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), out_dtype),
        interpret=interpret,
    )(z2d, w_up, r2d, alpha)


@functools.lru_cache(maxsize=None)
def _decode_fn(out_dtype_name: str, interpret: bool):
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(z2d, w_up, r2d, alpha):
        return _decode_call(z2d, w_up, r2d, alpha.reshape(1), out_dtype,
                            interpret)

    def fwd(z2d, w_up, r2d, alpha):
        return f(z2d, w_up, r2d, alpha), (z2d, w_up, r2d, alpha)

    def bwd(res, g):
        _, vjp = jax.vjp(
            lambda z, w, r, a: ref.bottleneck_decode(
                z, w, r, a, out_dtype=out_dtype), *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def bottleneck_decode(z, w_up, residual, alpha, *, out_dtype=jnp.bfloat16,
                      interpret=False):
    lead = z.shape[:-1]
    db = z.shape[-1]
    d = w_up.shape[1]
    y = _decode_fn(jnp.dtype(out_dtype).name, bool(interpret))(
        z.reshape(-1, db), w_up, residual.reshape(-1, d),
        jnp.asarray(alpha, jnp.float32))
    return y.reshape(*lead, d)


# ---------------------------------------------------------------------------
# gated decode: rows x d_b --alpha * (@ W_up)--> rows x d_model
# (pipeline stage entry — no residual crosses the wire, only the gate)
# ---------------------------------------------------------------------------


def _decode_gated_kernel(z_ref, w_ref, alpha_ref, o_ref):
    z = z_ref[...].astype(jnp.float32)
    y = z @ w_ref[...].astype(jnp.float32)
    o_ref[...] = (alpha_ref[0].astype(jnp.float32) * y).astype(o_ref.dtype)


def _decode_gated_call(z2d, w_up, alpha, out_dtype, interpret,
                       block_rows=DEFAULT_BLOCK_ROWS):
    R, db = z2d.shape
    d = w_up.shape[1]
    br = min(block_rows, R)
    grid = (cdiv(R, br),)
    return pl.pallas_call(
        _decode_gated_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, db), lambda i: (i, 0)),
            pl.BlockSpec((db, d), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), out_dtype),
        interpret=interpret,
    )(z2d, w_up, alpha)


@functools.lru_cache(maxsize=None)
def _decode_gated_fn(out_dtype_name: str, interpret: bool):
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(z2d, w_up, alpha):
        return _decode_gated_call(z2d, w_up, alpha.reshape(1), out_dtype,
                                  interpret)

    def fwd(z2d, w_up, alpha):
        return f(z2d, w_up, alpha), (z2d, w_up, alpha)

    def bwd(res, g):
        _, vjp = jax.vjp(
            lambda z, w, a: ref.bottleneck_decode_gated(
                z, w, a, out_dtype=out_dtype), *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


def bottleneck_decode_gated(z, w_up, alpha, *, out_dtype=jnp.bfloat16,
                            interpret=False):
    lead = z.shape[:-1]
    db = z.shape[-1]
    y = _decode_gated_fn(jnp.dtype(out_dtype).name, bool(interpret))(
        z.reshape(-1, db), w_up, jnp.asarray(alpha, jnp.float32))
    return y.reshape(*lead, w_up.shape[1])
