"""int8 blockwise stream codec Pallas kernels (compressed-sharing stage).

Weights/optimizer deltas are quantized on the way into the StateStore
(paper §2 stage 2).  Symmetric per-block int8: each 256-element block gets
one fp32 scale (amax/127).  The kernels tile the flat vector into
(rows x 256) panels so quantize+scale extraction happen in one VMEM pass.
Not differentiated (codec runs outside the autodiff graph).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import cdiv

BLOCK = 256
ROWS_PER_STEP = 512          # 512 x 256 fp32 = 512 KiB per VMEM panel


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (rows, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0]


def quantize_int8(x, block: int = BLOCK, interpret: bool = False):
    (n,) = x.shape
    assert n % block == 0, (n, block)
    rows = n // block
    rp = min(ROWS_PER_STEP, rows)
    x2d = x.reshape(rows, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(cdiv(rows, rp),),
        in_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0)),
                   pl.BlockSpec((rp,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.int8),
                   jax.ShapeDtypeStruct((rows,), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return q.reshape(n), s


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...][:, None]


def dequantize_int8(q, scales, block: int = BLOCK, interpret: bool = False):
    (n,) = q.shape
    rows = n // block
    rp = min(ROWS_PER_STEP, rows)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(cdiv(rows, rp),),
        in_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0)),
                  pl.BlockSpec((rp,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rp, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.float32),
        interpret=interpret,
    )(q.reshape(rows, block), scales)
    return out.reshape(n)


# ---------------------------------------------------------------------------
# int8 pipeline wire codec (paper §4: 128x on-wire = 64x bottleneck x 2x
# int8-vs-bf16).  Bottleneck codes are quantized at stage exit and
# dequantized at stage entry; gradients crossing the wire backward are
# quantized symmetrically (the straight-through custom_vjp below), so the
# compression is the paper's symmetrical headline number.
# ---------------------------------------------------------------------------


def wire_block(n: int, last_dim: int) -> int:
    """Block size for an n-element code tensor (mirrors ref.wire_code_block):
    the standard 256-element block when it divides, else one scale per code
    row — the trailing bottleneck dim always divides the element count."""
    return BLOCK if n % BLOCK == 0 else last_dim


def quantize_wire(z, interpret: bool = False):
    """(..., d_b) code tensor -> (q int8 same-shape, scales f32, block)."""
    n = z.size
    blk = wire_block(n, z.shape[-1])
    q, s = quantize_int8(z.astype(jnp.float32).reshape(-1), block=blk,
                         interpret=interpret)
    return q.reshape(z.shape), s, blk


def dequantize_wire(q, scales, block: int, interpret: bool = False):
    out = dequantize_int8(q.reshape(-1), scales, block=block,
                          interpret=interpret)
    return out.reshape(q.shape)


def wire_nbytes(shape, block: int | None = None) -> int:
    """Honest on-wire bytes for an int8-coded tensor: int8 payload + one
    fp32 scale per block."""
    n = 1
    for dim in shape:
        n *= dim
    blk = block or wire_block(n, shape[-1])
    return n + (n // blk) * 4


@functools.lru_cache(maxsize=None)
def _roundtrip_fn(interpret: bool):
    def rt(z):
        q, s, blk = quantize_wire(z, interpret=interpret)
        return dequantize_wire(q, s, blk, interpret=interpret).astype(z.dtype)

    @jax.custom_vjp
    def f(z):
        return rt(z)

    def fwd(z):
        return f(z), None

    def bwd(_, g):
        # backward wire codes are int8 too (paper's symmetric compression);
        # the quantizer itself is straight-through
        return (rt(g),)

    f.defvjp(fwd, bwd)
    return f


def int8_wire_roundtrip(z, interpret: bool = False):
    """Differentiable fake-quant of the pipeline wire: forward sees exactly
    the dequantized int8 code the receiving stage would see; the cotangent
    is quantized the same way on the way back.  Numerically identical to
    physically shipping (int8, scales) in both directions."""
    return _roundtrip_fn(bool(interpret))(z)
