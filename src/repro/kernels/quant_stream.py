"""int8 blockwise stream codec Pallas kernels (compressed-sharing stage).

Weights/optimizer deltas are quantized on the way into the StateStore
(paper §2 stage 2).  Symmetric per-block int8: each 256-element block gets
one fp32 scale (amax/127).  The kernels tile the flat vector into
(rows x 256) panels so quantize+scale extraction happen in one VMEM pass.
Not differentiated (codec runs outside the autodiff graph).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.common import cdiv

BLOCK = 256
ROWS_PER_STEP = 512          # 512 x 256 fp32 = 512 KiB per VMEM panel


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (rows, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0]


def quantize_int8(x, block: int = BLOCK, interpret: bool = False):
    (n,) = x.shape
    assert n % block == 0, (n, block)
    rows = n // block
    rp = min(ROWS_PER_STEP, rows)
    x2d = x.reshape(rows, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(cdiv(rows, rp),),
        in_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0)),
                   pl.BlockSpec((rp,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((rows, block), jnp.int8),
                   jax.ShapeDtypeStruct((rows,), jnp.float32)],
        interpret=interpret,
    )(x2d)
    return q.reshape(n), s


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...][:, None]


def dequantize_int8(q, scales, block: int = BLOCK, interpret: bool = False):
    (n,) = q.shape
    rows = n // block
    rp = min(ROWS_PER_STEP, rows)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(cdiv(rows, rp),),
        in_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0)),
                  pl.BlockSpec((rp,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rp, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.float32),
        interpret=interpret,
    )(q.reshape(rows, block), scales)
    return out.reshape(n)
