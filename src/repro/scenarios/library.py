"""The scenario catalog: the five chaos experiments the bench matrix runs.

Each builder returns a :class:`Scenario` — a fault schedule plus a phase
list over the public swarm surface — with its ``fault_seed`` declared up
front (the swarmlint ``scenario-conformance`` gate).  docs/CHAOS.md
documents what each scenario stresses and what its pass condition is.

  * ``kill_n_miners``        — N mid-epoch crashes + crash-resume respawn
  * ``flapping_joiner``      — a miner that dies and rejoins repeatedly
  * ``slow_link``            — seeded latency + flaky reads, no crashes
  * ``tampering_under_churn``— a weight-tamperer survives a crash epoch
                               (audit attribution must still name it)
  * ``store_failover``       — primary store dies mid-run, warm standby
                               takes over
"""
from __future__ import annotations

from repro.api.config import SwarmConfig
from repro.runtime.chaos import FaultSchedule
from repro.runtime.network import MinerBehavior
from repro.scenarios.base import (
    FailPrimaryStore,
    KillMiner,
    RespawnMiner,
    RunEpochs,
    Scenario,
)


def _config(**over) -> SwarmConfig:
    base = dict(n_stages=2, miners_per_stage=2, validators=1,
                inner_steps=4, b_min=1, retain_epochs=None)
    base.update(over)
    return SwarmConfig(**base)


def kill_n_miners(n: int = 1, fault_seed: int = 1301) -> Scenario:
    """Crash ``n`` miners mid-epoch (watermark-triggered), degrade the
    epoch gracefully, then respawn them from their snapshot caches.
    Pass: loss keeps converging; each respawn resumes, not restarts."""
    phases = [RunEpochs(1)]
    # one casualty per stage (uid = stage * miners_per_stage + slot), so
    # every stage keeps a survivor and the epoch degrades, never stalls
    uids = [i * 2 for i in range(n)]
    for i, uid in enumerate(uids):
        phases.append(KillMiner(uid=uid, at_epoch=1, after_tick=1 + i))
    phases += [RunEpochs(1)]
    phases += [RespawnMiner(uid=uid) for uid in uids]
    phases += [RunEpochs(2)]
    return Scenario(name=f"kill-{n}-miners", fault_seed=fault_seed,
                    phases=tuple(phases), config=_config())


def flapping_joiner(fault_seed: int = 1303) -> Scenario:
    """One miner flaps: killed mid-epoch, respawned, killed again the
    next epoch, respawned again.  Pass: the swarm never stalls and the
    flapper's rejoins ride its snapshot cache both times."""
    return Scenario(
        name="flapping-joiner", fault_seed=fault_seed,
        phases=(
            RunEpochs(1),
            KillMiner(uid=0, at_epoch=1, after_tick=1),
            RunEpochs(1),
            RespawnMiner(uid=0),
            RunEpochs(1),
            KillMiner(uid=0, at_epoch=3, after_tick=0),
            RunEpochs(1),
            RespawnMiner(uid=0),
            RunEpochs(1),
        ),
        config=_config())


def slow_link(fault_seed: int = 1307) -> Scenario:
    """No crashes — a degraded network: seeded per-op latency and flaky
    (retried) reads on every actor's transport.  Pass: trajectory equals
    the clean run (latency faults are terminal-free), just slower."""
    return Scenario(
        name="slow-link", fault_seed=fault_seed,
        phases=(RunEpochs(3),),
        schedule=FaultSchedule(seed=fault_seed, latency_prob=0.05,
                               latency_s=0.01, drop_get=0.05),
        config=_config())


def tampering_under_churn(fault_seed: int = 1311) -> Scenario:
    """A weight-tampering miner plus a mid-epoch crash of an *honest*
    peer: graceful degradation must not launder the tamperer — the
    reduce audit still attributes it from wire artifacts alone.  Pass:
    converged and the agreement matrix flags the tamperer's copies."""
    return Scenario(
        name="tampering-under-churn", fault_seed=fault_seed,
        phases=(
            RunEpochs(1),
            KillMiner(uid=0, at_epoch=1, after_tick=1),
            RunEpochs(1),
            RespawnMiner(uid=0),
            RunEpochs(1),
        ),
        # the agreement check is bit-exact, so even a tiny tamper flags;
        # keeping it small lets the run also *converge* under the merged
        # (slightly corrupted) anchor — the scenario gates attribution,
        # not tamper survival
        behaviors={3: MinerBehavior(tamper_weights=0.01)},
        config=_config(sync_mode="sharded", share_codec="none"))


def store_failover(fault_seed: int = 1313) -> Scenario:
    """Warm-standby store: the primary dies between epochs; every client
    reconnects to the standby and replays pending requests.  Pass: the
    run completes and converges with no visible seam."""
    return Scenario(
        name="store-failover", fault_seed=fault_seed,
        phases=(
            RunEpochs(1),
            FailPrimaryStore(),
            RunEpochs(2),
        ),
        store_standby=True,
        config=_config())


SCENARIOS = {
    "kill-n-miners": kill_n_miners,
    "flapping-joiner": flapping_joiner,
    "slow-link": slow_link,
    "tampering-under-churn": tampering_under_churn,
    "store-failover": store_failover,
}
