"""repro.scenarios — deterministic chaos scenarios over the actor swarm.

The chaos-engineering layer of the repo (docs/CHAOS.md): a ``Scenario``
is a fault schedule plus a phase list over the public ``ActorSwarm``
surface — kills, respawns, store failover, plain epochs — executed by
``run_scenario`` with the measurements (convergence, recovery latency,
re-planned ticks) folded into a ``ScenarioResult``.  ``SCENARIOS`` is
the catalog the ``bench_chaos`` matrix and the smoke-test chaos shard
both draw from.
"""
from __future__ import annotations

from repro.scenarios.base import (
    FailPrimaryStore,
    KillMiner,
    RespawnMiner,
    RunEpochs,
    Scenario,
    ScenarioPhase,
    ScenarioResult,
    run_scenario,
)
from repro.scenarios.library import (
    SCENARIOS,
    flapping_joiner,
    kill_n_miners,
    slow_link,
    store_failover,
    tampering_under_churn,
)

__all__ = [
    "SCENARIOS",
    "FailPrimaryStore",
    "KillMiner",
    "RespawnMiner",
    "RunEpochs",
    "Scenario",
    "ScenarioPhase",
    "ScenarioResult",
    "flapping_joiner",
    "kill_n_miners",
    "run_scenario",
    "slow_link",
    "store_failover",
    "tampering_under_churn",
]
