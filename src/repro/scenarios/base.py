"""Scenario harness: deterministic chaos experiments over the actor swarm.

A :class:`Scenario` is a *phase list + fault schedule* — the same shape
as the lockstep timeline's ``Phase`` objects, lifted to the fleet level:
each :class:`ScenarioPhase` is one step of the chaos timeline (run
epochs, arm a mid-epoch kill, respawn a casualty, fail the primary
store), and the mandatory ``fault_seed`` pins every random choice the
scenario makes (the ``ChaosTransport`` schedule, behavior RNGs), so the
determinism contract holds end to end: same seed => same fault schedule
=> same trajectory.

``run_scenario`` owns the swarm lifecycle: build the ``ActorSwarm`` from
the scenario's knobs, execute the phases in order, fold the per-epoch
stats plus the chaos bookkeeping (recovery latency, re-planned ticks,
convergence) into a :class:`ScenarioResult`, and always shut the fleet
down.  No core-loop edits: scenarios only compose public swarm surface
(``kill_miner`` / ``respawn_miner`` / ``fail_primary`` / ``run_epoch``).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional, Protocol, runtime_checkable

from repro.api.config import EpochStats, SwarmConfig
from repro.api.swarm import Swarm
from repro.configs.base import ModelConfig
from repro.runtime.chaos import FaultSchedule
from repro.runtime.network import FaultModel, MinerBehavior


@runtime_checkable
class ScenarioPhase(Protocol):
    """One step of a chaos timeline (mirrors the driver ``Phase`` shape:
    a ``name`` and a ``run`` over mutable shared state)."""
    name: str

    def run(self, swarm: Any, result: "ScenarioResult") -> None: ...


@dataclasses.dataclass
class ScenarioResult:
    """What a scenario run measured — the row BENCH_chaos.json records."""
    name: str
    fault_seed: int
    stats: list = dataclasses.field(default_factory=list)
    converged: bool = False
    first_loss: float = float("nan")
    final_loss: float = float("nan")
    recovery_seconds: float = 0.0
    replanned_ticks: int = 0
    kills: int = 0
    notes: list = dataclasses.field(default_factory=list)

    def row(self) -> dict:
        return {
            "scenario": self.name,
            "fault_seed": self.fault_seed,
            "epochs": len(self.stats),
            "converged": bool(self.converged),
            "first_loss": float(self.first_loss),
            "final_loss": float(self.final_loss),
            "recovery_seconds": float(self.recovery_seconds),
            "replanned_ticks": int(self.replanned_ticks),
            "kills": int(self.kills),
            "notes": list(self.notes),
        }


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named chaos experiment: swarm knobs + phase list.

    ``fault_seed`` is mandatory and feeds both the ``ChaosTransport``
    schedule (when ``schedule_of`` builds one) and any behavior faults —
    the swarmlint ``scenario-conformance`` rule enforces that every
    scenario declares it."""
    name: str
    fault_seed: int
    phases: tuple                       # ScenarioPhase steps, in order
    schedule: Optional[FaultSchedule] = None
    behaviors: Any = None               # dict[int, MinerBehavior] | None
    config: Any = None                  # SwarmConfig | None
    snapshots: bool = True
    store_standby: bool = False


class RunEpochs:
    """Advance the swarm ``n`` epochs, folding stats into the result."""
    name = "run-epochs"

    def __init__(self, n: int = 1):
        self.n = n

    def run(self, swarm, result: ScenarioResult) -> None:
        for _ in range(self.n):
            stats: EpochStats = swarm.run_epoch()
            result.stats.append(stats)
            result.replanned_ticks += stats.replanned_ticks


class KillMiner:
    """Arm a mid-epoch crash: a watcher thread kills ``uid`` as soon as
    the ``after_tick``-th tick loss of epoch ``at_epoch`` lands in the
    store — a *watermark* trigger, so the kill lands at the same logical
    point of the timeline on every run."""
    name = "kill-miner"

    def __init__(self, uid: int, at_epoch: int, after_tick: int = 0):
        self.uid = uid
        self.at_epoch = at_epoch
        self.after_tick = after_tick

    def run(self, swarm, result: ScenarioResult) -> None:
        schema = swarm.transport.schema
        key = schema.tick_loss(self.at_epoch, self.after_tick)
        uid = self.uid

        def watch():
            deadline = time.monotonic() + 120.0
            while time.monotonic() < deadline:
                if swarm.epoch > self.at_epoch:
                    # the epoch raced past the watermark (tiny models can
                    # finish an epoch inside the poll interval): a late
                    # kill would hit a later epoch — or a respawn — so
                    # stand down and record the miss
                    result.notes.append(
                        f"kill of miner{uid} missed ep{self.at_epoch}")
                    return
                try:
                    if swarm.transport.exists(key):
                        break
                except (OSError, ConnectionError):
                    return
                time.sleep(0.02)
            result.notes.append(
                f"killed miner{uid} at ep{self.at_epoch} "
                f"tick>{self.after_tick}")
            result.kills += 1
            result.__dict__.setdefault("_killed_at", {})[uid] = \
                time.monotonic()
            swarm.kill_miner(uid)

        t = threading.Thread(target=watch, name=f"kill-miner{uid}",
                             daemon=True)
        t.start()


class RespawnMiner:
    """Relaunch a killed miner; records recovery latency from the kill
    timestamp to the respawned child reporting ready."""
    name = "respawn-miner"

    def __init__(self, uid: int):
        self.uid = uid

    def run(self, swarm, result: ScenarioResult) -> None:
        swarm.respawn_miner(self.uid)
        killed = result.__dict__.get("_killed_at", {}).get(self.uid)
        if killed is not None:
            result.recovery_seconds = max(result.recovery_seconds,
                                          time.monotonic() - killed)
        result.notes.append(f"respawned miner{self.uid}")


class FailPrimaryStore:
    """Kill the primary store server; clients fail over to the warm
    standby.  Records the failover as recovery latency (the time for the
    next epoch's first watermark to land is the observable)."""
    name = "fail-primary-store"

    def run(self, swarm, result: ScenarioResult) -> None:
        t0 = time.monotonic()
        swarm.fail_primary()
        # first post-failover roundtrip proves the standby took over
        swarm.transport.exists(
            swarm.transport.schema.plan(max(swarm.epoch - 1, 0)))
        result.recovery_seconds = max(result.recovery_seconds,
                                      time.monotonic() - t0)
        result.notes.append("primary store failed over to standby")


def _default_config() -> SwarmConfig:
    return SwarmConfig(n_stages=2, miners_per_stage=2, validators=1,
                       inner_steps=4, b_min=1, retain_epochs=None)


def run_scenario(scenario: Scenario, model_cfg: ModelConfig, *,
                 snapshot_root: Optional[str] = None,
                 converge_factor: float = 1.05) -> ScenarioResult:
    """Execute a scenario end to end and fold the measurements.

    ``converged`` means the final epoch's mean loss is finite and no
    worse than ``converge_factor`` x the first epoch's — chaos must not
    stop the model training (scenario tests pin tighter, oracle-relative
    tolerances on top of this)."""
    config = scenario.config or _default_config()
    faults = (FaultModel(dict(scenario.behaviors), seed=config.seed)
              if scenario.behaviors else None)
    swarm = Swarm.create(
        model_cfg, config, runtime="actors", faults=faults,
        chaos=scenario.schedule,
        snapshot_root=(snapshot_root if scenario.snapshots else None),
        store_standby=scenario.store_standby)
    result = ScenarioResult(name=scenario.name,
                            fault_seed=scenario.fault_seed)
    try:
        for phase in scenario.phases:
            phase.run(swarm, result)
    finally:
        swarm.shutdown()
    losses = [s.mean_loss for s in result.stats
              if s.mean_loss == s.mean_loss]      # drop NaN (no records)
    if losses:
        result.first_loss = losses[0]
        result.final_loss = losses[-1]
        result.converged = (result.final_loss
                            <= result.first_loss * converge_factor)
    return result
