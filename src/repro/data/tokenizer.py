"""Byte-level tokenizer with hash-folding into arbitrary vocab sizes.

The paper trains on FineWeb; offline we need a *real* text path for the
examples (quickstart trains on actual text), so: UTF-8 bytes + a small
learned-free bigram merge table hashed into [n_special, vocab).  Not BPE-
quality, but deterministic, reversible enough for demos, and vocab-size
agnostic (every assigned arch has a different vocab).
"""
from __future__ import annotations

import hashlib

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int, merge_bigrams: bool = True):
        assert vocab_size > 256 + N_SPECIAL, vocab_size
        self.vocab_size = vocab_size
        self.merge_bigrams = merge_bigrams and vocab_size > 1024

    def _fold(self, a: int, b: int) -> int:
        h = hashlib.blake2b(bytes([a, b]), digest_size=4)
        span = self.vocab_size - (256 + N_SPECIAL)
        return 256 + N_SPECIAL + int.from_bytes(h.digest(), "little") % span

    def encode(self, text: str, add_special: bool = True) -> np.ndarray:
        bs = list(text.encode("utf-8"))
        ids = []
        i = 0
        while i < len(bs):
            if (self.merge_bigrams and i + 1 < len(bs)
                    and bs[i] < 128 and bs[i + 1] < 128 and (i % 2 == 0)):
                ids.append(self._fold(bs[i], bs[i + 1]))
                i += 2
            else:
                ids.append(N_SPECIAL + bs[i])
                i += 1
        if add_special:
            ids = [BOS] + ids + [EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        out = bytearray()
        for t in np.asarray(ids).tolist():
            if t < N_SPECIAL:
                continue
            if t < N_SPECIAL + 256:
                out.append(t - N_SPECIAL)
            else:
                out.extend(b"?")          # merged tokens are not invertible
        return out.decode("utf-8", errors="replace")
