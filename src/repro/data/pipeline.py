"""Deterministic data pipeline: FineWeb-like synthetic corpus + host sharding.

Offline container => no real FineWeb.  ``SyntheticCorpus`` generates a
*learnable* token stream (a hidden per-document Markov structure over the
vocab plus repeated motifs), so convergence benchmarks show real loss
decreases; it is seeded, shardable by (host, epoch, step), and cheap.

In IOTA, layer-0 miners own data ingestion + tokenization (paper §2.2):
``make_host_iterator(host_id, n_hosts, ...)`` hands each first-layer miner a
disjoint shard by folding host_id into the stream seed, exactly how the
runtime sim wires it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.common import stable_hash


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    n_motifs: int = 64           # repeated phrases -> learnable structure
    motif_len: int = 8
    markov_order: int = 1
    doc_len: int = 512


class SyntheticCorpus:
    """Hidden-structure synthetic token stream.

    Each document draws a topic t; tokens follow a topic-conditioned bigram
    chain interleaved with exact motif repetitions.  An LM that learns the
    motifs + chain reaches substantially-below-uniform loss — enough signal
    for the paper's convergence comparisons (Fig 5 reproduction) without
    real data.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        V = cfg.vocab_size
        self.motifs = rng.randint(3, V, size=(cfg.n_motifs, cfg.motif_len))
        # low-rank bigram logits: token -> distribution over next tokens
        rank = 16
        self._emb_in = rng.randn(V, rank).astype(np.float32) * 0.7
        self._emb_out = rng.randn(rank, V).astype(np.float32) * 0.7
        self._topic_shift = rng.randn(8, rank).astype(np.float32)

    def _doc(self, rng: np.random.RandomState) -> np.ndarray:
        cfg = self.cfg
        V = cfg.vocab_size
        topic = rng.randint(len(self._topic_shift))
        out = np.empty(cfg.doc_len, np.int64)
        tok = rng.randint(3, V)
        i = 0
        while i < cfg.doc_len:
            if rng.rand() < 0.15:                       # motif insertion
                m = self.motifs[rng.randint(cfg.n_motifs)]
                n = min(len(m), cfg.doc_len - i)
                out[i:i + n] = m[:n]
                i += n
                tok = int(out[i - 1])
                continue
            logits = (self._emb_in[tok] + 0.5 * self._topic_shift[topic]
                      ) @ self._emb_out
            # top-64 sampling keeps the chain predictable
            top = np.argpartition(logits, -64)[-64:]
            p = np.exp(logits[top] - logits[top].max())
            p /= p.sum()
            tok = int(top[rng.choice(len(top), p=p)])
            out[i] = tok
            i += 1
        return out

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        """Deterministic (host, step)-addressed batch: {tokens, labels}."""
        cfg = self.cfg
        rng = np.random.RandomState(
            stable_hash(cfg.seed, "batch", host_id, n_hosts, step) % (2**31))
        need = cfg.batch_size * (cfg.seq_len + 1)
        stream = []
        while sum(len(d) for d in stream) < need:
            stream.append(self._doc(rng))
        flat = np.concatenate(stream)[:need].reshape(
            cfg.batch_size, cfg.seq_len + 1).astype(np.int32)
        return {"tokens": flat[:, :-1], "labels": flat[:, 1:]}


def make_host_iterator(cfg: DataConfig, host_id: int = 0, n_hosts: int = 1,
                       start_step: int = 0) -> Iterator[dict]:
    """Resumable per-host iterator (checkpoint stores the step cursor)."""
    corpus = SyntheticCorpus(cfg)
    step = start_step
    while True:
        yield corpus.batch(step, host_id, n_hosts)
        step += 1
