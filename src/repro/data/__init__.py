from repro.data.pipeline import DataConfig, SyntheticCorpus, make_host_iterator  # noqa: F401
from repro.data.tokenizer import ByteTokenizer  # noqa: F401
