from repro.sharding.partition import (  # noqa: F401
    MeshAxes,
    batch_spec,
    make_mesh_axes,
    param_shardings,
    param_specs,
    shard_constraint,
)
